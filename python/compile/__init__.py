"""Build-time compile package: L2 jax model + L1 pallas kernels + AOT.

Never imported at runtime; `make artifacts` is its only consumer."""
