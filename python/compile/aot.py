"""AOT compile path: lower the L2 models (TCN + ablation variants + DNN
baseline) to HLO **text** and emit the artifact bundle the rust runtime
consumes:

    artifacts/
      manifest.json          # shapes, param order, batch sizes, file map
      params_<model>.bin     # f32 LE initial parameters, manifest order
      <model>_infer.hlo.txt  # (params..., x) -> (probs,)
      <model>_train.hlo.txt  # (params..., m..., v..., step, x, y)
                             #   -> (params', m', v', loss)
      <model>_eval.hlo.txt   # (params..., x, y) -> (loss,)

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

INFER_BATCH = 256
TRAIN_BATCH = 512
EVAL_BATCH = 512


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_model(name: str, mdef: dict, out_dir: str, seed: int) -> dict:
    """Lower infer/train/eval for one model; write files; return manifest."""
    specs = mdef["specs"]
    n = len(specs)
    window = mdef["window"]
    fdim = mdef["feature_dim"]
    x_infer = spec((INFER_BATCH, window, fdim)) if mdef["kind"] == "tcn" else spec((INFER_BATCH, fdim))
    x_train = spec((TRAIN_BATCH, window, fdim)) if mdef["kind"] == "tcn" else spec((TRAIN_BATCH, fdim))
    x_eval = spec((EVAL_BATCH, window, fdim)) if mdef["kind"] == "tcn" else spec((EVAL_BATCH, fdim))
    p_specs = [spec(s) for _, s in specs]

    files = {}

    # --- infer: (params..., x) -> (probs,) -------------------------------
    def infer_fn(*args):
        return (mdef["infer"](list(args[:n]), args[n]),)

    lowered = jax.jit(infer_fn).lower(*p_specs, x_infer)
    files["infer"] = f"{name}_infer.hlo.txt"
    with open(os.path.join(out_dir, files["infer"]), "w") as f:
        f.write(to_hlo_text(lowered))

    # --- train step -------------------------------------------------------
    train_step = M.make_train_step(mdef["forward"], n)
    t_args = p_specs + p_specs + p_specs + [spec(()), x_train, spec((TRAIN_BATCH,))]
    lowered = jax.jit(train_step).lower(*t_args)
    files["train"] = f"{name}_train.hlo.txt"
    with open(os.path.join(out_dir, files["train"]), "w") as f:
        f.write(to_hlo_text(lowered))

    # --- eval loss --------------------------------------------------------
    eval_loss = M.make_eval_loss(mdef["forward"])
    lowered = jax.jit(eval_loss).lower(*p_specs, x_eval, spec((EVAL_BATCH,)))
    files["eval"] = f"{name}_eval.hlo.txt"
    with open(os.path.join(out_dir, files["eval"]), "w") as f:
        f.write(to_hlo_text(lowered))

    # --- initial parameters ------------------------------------------------
    params = M.init_params(specs, seed=seed)
    bin_name = f"params_{name}.bin"
    with open(os.path.join(out_dir, bin_name), "wb") as f:
        for p in params:
            f.write(bytes(jnp.asarray(p, jnp.float32).tobytes()))

    return {
        "kind": mdef["kind"],
        "window": window,
        "feature_dim": fdim,
        "dilations": mdef["dilations"],
        "params": [{"name": nm, "shape": list(sh)} for nm, sh in specs],
        "params_bin": bin_name,
        "infer": {"hlo": files["infer"], "batch": INFER_BATCH},
        "train": {"hlo": files["train"], "batch": TRAIN_BATCH, "n_params": n},
        "eval": {"hlo": files["eval"], "batch": EVAL_BATCH},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--models", default="tcn,tcn_flat,tcn_short,dnn",
        help="comma-separated subset of the model zoo",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    zoo = M.model_zoo()
    manifest = {
        "version": 1,
        "adam": {"lr": M.ADAM_LR, "b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
        "dropout_p": M.DROPOUT_P,
        "models": {},
    }
    for name in args.models.split(","):
        name = name.strip()
        if name not in zoo:
            raise SystemExit(f"unknown model '{name}' (zoo: {sorted(zoo)})")
        print(f"[aot] lowering {name} ...", flush=True)
        manifest["models"][name] = lower_model(name, zoo[name], args.out, args.seed)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[aot] wrote {args.out}/manifest.json ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
