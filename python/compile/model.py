"""L2: the paper's predictor models as pure-jax functions over explicit
parameter lists, built on the L1 Pallas kernels.

- **TCN** (§3.2, Fig. 1): three dilated causal conv layers (kernel 3,
  dilations [1, 2, 4] by default), ReLU between layers, then two fully-
  connected layers on the last time step with dropout p=0.3 (§4.2) and a
  sigmoid head producing the reuse probability ŷ_t of eq. (1).
- **DNN (ML-Predict baseline)**: an MLP over the *current* access feature
  vector only — the canonical "no temporal weight sharing" baseline the
  paper contrasts against (DESIGN.md §3).
- **Training** (§3.4): binary cross-entropy (eq. 4) + Adam(lr=1e-4), one
  fused ``train_step`` suitable for AOT lowering: all state (params, Adam
  moments, step) is explicit inputs/outputs, so the rust trainer can drive
  epochs without Python.

Parameters travel as flat lists in a fixed order (see ``*_param_specs``);
``aot.py`` serializes the same order into ``manifest.json`` + ``params_*.bin``
and the rust ``runtime::params`` loader mirrors it.

Dropout is deterministic-counter based (a Fibonacci-hash of element index
folded with the step) rather than ``jax.random``: xla_extension 0.5.1 has no
problem with threefry, but the counter scheme keeps the train-step HLO free
of RNG state plumbing and makes rust-side replay bit-reproducible.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels.dense import dense
from .kernels.tcn_conv import dilated_causal_conv1d

# ---------------------------------------------------------------------------
# Architecture constants (paper §4.2)
# ---------------------------------------------------------------------------
FEATURE_DIM = 12          # per-access feature vector (paper eq. 5 features)
WINDOW = 16               # per-line history length fed to the TCN
TCN_CHANNELS = 32
TCN_KERNEL = 3
TCN_DILATIONS = (1, 2, 4)  # receptive field 1 + 2*(1+2+4) = 15 ≤ WINDOW
FC_HIDDEN = 16
DROPOUT_P = 0.3
DNN_HIDDEN = (64, 32)
ADAM_LR = 1e-4
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# ---------------------------------------------------------------------------
# Parameter specs + init
# ---------------------------------------------------------------------------

def tcn_param_specs(dilations: Sequence[int] = TCN_DILATIONS):
    """Ordered (name, shape) list — the AOT/rust param contract."""
    specs = []
    cin = FEATURE_DIM
    for i, _ in enumerate(dilations):
        specs.append((f"conv{i}_w", (TCN_KERNEL, cin, TCN_CHANNELS)))
        specs.append((f"conv{i}_b", (TCN_CHANNELS,)))
        cin = TCN_CHANNELS
    specs.append(("fc1_w", (TCN_CHANNELS, FC_HIDDEN)))
    specs.append(("fc1_b", (FC_HIDDEN,)))
    specs.append(("fc2_w", (FC_HIDDEN, 1)))
    specs.append(("fc2_b", (1,)))
    return specs


def dnn_param_specs():
    specs = []
    cin = FEATURE_DIM
    for i, h in enumerate(DNN_HIDDEN):
        specs.append((f"fc{i}_w", (cin, h)))
        specs.append((f"fc{i}_b", (h,)))
        cin = h
    specs.append((f"fc{len(DNN_HIDDEN)}_w", (cin, 1)))
    specs.append((f"fc{len(DNN_HIDDEN)}_b", (1,)))
    return specs


def init_params(specs, seed: int = 0):
    """He-style init, deterministic in `seed`."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in specs:
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            if len(shape) == 3:  # conv: (K, Cin, Cout)
                fan_in = shape[0] * shape[1]
            scale = jnp.sqrt(2.0 / fan_in)
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


# ---------------------------------------------------------------------------
# Deterministic dropout (counter-based; no RNG ops in the lowered HLO)
# ---------------------------------------------------------------------------

def _hash_uniform(shape, step: jax.Array, salt: int) -> jax.Array:
    """Pseudo-uniform in [0,1): Fibonacci hash of (element index, step)."""
    n = 1
    for s in shape:
        n *= s
    idx = jax.lax.iota(jnp.uint32, n)
    stepu = step.astype(jnp.uint32) + jnp.uint32(salt)
    h = (idx + stepu * jnp.uint32(0x9E3779B9)) * jnp.uint32(0x85EBCA6B)
    h = (h ^ (h >> 13)) * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return (h.astype(jnp.float32) / jnp.float32(4294967296.0)).reshape(shape)


def dropout(x: jax.Array, step: jax.Array, *, p: float = DROPOUT_P, salt: int = 1) -> jax.Array:
    keep = (_hash_uniform(x.shape, step, salt) >= p).astype(jnp.float32)
    return x * keep / (1.0 - p)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def tcn_forward(params, x, *, dilations: Sequence[int] = TCN_DILATIONS,
                train: bool = False, step=None):
    """ŷ = σ(W ⊛ X + b) stack (eq. 1). x: (B, T, F) → (B,) reuse probs."""
    h = x
    i = 0
    for li, d in enumerate(dilations):
        w, b = params[i], params[i + 1]
        i += 2
        h = dilated_causal_conv1d(h, w, b, dilation=d)
        h = jnp.maximum(h, 0.0)
        del li
    last = h[:, -1, :]  # prediction for the line's state *now*
    z = dense(last, params[i], params[i + 1], activation="relu")
    if train:
        z = dropout(z, step, salt=7)
    logit_w, logit_b = params[i + 2], params[i + 3]
    # Return logits from a fused dense; sigmoid applied by callers/loss.
    logits = dense(z, logit_w, logit_b, activation="none")[:, 0]
    return logits


def dnn_forward(params, x, *, train: bool = False, step=None):
    """ML-Predict baseline. x: (B, F) current-access features → (B,) logits."""
    h = x
    i = 0
    for li in range(len(DNN_HIDDEN)):
        h = dense(h, params[i], params[i + 1], activation="relu")
        i += 2
        if train and li == len(DNN_HIDDEN) - 1:
            h = dropout(h, step, salt=11)
    return dense(h, params[i], params[i + 1], activation="none")[:, 0]


def tcn_infer(params, x):
    """AOT entry: (params..., x[B,T,F]) → reuse probabilities (B,)."""
    return jax.nn.sigmoid(tcn_forward(params, x))


def dnn_infer(params, x):
    return jax.nn.sigmoid(dnn_forward(params, x))


# ---------------------------------------------------------------------------
# Loss (eq. 4) + Adam train step
# ---------------------------------------------------------------------------

def bce_from_logits(logits, y):
    """Numerically-stable binary cross-entropy (eq. 4)."""
    # max(z,0) - z*y + log(1+exp(-|z|))
    return jnp.mean(jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def make_train_step(forward, n_params: int):
    """Build ``train_step(params, m, v, step, x, y) → (params', m', v', loss)``.

    Everything is positional f32 tensors so the lowered HLO has a flat
    (3*n_params + 3)-input, (3*n_params + 1)-output signature the rust
    trainer can drive generically.
    """

    def loss_fn(params, x, y, step):
        logits = forward(params, x, train=True, step=step)
        return bce_from_logits(logits, y)

    def train_step(*args):
        params = list(args[:n_params])
        m = list(args[n_params:2 * n_params])
        v = list(args[2 * n_params:3 * n_params])
        step = args[3 * n_params]
        x = args[3 * n_params + 1]
        y = args[3 * n_params + 2]

        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, step)
        step1 = step + 1.0
        b1t = ADAM_B1 ** step1
        b2t = ADAM_B2 ** step1
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, grads, m, v):
            mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
            vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
            mhat = mi / (1.0 - b1t)
            vhat = vi / (1.0 - b2t)
            new_p.append(p - ADAM_LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
            new_m.append(mi)
            new_v.append(vi)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    return train_step


def make_eval_loss(forward):
    """``eval_loss(params, x, y) → loss`` (no dropout) for val/test curves."""

    def eval_loss(*args):
        params = list(args[:-2])
        x, y = args[-2], args[-1]
        logits = forward(params, x, train=False)
        return (bce_from_logits(logits, y),)

    return eval_loss


# Named model zoo for aot.py and the ablation benches.
def model_zoo():
    """name → dict(forward, infer, specs, dilations/window metadata)."""

    def tcn_like(name, dilations, window):
        def fwd(params, x, *, train=False, step=None):
            return tcn_forward(params, x, dilations=dilations, train=train, step=step)

        return {
            "name": name,
            "kind": "tcn",
            "window": window,
            "feature_dim": FEATURE_DIM,
            "specs": tcn_param_specs(dilations),
            "forward": fwd,
            "infer": lambda params, x: jax.nn.sigmoid(fwd(params, x)),
            "dilations": list(dilations),
        }

    return {
        "tcn": tcn_like("tcn", TCN_DILATIONS, WINDOW),
        # Ablation B: no dilation growth (receptive field 7 instead of 15).
        "tcn_flat": tcn_like("tcn_flat", (1, 1, 1), WINDOW),
        # Ablation B': single-scale shallow variant.
        "tcn_short": tcn_like("tcn_short", (1, 2), WINDOW),
        "dnn": {
            "name": "dnn",
            "kind": "dnn",
            "window": 1,
            "feature_dim": FEATURE_DIM,
            "specs": dnn_param_specs(),
            "forward": dnn_forward,
            "infer": dnn_infer,
            "dilations": [],
        },
    }
