"""L1 Pallas kernel: fused dense layer (matmul + bias + activation).

Used by the TCN head (FC→ReLU→FC→sigmoid) and by the entire ML-Predict DNN
baseline. Fusing bias+activation into the matmul kernel keeps the activation
tensor in VMEM for its whole lifetime — one HBM round-trip per layer instead
of three.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "sigmoid":
        y = jax.nn.sigmoid(y)
    elif activation != "none":
        raise ValueError(f"activation {activation}")
    o_ref[...] = y


def _dense_pallas(x, w, b, activation: str, block_b: int):
    batch, cin = x.shape
    cin_w, cout = w.shape
    assert cin == cin_w, f"dims {cin} vs {cin_w}"
    block_b = min(block_b, batch)
    assert batch % block_b == 0, f"B={batch} % block_b={block_b}"
    kernel = functools.partial(_dense_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=(batch // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, cin), lambda i: (i, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, cout), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32))


# Analytic VJP (interpret-mode pallas_call is not reverse-differentiable):
# the pre-activation is recomputed in the backward pass — cheaper than
# stashing it, and XLA fuses it with the surrounding train-step HLO.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _dense(x, w, b, activation, block_b):
    return _dense_pallas(x, w, b, activation, block_b)


def _dense_fwd(x, w, b, activation, block_b):
    return _dense_pallas(x, w, b, activation, block_b), (x, w, b)


def _dense_bwd(activation, block_b, res, dy):
    x, w, b = res
    pre = x @ w + b[None, :]
    if activation == "relu":
        dpre = dy * (pre > 0).astype(dy.dtype)
    elif activation == "sigmoid":
        s = jax.nn.sigmoid(pre)
        dpre = dy * s * (1.0 - s)
    else:
        dpre = dy
    dx = dpre @ w.T
    dw = x.T @ dpre
    db = dpre.sum(axis=0)
    return dx, dw, db


_dense.defvjp(_dense_fwd, _dense_bwd)


@functools.partial(jax.jit, static_argnames=("activation", "block_b"))
def dense(
    x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "none",
    block_b: int = DEFAULT_BLOCK_B,
) -> jax.Array:
    """Fused ``act(x @ w + b)``: x (B, In), w (In, Out), b (Out,)."""
    return _dense(x, w, b, activation, block_b)


def vmem_bytes(block_b: int, cin: int, cout: int) -> int:
    """Per-grid-step VMEM footprint (f32)."""
    return (block_b * cin + cin * cout + cout + block_b * cout) * 4
