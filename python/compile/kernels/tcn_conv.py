"""L1 Pallas kernel: dilated causal 1-D convolution — the TCN hot-spot.

The paper's predictor (eq. 1) is a stack of dilated causal convolutions over
per-line access-feature sequences. On GPU the reference implementation would
be a cuDNN conv; here the kernel is *rethought for TPU* (DESIGN.md
§Hardware-Adaptation):

- the input block ``(B_tile, T + pad, C_in)`` and the full filter
  ``(K, C_in, C_out)`` are staged in VMEM via ``BlockSpec`` (no HBM traffic
  inside the kernel);
- the dilated gather is restructured into ``K`` *static* slices of the
  left-padded input, each feeding a dense ``(B_tile*T, C_in) @ (C_in, C_out)``
  matmul — i.e. all FLOPs land on the MXU systolic array instead of a
  sliding-window loop;
- causality comes from the left-padding alone: output ``t`` only sees inputs
  ``t - k*d`` for ``k in [0, K)``.

``interpret=True`` is mandatory on this image: CPU PJRT cannot execute
Mosaic custom-calls, and the interpreted path lowers to plain HLO that the
rust runtime executes directly. Numerics are pinned against the pure-jnp
oracle in ``ref.py`` by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: keeps the VMEM slab small (see vmem_bytes()) while leaving the
# (B_tile*T, C_in) matmul big enough to fill the 128x128 MXU.
DEFAULT_BLOCK_B = 64


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, dilation: int, t: int):
    """One grid step: causal dilated conv over a (B_tile, T+pad, C_in) slab.

    x_ref holds the *pre-padded* input, so slice ``[:, j*d : j*d+T, :]`` is
    the shifted view feeding filter tap ``j``; the loop over taps is a python
    loop over K static slices — unrolled at trace time into K MXU matmuls.
    """
    x = x_ref[...]  # (Bt, T + (k-1)*d, Cin)
    w = w_ref[...]  # (K, Cin, Cout)
    b = b_ref[...]  # (Cout,)
    bt = x.shape[0]
    cin = x.shape[2]
    cout = w.shape[2]
    acc = jnp.zeros((bt * t, cout), dtype=jnp.float32)
    for j in range(k):
        # Tap j sees input shifted by j*dilation; with left-pad (k-1)*d the
        # slice is static — no gather, pure contiguous reads.
        xj = jax.lax.slice_in_dim(x, j * dilation, j * dilation + t, axis=1)
        acc = acc + jnp.dot(
            xj.reshape(bt * t, cin), w[j], preferred_element_type=jnp.float32
        )
    o_ref[...] = (acc + b[None, :]).reshape(bt, t, cout)


def _conv_pallas(x, w, b, dilation: int, block_b: int):
    batch, t, cin = x.shape
    k, cin_w, cout = w.shape
    assert cin == cin_w, f"channel mismatch {cin} vs {cin_w}"
    block_b = min(block_b, batch)
    assert batch % block_b == 0, f"B={batch} not divisible by block_b={block_b}"
    pad = (k - 1) * dilation

    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (pad, 0), (0, 0)))
    grid = (batch // block_b,)
    kernel = functools.partial(_conv_kernel, k=k, dilation=dilation, t=t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, t + pad, cin), lambda i: (i, 0, 0)),
            pl.BlockSpec((k, cin, cout), lambda i: (0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, t, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, t, cout), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, w.astype(jnp.float32), b.astype(jnp.float32))


# Interpret-mode pallas_call does not support reverse-mode AD, so the kernel
# carries an analytic VJP: the backward pass is the standard conv-transpose
# expressed as K shifted matmuls (MXU-shaped, same as the forward) in plain
# jnp — it lowers into the same fused train-step HLO.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _conv(x, w, b, dilation, block_b):
    return _conv_pallas(x, w, b, dilation, block_b)


def _conv_fwd(x, w, b, dilation, block_b):
    return _conv_pallas(x, w, b, dilation, block_b), (x, w)


def _conv_bwd(dilation, block_b, res, dy):
    x, w = res
    k = w.shape[0]
    _, t, _ = x.shape
    pad = (k - 1) * dilation
    xp = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    dw = jnp.stack(
        [
            jnp.einsum(
                "btc,bto->co",
                jax.lax.slice_in_dim(xp, j * dilation, j * dilation + t, axis=1),
                dy,
            )
            for j in range(k)
        ]
    )
    db = dy.sum(axis=(0, 1))
    dxp = jnp.zeros_like(xp)
    for j in range(k):
        upd = jnp.einsum("bto,co->btc", dy, w[j])
        dxp = dxp.at[:, j * dilation : j * dilation + t, :].add(upd)
    dx = dxp[:, pad:, :]
    return dx, dw, db


_conv.defvjp(_conv_fwd, _conv_bwd)


@functools.partial(jax.jit, static_argnames=("dilation", "block_b"))
def dilated_causal_conv1d(
    x: jax.Array, w: jax.Array, b: jax.Array, *, dilation: int, block_b: int = DEFAULT_BLOCK_B
) -> jax.Array:
    """Causal dilated conv: x (B, T, Cin), w (K, Cin, Cout), b (Cout,).

    Returns (B, T, Cout) float32. B must be divisible by ``block_b`` (the AOT
    path lowers with fixed shapes, so this is checked at trace time).
    Differentiable via the custom VJP above.
    """
    return _conv(x, w, b, dilation, block_b)


def vmem_bytes(block_b: int, t: int, cin: int, cout: int, k: int, dilation: int) -> int:
    """Per-grid-step VMEM footprint estimate (f32), used by the §Perf
    structural analysis in EXPERIMENTS.md: input slab + filter + output."""
    pad = (k - 1) * dilation
    x_slab = block_b * (t + pad) * cin * 4
    w_slab = k * cin * cout * 4
    o_slab = block_b * t * cout * 4
    acc = block_b * t * cout * 4
    return x_slab + w_slab + o_slab + acc


def mxu_flops_fraction() -> float:
    """Fraction of kernel FLOPs issued as MXU-shaped matmuls: the tap loop
    emits only ``jnp.dot`` contractions plus a bias add, so effectively all
    multiply-accumulate work is MXU work."""
    return 1.0
