"""Pure-jnp oracles for the Pallas kernels (the L1 correctness contract).

These are written with independent primitives (``lax.conv_general_dilated``
for the conv, plain ``@`` for dense) so a bug in the kernels' slicing or
blocking logic cannot be mirrored here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dilated_causal_conv1d_ref(x: jax.Array, w: jax.Array, b: jax.Array, *, dilation: int) -> jax.Array:
    """Oracle for kernels.tcn_conv.dilated_causal_conv1d.

    x: (B, T, Cin), w: (K, Cin, Cout), b: (Cout,) → (B, T, Cout).
    Causal: output t depends on inputs t, t-d, ..., t-(K-1)*d.
    """
    k = w.shape[0]
    pad = (k - 1) * dilation
    # conv_general_dilated with explicit left padding; feature dims:
    # lhs (B, T, C) = "NWC"; rhs (K, Cin, Cout) = "WIO".
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1,),
        padding=[(pad, 0)],
        rhs_dilation=(dilation,),
        dimension_numbers=("NWC", "WIO", "NWC"),
    ) + b[None, None, :]


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "none") -> jax.Array:
    """Oracle for kernels.dense.dense."""
    y = x.astype(jnp.float32) @ w.astype(jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "sigmoid":
        y = jax.nn.sigmoid(y)
    elif activation != "none":
        raise ValueError(activation)
    return y
