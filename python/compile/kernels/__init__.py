"""L1 Pallas kernels (build-time only; lowered into the model HLO)."""

from . import dense, ref, tcn_conv  # noqa: F401
