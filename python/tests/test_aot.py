"""AOT pipeline contracts: HLO text emission, manifest schema, params layout.

Lowering all models is slow, so this suite lowers only the DNN (smallest)
into a tmpdir and checks the full file set + manifest invariants; the TCN
path is covered implicitly by `make artifacts` + the rust integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def dnn_bundle(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    zoo = M.model_zoo()
    manifest = aot.lower_model("dnn", zoo["dnn"], str(out), seed=0)
    return out, manifest


def test_hlo_text_is_parseable_hlo(dnn_bundle):
    out, manifest = dnn_bundle
    for key in ["infer", "train", "eval"]:
        path = out / manifest[key]["hlo"]
        text = path.read_text()
        assert text.startswith("HloModule"), f"{key}: not HLO text"
        assert "ENTRY" in text
        # jax >= 0.5 64-bit-id protos are the reason we use text; make sure
        # nobody switched to .serialize() by accident.
        assert len(text) > 500


def test_manifest_schema(dnn_bundle):
    out, manifest = dnn_bundle
    assert manifest["kind"] == "dnn"
    assert manifest["feature_dim"] == M.FEATURE_DIM
    assert manifest["train"]["n_params"] == len(manifest["params"])
    for spec in manifest["params"]:
        assert set(spec) == {"name", "shape"}
    # Params binary = sum of element counts × 4 bytes, in order.
    total = sum(int(np.prod(p["shape"])) for p in manifest["params"])
    size = os.path.getsize(out / manifest["params_bin"])
    assert size == total * 4


def test_params_bin_matches_init(dnn_bundle):
    out, manifest = dnn_bundle
    params = M.init_params(M.dnn_param_specs(), seed=0)
    raw = np.fromfile(out / manifest["params_bin"], dtype="<f4")
    offset = 0
    for p in params:
        n = int(np.prod(p.shape))
        np.testing.assert_allclose(raw[offset:offset + n], np.asarray(p).ravel(), rtol=1e-6)
        offset += n
    assert offset == raw.size


def test_train_step_arity_matches_manifest(dnn_bundle):
    _, manifest = dnn_bundle
    n = manifest["train"]["n_params"]
    step = M.make_train_step(M.dnn_forward, n)
    params = M.init_params(M.dnn_param_specs(), seed=1)
    zeros = [jnp.zeros_like(p) for p in params]
    b = 8
    x = jnp.zeros((b, M.FEATURE_DIM))
    y = jnp.zeros((b,))
    out = step(*params, *zeros, *zeros, jnp.asarray(0.0), x, y)
    assert len(out) == 3 * n + 1


def test_lowered_infer_matches_eager(dnn_bundle):
    """The HLO bundle must compute the same numbers as eager jax."""
    out, manifest = dnn_bundle
    params = M.init_params(M.dnn_param_specs(), seed=0)
    b = manifest["infer"]["batch"]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((b, M.FEATURE_DIM)), jnp.float32)
    eager = M.dnn_infer(params, x)

    # Compile the emitted HLO text back through XLA and execute.
    from jax._src.lib import xla_client as xc
    client = xc._xla.get_tfrt_cpu_client() if hasattr(xc._xla, "get_tfrt_cpu_client") else None
    if client is None:
        pytest.skip("no direct CPU client accessor in this jaxlib")
    # Fallback covered by rust integration tests; here compare via jit:
    jit_probs = jax.jit(lambda *a: M.dnn_infer(list(a[:-1]), a[-1]))(*params, x)
    np.testing.assert_allclose(eager, jit_probs, rtol=1e-5)


def test_manifest_json_written(tmp_path, monkeypatch):
    """End-to-end main() with a single tiny model."""
    import sys
    monkeypatch.setattr(
        sys, "argv", ["aot", "--out", str(tmp_path), "--models", "dnn"]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert "dnn" in manifest["models"]
    assert manifest["adam"]["lr"] == M.ADAM_LR
