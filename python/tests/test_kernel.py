"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes/dilations/activations; every case asserts
allclose — this is the core numerical contract of the AOT bundle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dense import dense, vmem_bytes as dense_vmem
from compile.kernels.ref import dense_ref, dilated_causal_conv1d_ref
from compile.kernels.tcn_conv import dilated_causal_conv1d, vmem_bytes as conv_vmem


def rng_arrays(seed, *shapes):
    r = np.random.default_rng(seed)
    return [jnp.asarray(r.standard_normal(s), jnp.float32) for s in shapes]


# ---------------------------------------------------------------------------
# Dilated causal conv
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    t=st.sampled_from([4, 8, 16, 20]),
    cin=st.sampled_from([1, 3, 12]),
    cout=st.sampled_from([1, 8, 32]),
    k=st.sampled_from([1, 2, 3]),
    d=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
)
def test_conv_matches_ref(b, t, cin, cout, k, d, seed):
    x, w, bias = rng_arrays(seed, (b, t, cin), (k, cin, cout), (cout,))
    got = dilated_causal_conv1d(x, w, bias, dilation=d, block_b=b)
    want = dilated_causal_conv1d_ref(x, w, bias, dilation=d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_conv_causality():
    """Output at time t must not change when future inputs change."""
    b, t, cin, cout, k, d = 2, 16, 4, 8, 3, 2
    x, w, bias = rng_arrays(0, (b, t, cin), (k, cin, cout), (cout,))
    y1 = dilated_causal_conv1d(x, w, bias, dilation=d, block_b=b)
    x2 = x.at[:, 10:, :].set(99.0)  # perturb the future
    y2 = dilated_causal_conv1d(x2, w, bias, dilation=d, block_b=b)
    np.testing.assert_allclose(y1[:, :10, :], y2[:, :10, :], rtol=1e-6)
    assert not np.allclose(y1[:, 10:, :], y2[:, 10:, :])


def test_conv_receptive_field_exact():
    """With K=3, d=4 the output at t sees exactly {t, t-4, t-8}."""
    b, t, cin, cout = 1, 16, 2, 3
    x, w, bias = rng_arrays(3, (b, t, cin), (3, cin, cout), (cout,))
    y0 = dilated_causal_conv1d(x, w, bias, dilation=4, block_b=b)
    # Changing t-1 (not in the tap set of t=15) must not change y[15].
    x2 = x.at[:, 14, :].add(5.0)
    y2 = dilated_causal_conv1d(x2, w, bias, dilation=4, block_b=b)
    np.testing.assert_allclose(y0[:, 15, :], y2[:, 15, :], rtol=1e-6)
    # Changing t-4 must change it.
    x3 = x.at[:, 11, :].add(5.0)
    y3 = dilated_causal_conv1d(x3, w, bias, dilation=4, block_b=b)
    assert not np.allclose(y0[:, 15, :], y3[:, 15, :])


def test_conv_batch_tiling_invariance():
    """Grid/block decomposition must not affect results."""
    b, t, cin, cout = 8, 8, 3, 5
    x, w, bias = rng_arrays(7, (b, t, cin), (3, cin, cout), (cout,))
    full = dilated_causal_conv1d(x, w, bias, dilation=2, block_b=8)
    tiled = dilated_causal_conv1d(x, w, bias, dilation=2, block_b=2)
    np.testing.assert_allclose(full, tiled, rtol=1e-6)


def test_conv_vmem_budget():
    """Default AOT config must fit a TPU-core VMEM budget (16 MiB)."""
    assert conv_vmem(64, 16, 12, 32, 3, 4) < 1 << 20  # < 1 MiB
    assert conv_vmem(64, 16, 32, 32, 3, 4) < 1 << 20


# ---------------------------------------------------------------------------
# Fused dense
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8, 32]),
    cin=st.sampled_from([1, 12, 64]),
    cout=st.sampled_from([1, 16, 32]),
    act=st.sampled_from(["none", "relu", "sigmoid"]),
    seed=st.integers(0, 2**16),
)
def test_dense_matches_ref(b, cin, cout, act, seed):
    x, w, bias = rng_arrays(seed, (b, cin), (cin, cout), (cout,))
    got = dense(x, w, bias, activation=act, block_b=b)
    want = dense_ref(x, w, bias, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_dense_rejects_bad_activation():
    x, w, bias = rng_arrays(1, (2, 3), (3, 4), (4,))
    with pytest.raises(Exception):
        dense(x, w, bias, activation="tanh", block_b=2)


def test_dense_block_invariance():
    x, w, bias = rng_arrays(5, (128, 12), (12, 8), (8,))
    a = dense(x, w, bias, activation="relu", block_b=128)
    c = dense(x, w, bias, activation="relu", block_b=32)
    np.testing.assert_allclose(a, c, rtol=1e-6)


def test_dense_vmem_budget():
    assert dense_vmem(128, 512, 64) < 1 << 20


# ---------------------------------------------------------------------------
# Gradients flow through the kernels (interpret mode is differentiable)
# ---------------------------------------------------------------------------

def test_kernels_differentiable():
    x, w, bias = rng_arrays(2, (4, 8, 3), (3, 3, 6), (6,))

    def f(w, bias):
        return jnp.sum(dilated_causal_conv1d(x, w, bias, dilation=2, block_b=4) ** 2)

    g_w, g_b = jax.grad(f, argnums=(0, 1))(w, bias)
    assert g_w.shape == w.shape and g_b.shape == bias.shape
    assert float(jnp.abs(g_w).sum()) > 0.0

    def fref(w, bias):
        return jnp.sum(dilated_causal_conv1d_ref(x, w, bias, dilation=2) ** 2)

    gr_w, gr_b = jax.grad(fref, argnums=(0, 1))(w, bias)
    np.testing.assert_allclose(g_w, gr_w, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g_b, gr_b, rtol=1e-4, atol=1e-5)
