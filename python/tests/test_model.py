"""L2 model contracts: shapes, loss behaviour, Adam train step, zoo."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M


def make_batch(n, window=M.WINDOW, fdim=M.FEATURE_DIM, seed=0, temporal=True):
    """Synthetic learnable batch: label correlates with a feature pattern."""
    r = np.random.default_rng(seed)
    x = r.standard_normal((n, window, fdim)).astype(np.float32)
    # Temporal rule: label = 1 if feature-4 rises across the window.
    signal = x[:, -1, 4] - x[:, 0, 4]
    y = (signal > 0).astype(np.float32)
    if not temporal:
        x = x[:, -1, :]
    return jnp.asarray(x), jnp.asarray(y)


def test_param_specs_and_init():
    specs = M.tcn_param_specs()
    assert len(specs) == 10
    params = M.init_params(specs, seed=1)
    for p, (name, shape) in zip(params, specs):
        assert p.shape == shape, name
    # biases zero, weights non-trivial
    assert float(jnp.abs(params[1]).sum()) == 0.0
    assert float(jnp.abs(params[0]).sum()) > 0.0
    # deterministic
    params2 = M.init_params(specs, seed=1)
    np.testing.assert_allclose(params[0], params2[0])


def test_tcn_forward_shapes_and_range():
    params = M.init_params(M.tcn_param_specs(), seed=0)
    x, _ = make_batch(32)
    probs = M.tcn_infer(params, x)
    assert probs.shape == (32,)
    assert float(probs.min()) >= 0.0 and float(probs.max()) <= 1.0


def test_dnn_forward_shapes():
    params = M.init_params(M.dnn_param_specs(), seed=0)
    x, _ = make_batch(32, temporal=False)
    probs = M.dnn_infer(params, x)
    assert probs.shape == (32,)


def test_bce_sane():
    logits = jnp.asarray([10.0, -10.0])
    y = jnp.asarray([1.0, 0.0])
    assert float(M.bce_from_logits(logits, y)) < 1e-3
    y_bad = jnp.asarray([0.0, 1.0])
    assert float(M.bce_from_logits(logits, y_bad)) > 5.0
    # Chance-level at logit 0: ln 2.
    assert abs(float(M.bce_from_logits(jnp.zeros(4), jnp.asarray([0.0, 1.0, 0.0, 1.0]))) - 0.6931) < 1e-3


def test_dropout_deterministic_and_scaled():
    x = jnp.ones((64, 64))
    a = M.dropout(x, jnp.asarray(3.0))
    b = M.dropout(x, jnp.asarray(3.0))
    c = M.dropout(x, jnp.asarray(4.0))
    np.testing.assert_allclose(a, b)
    assert not np.allclose(a, c), "different steps → different masks"
    # E[output] ≈ E[input]
    assert abs(float(a.mean()) - 1.0) < 0.1
    kept = float((a > 0).mean())
    assert abs(kept - (1 - M.DROPOUT_P)) < 0.08


def test_train_step_decreases_loss_tcn():
    specs = M.tcn_param_specs()
    n = len(specs)
    params = M.init_params(specs, seed=0)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step_fn = jax.jit(M.make_train_step(M.tcn_forward, n))
    x, y = make_batch(128, seed=5)
    losses = []
    step = jnp.asarray(0.0)
    for _ in range(30):
        out = step_fn(*params, *m, *v, step, x, y)
        params = list(out[:n])
        m = list(out[n:2 * n])
        v = list(out[2 * n:3 * n])
        losses.append(float(out[3 * n]))
        step = step + 1.0
    assert losses[-1] < losses[0], f"no learning: {losses[0]:.4f} -> {losses[-1]:.4f}"


def test_eval_loss_matches_manual():
    specs = M.dnn_param_specs()
    params = M.init_params(specs, seed=2)
    x, y = make_batch(64, temporal=False, seed=9)
    ev = M.make_eval_loss(M.dnn_forward)
    (loss,) = ev(*params, x, y)
    manual = M.bce_from_logits(M.dnn_forward(params, x), y)
    np.testing.assert_allclose(loss, manual, rtol=1e-6)


def test_model_zoo_variants_run():
    zoo = M.model_zoo()
    assert set(zoo) == {"tcn", "tcn_flat", "tcn_short", "dnn"}
    for name, mdef in zoo.items():
        params = M.init_params(mdef["specs"], seed=0)
        if mdef["kind"] == "tcn":
            x = jnp.zeros((8, mdef["window"], mdef["feature_dim"]))
        else:
            x = jnp.zeros((8, mdef["feature_dim"]))
        probs = mdef["infer"](params, x)
        assert probs.shape == (8,), name


def test_tcn_beats_dnn_on_temporal_rule():
    """The structural claim behind Table 1: a temporal rule learnable by the
    TCN is invisible to the flattened-current-features DNN."""
    xt, y = make_batch(512, seed=13)
    xc = xt[:, -1, :]  # DNN sees only the current feature vector

    def train(forward, specs, x, steps=150):
        n = len(specs)
        params = M.init_params(specs, seed=0)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        fn = jax.jit(M.make_train_step(forward, n))
        s = jnp.asarray(0.0)
        loss = None
        for _ in range(steps):
            out = fn(*params, *m, *v, s, x, y)
            params, m, v = list(out[:n]), list(out[n:2 * n]), list(out[2 * n:3 * n])
            loss = float(out[3 * n])
            s = s + 1.0
        return loss

    tcn_loss = train(M.tcn_forward, M.tcn_param_specs(), xt)
    dnn_loss = train(M.dnn_forward, M.dnn_param_specs(), xc)
    assert tcn_loss < dnn_loss - 0.02, f"tcn {tcn_loss:.3f} vs dnn {dnn_loss:.3f}"
