//! Policy shoot-out across the whole zoo — every replacement policy in the
//! library on the same GPT-style trace, including the Belady upper bound,
//! run in parallel on the thread pool.
//!
//! ```bash
//! cargo run --release --example policy_comparison [accesses]
//! ```

use acpc::config::{ExperimentConfig, PredictorKind};
use acpc::predictor::{HeuristicPredictor, PredictorBox};
use acpc::sim::run_experiment;
use acpc::util::bench::print_table;
use acpc::util::pool::{default_threads, run_parallel};

fn main() {
    let accesses: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(500_000);

    let policies =
        ["random", "lru", "plru", "lip", "bip", "dip", "srrip", "brrip", "drrip", "ship",
         "mlpredict", "acpc", "belady"];

    let jobs: Vec<_> = policies
        .iter()
        .map(|&policy| {
            move || {
                let needs_pred = matches!(policy, "mlpredict" | "acpc");
                let kind =
                    if needs_pred { PredictorKind::Heuristic } else { PredictorKind::None };
                let mut cfg = ExperimentConfig::table1(policy, kind);
                cfg.accesses = accesses;
                let mut predictor = if needs_pred {
                    PredictorBox::Heuristic(HeuristicPredictor)
                } else {
                    PredictorBox::None
                };
                (policy, run_experiment(&cfg, &mut predictor))
            }
        })
        .collect();
    let results = run_parallel(default_threads(), jobs);

    let lru_report =
        results.iter().find(|(p, _)| *p == "lru").map(|(_, r)| r.report.clone()).unwrap();
    let mut rows: Vec<Vec<String>> = results
        .iter()
        .map(|(policy, r)| {
            vec![
                policy.to_string(),
                format!("{:.1}", r.report.l2_hit_rate * 100.0),
                format!("{:.2}", r.report.l2_pollution_ratio * 100.0),
                r.report
                    .miss_penalty_reduction_vs(&lru_report)
                    .map(|v| format!("{v:+.1}"))
                    .unwrap_or_else(|| "n/a".into()),
                format!("{:.2}", r.report.amat),
                format!("{:.2}", r.emu),
                format!("{:.2}M", r.accesses_per_sec / 1e6),
            ]
        })
        .collect();
    rows.sort_by(|a, b| b[1].parse::<f64>().unwrap().total_cmp(&a[1].parse::<f64>().unwrap()));
    print_table(
        "All policies, GPT-style trace",
        &["policy", "CHR %", "PPR %", "MPR vs LRU %", "AMAT", "EMU", "sim acc/s"],
        &rows,
    );
    println!("\n(belady is the clairvoyant upper bound; mlpredict/acpc use the heuristic predictor here)");
}
