//! Online adaptation (§3.4): the workload's Zipf head rotates mid-run
//! ("phase drift"), and we compare ACPC+TCN with the online feedback loop
//! ON vs OFF. With feedback, the predictor retrains on observed reuse
//! outcomes (replay buffer + compiled Adam steps from rust) and recovers;
//! without it, predictions go stale.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example online_adaptation
//! ```

use acpc::config::{ExperimentConfig, PredictorKind};
use acpc::predictor::{Dataset, GeometryHints, ModelRuntime, PredictorBox};
use acpc::runtime::{Engine, Manifest};
use acpc::sim::run_experiment;
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::training::{train, TrainConfig};

fn main() {
    let Some(dir) = acpc::runtime::artifacts_dir() else {
        eprintln!("online_adaptation: run `make artifacts` first");
        std::process::exit(1);
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let engine = Engine::cpu().expect("engine");
    let window = manifest.model("tcn").expect("tcn").window;
    let seed = 0xADA7;

    // Pre-train on a *stationary* trace (no phase drift).
    println!("[1/3] pre-training TCN on a drift-free trace ...");
    let mut gcfg = GeneratorConfig::new(ModelProfile::gpt3ish(), seed);
    gcfg.phase_period = 0; // stationary
    let geom = GeometryHints::from_generator(&gcfg);
    let trace = TraceGenerator::new(gcfg).generate(400_000);
    let ds = Dataset::build(&trace, window, geom, 4096, 6);
    let split = ds.split(seed);
    let mut pretrained = ModelRuntime::load(&engine, &manifest, "tcn").expect("tcn");
    let res = train(
        &mut pretrained,
        &ds,
        &split,
        &TrainConfig { epochs: 10, patience: 0, max_batches_per_epoch: 40, seed, verbose_every: 0 },
    );
    println!("      pre-trained loss: {:.3}", res.final_train_loss);
    let ckpt = std::env::temp_dir().join("acpc_online_adapt.ckpt");
    pretrained.store.save_checkpoint(&ckpt).expect("ckpt");

    // Evaluation trace WITH aggressive phase drift.
    let mk_cfg = |feedback: usize| {
        let mut cfg = ExperimentConfig::table1("acpc", PredictorKind::Tcn);
        cfg.accesses = 600_000;
        cfg.generator.phase_period = 1_500; // rotate the hot set frequently
        cfg.feedback_interval = feedback;
        cfg.name = format!("drift-feedback{feedback}");
        cfg
    };
    let load = |engine: &Engine| {
        let mut rt = ModelRuntime::load(engine, &manifest, "tcn").expect("tcn");
        rt.store.load_checkpoint(&ckpt).expect("load");
        rt
    };

    println!("[2/3] drifting workload, feedback OFF ...");
    let mut frozen = PredictorBox::Model(Box::new(load(&engine)));
    let off = run_experiment(&mk_cfg(0), &mut frozen);

    println!("[3/3] drifting workload, feedback ON (retrain every 50k accesses) ...");
    let mut adaptive = PredictorBox::Model(Box::new(load(&engine)));
    let on = run_experiment(&mk_cfg(50_000), &mut adaptive);

    println!("\n== online adaptation under phase drift ==");
    println!("  feedback OFF: {} (online steps: {})", off.report.summary(), off.online_train_steps);
    println!("  feedback ON : {} (online steps: {})", on.report.summary(), on.online_train_steps);
    println!(
        "\nadaptation gain: CHR {:+.2} pp, pollution {:+.1}%",
        (on.report.l2_hit_rate - off.report.l2_hit_rate) * 100.0,
        (on.report.l2_pollution_ratio / off.report.l2_pollution_ratio - 1.0) * 100.0
    );
    std::fs::remove_file(ckpt).ok();
}
