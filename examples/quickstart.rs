//! Quickstart: the smallest end-to-end ACPC run.
//!
//! Generates a GPT-style inference trace, simulates the L2 under plain LRU
//! and under ACPC (heuristic predictor — no artifacts needed), and prints
//! the paper's core comparison: hit rate up, pollution down.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use acpc::config::{ExperimentConfig, PredictorKind};
use acpc::predictor::{HeuristicPredictor, PredictorBox};
use acpc::sim::run_experiment;

fn main() {
    let accesses = 400_000;

    // 1. Baseline: LRU, no learned guidance.
    let mut lru_cfg = ExperimentConfig::table1("lru", PredictorKind::None);
    lru_cfg.accesses = accesses;
    let lru = run_experiment(&lru_cfg, &mut PredictorBox::None);

    // 2. ACPC: priority-aware replacement + prefetch filtering, driven by a
    //    reuse predictor (the built-in heuristic here; swap in the trained
    //    TCN with `PredictorKind::Tcn` once `make artifacts` has run).
    let mut acpc_cfg = ExperimentConfig::table1("acpc", PredictorKind::Heuristic);
    acpc_cfg.accesses = accesses;
    let mut predictor = PredictorBox::Heuristic(HeuristicPredictor);
    let acpc = run_experiment(&acpc_cfg, &mut predictor);

    println!("workload: {} accesses, {} tokens decoded", accesses, acpc.tokens);
    println!("  LRU : {}", lru.report.summary());
    println!("  ACPC: {}", acpc.report.summary());
    println!(
        "\nACPC vs LRU: hit rate {:+.1} pp, pollution {:+.1}%, AMAT {:+.1}%",
        (acpc.report.l2_hit_rate - lru.report.l2_hit_rate) * 100.0,
        (acpc.report.l2_pollution_ratio / lru.report.l2_pollution_ratio - 1.0) * 100.0,
        (acpc.report.amat / lru.report.amat - 1.0) * 100.0,
    );
    assert!(acpc.report.l2_hit_rate > lru.report.l2_hit_rate, "ACPC should win");
}
