//! # ACPC — Adaptive Cache Pollution Control for LLM Inference Workloads
//!
//! Production-style reproduction of Liu, Du & Wang (CS.AR 2025): a Temporal
//! Convolutional Network predicts per-line reuse from LLM-inference access
//! sequences, and a Priority-Aware Replacement Module (PARM) turns those
//! predictions into eviction/insertion priorities that suppress prefetch
//! pollution.
//!
//! The crate is the L3 (rust) layer of a three-layer stack:
//!
//! - **L1 (Pallas kernels)** and **L2 (JAX model)** live in `python/compile/`
//!   and are AOT-lowered once into `artifacts/*.hlo.txt`;
//! - this crate loads those artifacts via PJRT ([`runtime`]) and runs the
//!   *entire* evaluation substrate natively: trace synthesis ([`trace`]),
//!   a multi-level cache simulator ([`mem`]), replacement policies
//!   ([`policy`]), the feature/label pipeline ([`predictor`]), Rust-driven
//!   training of the compiled model ([`training`]), a serving-style
//!   coordinator ([`coordinator`]), a population-scale traffic layer with
//!   open-loop arrivals and capture/replay ([`traffic`]), and the paper's
//!   metrics ([`metrics`]).
//!
//! Python never executes on the simulation/serving path.
//!
//! **Run API:** every experiment goes through one front door — build a
//! serializable [`api::RunSpec`], hand it to an [`api::Runner`], get a
//! versioned [`api::RunReport`] whose embedded resolved spec reproduces
//! the run bit-for-bit. See the [`api`] module docs and the README's
//! "Library API" section.

pub mod adapt;
pub mod api;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod mem;
pub mod metrics;
pub mod obs;
pub mod policy;
pub mod predictor;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod traffic;
pub mod training;
pub mod util;
