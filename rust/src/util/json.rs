//! Minimal JSON parser and writer.
//!
//! The offline registry on this image carries no `serde` facade crate, so the
//! project ships its own small JSON implementation. It supports the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, bools, null) and
//! is used for: the AOT artifact manifest (`artifacts/manifest.json`),
//! experiment config files, and machine-readable reports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a BTreeMap for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn array_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn array_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn array_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error mentioning the key — manifest parsing
    /// wants hard failures on missing fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { pos: 0, msg: format!("missing key '{key}'") })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    /// Array of numbers → Vec<usize>, with a descriptive error.
    pub fn usize_array(&self, key: &str) -> Result<Vec<usize>, JsonError> {
        let arr = self
            .req(key)?
            .as_arr()
            .ok_or_else(|| JsonError { pos: 0, msg: format!("'{key}' is not an array") })?;
        arr.iter()
            .map(|v| {
                v.as_usize().ok_or_else(|| JsonError { pos: 0, msg: format!("'{key}' has non-numeric element") })
            })
            .collect()
    }

    // ---- parse ----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- write ----------------------------------------------------------
    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity tokens; emit null so the
                    // output stays parseable (NaN = "undefined" metrics,
                    // e.g. MPR against a degenerate baseline, EMU on runs
                    // too short to sample).
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = fmt::Write::write_fmt(out, format_args!("{}", *x as i64));
                } else {
                    let _ = fmt::Write::write_fmt(out, format_args!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        // Surrogate pairs: combine if a high surrogate is followed by \uXXXX low.
                        if (0xD800..0xDC00).contains(&code)
                            && self.bytes[self.pos..].starts_with(b"\\u")
                        {
                            self.pos += 2;
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                                low = low * 16
                                    + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) => {
                    // Collect the full UTF-8 sequence starting at b.
                    let width = match b {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..width {
                        self.bump();
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.25", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": true}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(true)));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé😀""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé😀"));
        // Raw multi-byte passthrough.
        let v2 = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v2.as_str(), Some("héllo"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::from_pairs(vec![
            ("name", Json::Str("tcn".into())),
            ("shape", Json::array_usize(&[256, 32, 12])),
            ("lr", Json::Num(1e-4)),
        ]);
        let pretty = v.to_pretty();
        assert!(pretty.contains("\"name\": \"tcn\""));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn escaping_control_chars() {
        let v = Json::Str("a\"b\\c\nd\u{0001}".to_string());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn req_and_usize_array() {
        let v = Json::parse(r#"{"dims": [2, 3, 4]}"#).unwrap();
        assert_eq!(v.usize_array("dims").unwrap(), vec![2, 3, 4]);
        assert!(v.req("missing").is_err());
        assert!(v.usize_array("missing").is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
        // Round-trips as a parseable document.
        let doc = Json::from_pairs(vec![("mpr", Json::Num(f64::NAN))]);
        assert!(Json::parse(&doc.to_pretty()).is_ok());
    }
}
