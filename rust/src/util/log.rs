//! Leveled stderr logger with an env filter (`ACPC_LOG=debug|info|warn|error`,
//! default `info`). Timestamps are monotonic seconds since process start so
//! logs are diffable across runs.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

/// Initialize level from `ACPC_LOG`; idempotent, cheap to call anywhere.
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("ACPC_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    }
}

pub fn set_level(lvl: Level) {
    START.get_or_init(Instant::now);
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:9.3} {tag}] {args}");
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}
