//! Scoped thread pool for parameter sweeps (no tokio/rayon in the offline
//! registry). Work items are closures producing a value; `run_parallel`
//! fans them out over `nthreads` OS threads and returns results in input
//! order. Built on `std::thread::scope`, so borrowed data works.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Execute `jobs` on up to `nthreads` threads; returns outputs in order.
pub fn run_parallel<T, F>(nthreads: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let nthreads = nthreads.max(1).min(n);
    if nthreads == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }

    // Jobs behind a mutex-protected queue of (index, job); results into slots.
    let queue: Mutex<Vec<(usize, F)>> = Mutex::new(jobs.into_iter().enumerate().rev().collect());
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let active = AtomicUsize::new(0);

    std::thread::scope(|s| {
        for _ in 0..nthreads {
            s.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((idx, f)) => {
                        active.fetch_add(1, Ordering::Relaxed);
                        let out = f();
                        *results[idx].lock().unwrap() = Some(out);
                        active.fetch_sub(1, Ordering::Relaxed);
                    }
                    None => break,
                }
            });
        }
    });

    results.into_iter().map(|m| m.into_inner().unwrap().expect("job did not complete")).collect()
}

/// Number of worker threads to use by default: physical parallelism minus
/// one (leave a core for the coordinator), at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..64usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = run_parallel(8, jobs);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn borrows_from_scope() {
        let data: Vec<u64> = (0..100).collect();
        let jobs: Vec<_> = (0..10)
            .map(|i| {
                let slice = &data[i * 10..(i + 1) * 10];
                move || slice.iter().sum::<u64>()
            })
            .collect();
        let out = run_parallel(4, jobs);
        assert_eq!(out.iter().sum::<u64>(), (0..100).sum::<u64>());
    }

    #[test]
    fn single_thread_and_empty() {
        let out: Vec<u32> = run_parallel(4, Vec::<fn() -> u32>::new());
        assert!(out.is_empty());
        let out = run_parallel(1, vec![|| 7u32]);
        assert_eq!(out, vec![7]);
    }
}
