//! Self-contained utility substrate: RNG, stats, JSON, logging, thread pool,
//! bench timing, and a property-test harness. The offline crate registry on
//! this image lacks `rand`/`serde`/`criterion`/`proptest`/`tokio`, so these
//! are first-class parts of the library rather than dev conveniences.

pub mod bench;
pub mod hash;
pub mod json;
pub mod log;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod spsc;
pub mod stats;
