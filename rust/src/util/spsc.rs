//! Bounded single-producer/single-consumer ring for the sharded simulator's
//! access distribution path.
//!
//! The offline registry carries no `crossbeam`, and `std::sync::mpsc` takes
//! a lock per send under contention, so the shard splitter ships access
//! chunks through this minimal lock-free ring instead: one atomic store per
//! push and one per pop, wait-free on both sides except when the ring is
//! full/empty (the caller spins with `yield_now`). The SPSC discipline is
//! enforced by the type system — [`channel`] hands out exactly one
//! [`Producer`] and one [`Consumer`], neither of which is `Clone`.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

struct Ring<T> {
    slots: Box<[UnsafeCell<Option<T>>]>,
    /// Next slot the consumer will read (monotone; slot = head % cap).
    head: AtomicU64,
    /// Next slot the producer will write (monotone; slot = tail % cap).
    tail: AtomicU64,
    closed: AtomicBool,
    /// Consumer handle dropped (normally or by a panicking thread). A
    /// blocking push must not spin forever on a full ring nobody will ever
    /// drain — it discards instead, so a panicked shard worker surfaces as
    /// a join error rather than a producer livelock.
    receiver_gone: AtomicBool,
}

/// Escalating wait: stay on `yield_now` for a while (fast path when the
/// peer is merely behind), then back off to short sleeps so starved sides
/// of an oversubscribed run stop burning whole cores.
fn backoff(spins: &mut u32) {
    *spins = spins.saturating_add(1);
    if *spins < 64 {
        std::thread::yield_now();
    } else {
        std::thread::sleep(std::time::Duration::from_micros(50));
    }
}

// The producer only writes slots in [tail, head+cap) and the consumer only
// reads slots in [head, tail); the acquire/release pair on `tail` (push →
// pop) and `head` (pop → push) orders the slot contents between the two
// threads. Safe *only* under the one-producer/one-consumer discipline the
// public handles enforce.
unsafe impl<T: Send> Sync for Ring<T> {}
unsafe impl<T: Send> Send for Ring<T> {}

/// Producer handle: push values, then [`Producer::close`] (or drop) to let
/// the consumer drain and terminate.
pub struct Producer<T> {
    ring: Arc<Ring<T>>,
}

/// Consumer handle: pop until [`Consumer::pop`] returns `None` *and*
/// [`Consumer::is_closed`] — an empty ring alone may just mean the producer
/// is momentarily behind.
pub struct Consumer<T> {
    ring: Arc<Ring<T>>,
}

/// Build a bounded SPSC channel with room for `capacity` in-flight values.
pub fn channel<T: Send>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    let capacity = capacity.max(1);
    let slots: Box<[UnsafeCell<Option<T>>]> =
        (0..capacity).map(|_| UnsafeCell::new(None)).collect();
    let ring = Arc::new(Ring {
        slots,
        head: AtomicU64::new(0),
        tail: AtomicU64::new(0),
        closed: AtomicBool::new(false),
        receiver_gone: AtomicBool::new(false),
    });
    (Producer { ring: Arc::clone(&ring) }, Consumer { ring })
}

impl<T: Send> Producer<T> {
    /// Non-blocking push; returns the value back when the ring is full.
    pub fn try_push(&mut self, v: T) -> Result<(), T> {
        let ring = &*self.ring;
        let tail = ring.tail.load(Ordering::Relaxed);
        let head = ring.head.load(Ordering::Acquire);
        if tail - head >= ring.slots.len() as u64 {
            return Err(v);
        }
        let slot = (tail % ring.slots.len() as u64) as usize;
        // SAFETY: slot index is in (head+cap)-exclusive producer territory;
        // the consumer will not touch it until tail is published below.
        unsafe {
            *ring.slots[slot].get() = Some(v);
        }
        ring.tail.store(tail + 1, Ordering::Release);
        Ok(())
    }

    /// Blocking push: waits (escalating backoff) while the ring is full.
    /// If the consumer is gone — dropped normally or unwound by a panic —
    /// the value is *discarded* instead of blocking forever: the stream has
    /// no reader, and the caller's join of the consumer thread reports why.
    pub fn push(&mut self, mut v: T) {
        let mut spins = 0u32;
        loop {
            if self.ring.receiver_gone.load(Ordering::Acquire) {
                return;
            }
            match self.try_push(v) {
                Ok(()) => return,
                Err(back) => {
                    v = back;
                    backoff(&mut spins);
                }
            }
        }
    }

    /// Signal end-of-stream. Also performed on drop.
    pub fn close(&mut self) {
        self.ring.closed.store(true, Ordering::Release);
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.ring.closed.store(true, Ordering::Release);
    }
}

impl<T: Send> Consumer<T> {
    /// Non-blocking pop; `None` when the ring is momentarily empty.
    pub fn try_pop(&mut self) -> Option<T> {
        let ring = &*self.ring;
        let head = ring.head.load(Ordering::Relaxed);
        let tail = ring.tail.load(Ordering::Acquire);
        if tail == head {
            return None;
        }
        let slot = (head % ring.slots.len() as u64) as usize;
        // SAFETY: slot is in [head, tail) consumer territory; the producer
        // will not reuse it until head is published below.
        let v = unsafe { (*ring.slots[slot].get()).take() };
        ring.head.store(head + 1, Ordering::Release);
        v
    }

    /// Blocking pop: waits (escalating backoff) while the ring is empty;
    /// `None` only after the producer closed *and* the ring fully drained.
    pub fn pop(&mut self) -> Option<T> {
        let mut spins = 0u32;
        loop {
            if let Some(v) = self.try_pop() {
                return Some(v);
            }
            if self.is_closed() {
                // Re-check: the producer may have pushed between the empty
                // try_pop and the closed read.
                return self.try_pop();
            }
            backoff(&mut spins);
        }
    }

    pub fn is_closed(&self) -> bool {
        self.ring.closed.load(Ordering::Acquire)
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        self.ring.receiver_gone.store(true, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_capacity() {
        let (mut tx, mut rx) = channel::<u32>(4);
        for i in 0..4 {
            tx.try_push(i).unwrap();
        }
        assert!(tx.try_push(99).is_err(), "full ring rejects");
        for i in 0..4 {
            assert_eq!(rx.try_pop(), Some(i));
        }
        assert_eq!(rx.try_pop(), None);
        // Space freed: push works again (indices keep counting up).
        tx.try_push(7).unwrap();
        assert_eq!(rx.try_pop(), Some(7));
    }

    #[test]
    fn close_terminates_consumer() {
        let (mut tx, mut rx) = channel::<u8>(2);
        tx.push(1);
        tx.close();
        assert_eq!(rx.pop(), Some(1));
        assert_eq!(rx.pop(), None, "closed + drained");
    }

    #[test]
    fn cross_thread_stream_is_lossless_and_ordered() {
        let n = 200_000u64;
        let (mut tx, mut rx) = channel::<u64>(64);
        std::thread::scope(|s| {
            s.spawn(move || {
                for i in 0..n {
                    tx.push(i);
                }
                // Producer drop closes the ring.
            });
            let mut expect = 0u64;
            while let Some(v) = rx.pop() {
                assert_eq!(v, expect);
                expect += 1;
            }
            assert_eq!(expect, n);
        });
    }

    #[test]
    fn drop_of_producer_closes() {
        let (tx, mut rx) = channel::<u8>(2);
        drop(tx);
        assert!(rx.is_closed());
        assert_eq!(rx.pop(), None);
    }

    /// A dead consumer (e.g. a panicked shard worker) must not deadlock the
    /// producer: blocking pushes discard instead of spinning forever.
    #[test]
    fn push_does_not_block_after_consumer_drop() {
        let (mut tx, rx) = channel::<u32>(1);
        tx.push(1); // ring now full
        drop(rx);
        // Would spin forever without the receiver_gone check.
        tx.push(2);
        tx.push(3);
    }
}
