//! Bench timing harness. `criterion` is not present in the offline registry,
//! so `cargo bench` targets (declared `harness = false`) use this module:
//! warmup + repeated timed runs, reporting mean ± 95% CI, min, and throughput.

use super::stats::Welford;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub ci95_ns: f64,
    pub min_ns: f64,
    /// items/sec if `items_per_iter` was set.
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let t = fmt_ns(self.mean_ns);
        let ci = fmt_ns(self.ci95_ns);
        let min = fmt_ns(self.min_ns);
        match self.throughput {
            Some(tp) => format!(
                "{:<44} {:>12}/iter ±{:>9} (min {:>9}) {:>14.0} items/s",
                self.name, t, ci, min, tp
            ),
            None => format!("{:<44} {:>12}/iter ±{:>9} (min {:>9})", self.name, t, ci, min),
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner: fixed warmup iterations then `iters` timed iterations.
pub struct Bench {
    pub warmup: u64,
    pub iters: u64,
    pub items_per_iter: Option<u64>,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 3, iters: 10, items_per_iter: None }
    }
}

impl Bench {
    pub fn new(warmup: u64, iters: u64) -> Self {
        Self { warmup, iters, items_per_iter: None }
    }

    pub fn throughput(mut self, items: u64) -> Self {
        self.items_per_iter = Some(items);
        self
    }

    /// Time `f`, which should perform one full iteration of the workload.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut w = Welford::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            w.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ns: w.mean(),
            ci95_ns: w.ci95(),
            min_ns: w.min(),
            throughput: self.items_per_iter.map(|n| n as f64 / (w.mean() / 1e9)),
        };
        println!("{}", res.report());
        res
    }
}

/// Prevent the optimizer from discarding a computed value
/// (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render a markdown-ish table: `header` then rows; used by the table1 and
/// ablation benches to print paper-style tables.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line: Vec<String> =
        header.iter().zip(&widths).map(|(h, w)| format!("{h:<w$}", w = w)).collect();
    println!("| {} |", line.join(" | "));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    println!("|-{}-|", sep.join("-|-"));
    for row in rows {
        let cells: Vec<String> =
            row.iter().zip(&widths).map(|(c, w)| format!("{c:<w$}", w = w)).collect();
        println!("| {} |", cells.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_reports() {
        let b = Bench::new(1, 5).throughput(1000);
        let mut acc = 0u64;
        let res = b.run("noop-ish", || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(res.mean_ns > 0.0);
        assert!(res.throughput.unwrap() > 0.0);
        assert_eq!(res.iters, 5);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(5e2).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e10).contains('s'));
    }
}
