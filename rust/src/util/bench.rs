//! Bench timing harness. `criterion` is not present in the offline registry,
//! so `cargo bench` targets (declared `harness = false`) use this module:
//! warmup + repeated timed runs, reporting mean ± 95% CI, min, and throughput.
//!
//! Benches that feed the repo's perf trajectory additionally record their
//! results through [`BenchJson`], which appends them to a machine-readable
//! `BENCH_sim.json` history (schema `acpc-bench-v2`) so the committed
//! trajectory accumulates accesses/second and shard-scaling curves across
//! commits, and `acpc diff --bench` can gate regressions against it.

use super::json::Json;
use super::stats::Welford;
use std::path::PathBuf;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub ci95_ns: f64,
    pub min_ns: f64,
    /// items/sec if `items_per_iter` was set.
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("ci95_ns", Json::Num(self.ci95_ns)),
            ("min_ns", Json::Num(self.min_ns)),
        ];
        if let Some(tp) = self.throughput {
            pairs.push(("items_per_sec", Json::Num(tp)));
        }
        Json::from_pairs(pairs)
    }

    pub fn report(&self) -> String {
        let t = fmt_ns(self.mean_ns);
        let ci = fmt_ns(self.ci95_ns);
        let min = fmt_ns(self.min_ns);
        match self.throughput {
            Some(tp) => format!(
                "{:<44} {:>12}/iter ±{:>9} (min {:>9}) {:>14.0} items/s",
                self.name, t, ci, min, tp
            ),
            None => format!("{:<44} {:>12}/iter ±{:>9} (min {:>9})", self.name, t, ci, min),
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner: fixed warmup iterations then `iters` timed iterations.
pub struct Bench {
    pub warmup: u64,
    pub iters: u64,
    pub items_per_iter: Option<u64>,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 3, iters: 10, items_per_iter: None }
    }
}

impl Bench {
    pub fn new(warmup: u64, iters: u64) -> Self {
        Self { warmup, iters, items_per_iter: None }
    }

    pub fn throughput(mut self, items: u64) -> Self {
        self.items_per_iter = Some(items);
        self
    }

    /// Time `f`, which should perform one full iteration of the workload.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut w = Welford::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            w.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_ns: w.mean(),
            ci95_ns: w.ci95(),
            min_ns: w.min(),
            throughput: self.items_per_iter.map(|n| n as f64 / (w.mean() / 1e9)),
        };
        println!("{}", res.report());
        res
    }
}

/// Bench scale selector: `ACPC_BENCH_SCALE=smoke` shrinks workloads for CI
/// smoke runs; anything else (or unset) is the full scale.
pub fn bench_scale() -> &'static str {
    match std::env::var("ACPC_BENCH_SCALE").as_deref() {
        Ok("smoke") => "smoke",
        _ => "full",
    }
}

/// Trajectory schema identifier (snapshot history).
pub const BENCH_SCHEMA: &str = "acpc-bench-v2";
/// Oldest snapshots are dropped past this bound.
const SNAPSHOT_CAP: usize = 50;

/// Machine-readable perf-trajectory sink: collects one bench binary's
/// results plus arbitrary extra series (e.g. a shard-scaling curve) and
/// appends them to the `BENCH_sim.json` **history**:
///
/// ```json
/// {
///   "schema": "acpc-bench-v2",
///   "snapshots": [
///     { "id": "<run id>", "scale": "full|smoke",
///       "benches": {
///         "<bench>": { "results": [{"name", "iters", "mean_ns", "ci95_ns",
///                                   "min_ns", "items_per_sec"?}, ...],
///                      ...extra keys... }
///       } },
///     ...
///   ]
/// }
/// ```
///
/// The run id comes from `$ACPC_BENCH_RUN_ID` (CI sets the commit SHA;
/// default `"local"`). Consecutive writes under the same id + scale merge
/// their bench sections into one snapshot — running the whole bench suite
/// produces a single trajectory point — while a new id appends a snapshot,
/// preserving history (capped at the [`SNAPSHOT_CAP`] most recent). Files
/// in the retired `acpc-bench-v1` layout are migrated as one `"legacy"`
/// snapshot. The file path is `$ACPC_BENCH_JSON` or `BENCH_sim.json` in
/// the working directory.
pub struct BenchJson {
    bench: String,
    run_id: String,
    results: Vec<Json>,
    extra: Vec<(String, Json)>,
}

impl BenchJson {
    pub fn new(bench: &str) -> Self {
        let run_id = std::env::var("ACPC_BENCH_RUN_ID").unwrap_or_else(|_| "local".to_string());
        Self { bench: bench.to_string(), run_id, results: Vec::new(), extra: Vec::new() }
    }

    /// Override the snapshot id (tests; avoids racing on the env var).
    pub fn with_run_id(mut self, id: &str) -> Self {
        self.run_id = id.to_string();
        self
    }

    /// Record one timed case.
    pub fn push(&mut self, r: &BenchResult) {
        self.results.push(r.to_json());
    }

    /// Attach an extra series/value under the bench's section.
    pub fn set(&mut self, key: &str, value: Json) {
        self.extra.push((key.to_string(), value));
    }

    /// Resolved output path.
    pub fn path() -> PathBuf {
        std::env::var("ACPC_BENCH_JSON").map(PathBuf::from).unwrap_or_else(|_| {
            PathBuf::from("BENCH_sim.json")
        })
    }

    /// Merge this bench's section into the trajectory file and write it.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = Self::path();
        self.write_to(&path)?;
        Ok(path)
    }

    /// [`write`](Self::write) to an explicit path (tests / custom sinks).
    pub fn write_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        // Start from the existing history when it parses; a corrupt or
        // absent file restarts the trajectory.
        let existing = std::fs::read_to_string(path).ok().and_then(|t| Json::parse(&t).ok());
        let mut snapshots: Vec<Json> = match &existing {
            Some(j) if j.get("schema").and_then(|s| s.as_str()) == Some(BENCH_SCHEMA) => {
                j.get("snapshots")
                    .and_then(|s| s.as_arr())
                    .map(<[Json]>::to_vec)
                    .unwrap_or_default()
            }
            // v1 files carried a single un-versioned point under "benches";
            // carry it over so the history survives the schema bump.
            Some(j) if j.get("benches").and_then(|b| b.as_obj()).is_some() => {
                let benches = j.get("benches").cloned().unwrap_or_else(Json::obj);
                let scale = benches
                    .as_obj()
                    .and_then(|m| m.values().next())
                    .and_then(|sec| sec.get("scale"))
                    .and_then(|s| s.as_str())
                    .unwrap_or("full")
                    .to_string();
                vec![Json::from_pairs(vec![
                    ("id", Json::Str("legacy".into())),
                    ("scale", Json::Str(scale)),
                    ("benches", benches),
                ])]
            }
            _ => Vec::new(),
        };

        let mut section = Json::from_pairs(vec![("results", Json::Arr(self.results.clone()))]);
        for (k, v) in &self.extra {
            section.set(k, v.clone());
        }

        let scale = bench_scale();
        let merge_into_last = snapshots.last().is_some_and(|s| {
            s.get("id").and_then(|v| v.as_str()) == Some(self.run_id.as_str())
                && s.get("scale").and_then(|v| v.as_str()) == Some(scale)
        });
        if merge_into_last {
            let last = snapshots.last_mut().unwrap();
            let mut benches = last.get("benches").cloned().unwrap_or_else(Json::obj);
            benches.set(&self.bench, section);
            last.set("benches", benches);
        } else {
            let mut benches = Json::obj();
            benches.set(&self.bench, section);
            snapshots.push(Json::from_pairs(vec![
                ("id", Json::Str(self.run_id.clone())),
                ("scale", Json::Str(scale.into())),
                ("benches", benches),
            ]));
        }
        if snapshots.len() > SNAPSHOT_CAP {
            let excess = snapshots.len() - SNAPSHOT_CAP;
            snapshots.drain(..excess);
        }

        let mut root = Json::obj();
        root.set("schema", Json::Str(BENCH_SCHEMA.into()));
        root.set("snapshots", Json::Arr(snapshots));
        std::fs::write(path, root.to_pretty())
    }
}

/// The most recent snapshot of a parsed trajectory file (`acpc diff
/// --bench` compares these between two histories).
pub fn latest_snapshot(root: &Json) -> Option<&Json> {
    root.get("snapshots")?.as_arr()?.last()
}

/// Prevent the optimizer from discarding a computed value
/// (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render a markdown-ish table: `header` then rows; used by the table1 and
/// ablation benches to print paper-style tables.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line: Vec<String> =
        header.iter().zip(&widths).map(|(h, w)| format!("{h:<w$}", w = w)).collect();
    println!("| {} |", line.join(" | "));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    println!("|-{}-|", sep.join("-|-"));
    for row in rows {
        let cells: Vec<String> =
            row.iter().zip(&widths).map(|(c, w)| format!("{c:<w$}", w = w)).collect();
        println!("| {} |", cells.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_reports() {
        let b = Bench::new(1, 5).throughput(1000);
        let mut acc = 0u64;
        let res = b.run("noop-ish", || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(res.mean_ns > 0.0);
        assert!(res.throughput.unwrap() > 0.0);
        assert_eq!(res.iters, 5);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(5e2).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e10).contains('s'));
    }

    fn case(name: &str, mean_ns: f64) -> BenchResult {
        BenchResult {
            name: name.into(),
            iters: 5,
            mean_ns,
            ci95_ns: 10.0,
            min_ns: mean_ns * 0.9,
            throughput: Some(1e6),
        }
    }

    /// Benches writing under one run id share a snapshot; a new run id
    /// appends a snapshot, and a same-id rewrite replaces (not duplicates)
    /// the bench's section.
    #[test]
    fn bench_json_snapshots_merge_and_append() {
        let dir = std::env::temp_dir().join("acpc_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_sim.json");
        let _ = std::fs::remove_file(&path);

        let r = case("case_a", 1000.0);
        let mut a = BenchJson::new("alpha").with_run_id("run1");
        a.push(&r);
        a.set("extra_curve", Json::array_f64(&[1.0, 2.0]));
        a.write_to(&path).unwrap();

        let mut b = BenchJson::new("beta").with_run_id("run1");
        b.push(&r);
        b.write_to(&path).unwrap();

        // Re-run alpha under the same id: replaces its section in place.
        a.write_to(&path).unwrap();

        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(BENCH_SCHEMA));
        let snaps = j.get("snapshots").unwrap().as_arr().unwrap();
        assert_eq!(snaps.len(), 1, "one id + one scale = one snapshot");
        let benches = snaps[0].get("benches").unwrap();
        for name in ["alpha", "beta"] {
            let sec = benches.get(name).unwrap_or_else(|| panic!("missing {name}"));
            let results = sec.get("results").unwrap().as_arr().unwrap();
            assert_eq!(results.len(), 1);
            assert_eq!(results[0].get("name").unwrap().as_str(), Some("case_a"));
            assert!(results[0].get("items_per_sec").unwrap().as_f64().unwrap() > 0.0);
        }
        assert!(benches.get("alpha").unwrap().get("extra_curve").is_some());

        // A second run id appends a new trajectory point.
        let mut a2 = BenchJson::new("alpha").with_run_id("run2");
        a2.push(&case("case_a", 1200.0));
        a2.write_to(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let snaps = j.get("snapshots").unwrap().as_arr().unwrap();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[1].get("id").unwrap().as_str(), Some("run2"));
        let latest = latest_snapshot(&j).unwrap();
        assert_eq!(latest.get("id").unwrap().as_str(), Some("run2"));
        let _ = std::fs::remove_file(&path);
    }

    /// A v1 trajectory file is migrated into the history as a "legacy"
    /// snapshot rather than discarded.
    #[test]
    fn bench_json_migrates_v1_files() {
        let dir = std::env::temp_dir().join("acpc_bench_json_v1_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_sim.json");
        std::fs::write(
            &path,
            r#"{"schema": "acpc-bench-v1",
                "benches": {"alpha": {"scale": "smoke", "results": []}}}"#,
        )
        .unwrap();

        let mut b = BenchJson::new("beta").with_run_id("run1");
        b.push(&case("case_b", 500.0));
        b.write_to(&path).unwrap();

        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let snaps = j.get("snapshots").unwrap().as_arr().unwrap();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].get("id").unwrap().as_str(), Some("legacy"));
        assert_eq!(snaps[0].get("scale").unwrap().as_str(), Some("smoke"));
        assert!(snaps[0].get("benches").unwrap().get("alpha").is_some());
        assert!(snaps[1].get("benches").unwrap().get("beta").is_some());
        let _ = std::fs::remove_file(&path);
    }
}
