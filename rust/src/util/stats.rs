//! Small statistics toolkit used by the metrics layer and the bench harness:
//! streaming moments (Welford), percentiles, exponentially-weighted moving
//! averages, fixed-bucket histograms and timing summaries.

/// Streaming mean/variance via Welford's algorithm; O(1) memory.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of the ~95% normal confidence interval on the mean.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return f64::NAN;
        }
        1.96 * self.stddev() / (self.n as f64).sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentile over a stored sample (sorts a copy on query).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Exponentially weighted moving average; `alpha` is the weight of the new
/// observation. Used for the occupancy/frequency signals in the PARM policy.
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    alpha: f64,
    value: f64,
    primed: bool,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: 0.0, primed: false }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        if self.primed {
            self.value = self.alpha * x + (1.0 - self.alpha) * self.value;
        } else {
            self.value = x;
            self.primed = true;
        }
        self.value
    }

    pub fn get(&self) -> f64 {
        self.value
    }
}

/// Fixed-bucket linear histogram over `[lo, hi)` with under/overflow buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Self { lo, hi, buckets: vec![0; nbuckets], underflow: 0, overflow: 0, count: 0 }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[idx.min(n - 1)] += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        let target = (q * self.count as f64) as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.lo;
        }
        let w = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return self.lo + w * (i as f64 + 1.0);
            }
        }
        self.hi
    }
}

/// Pearson correlation of two equal-length series (used by trace validation
/// tests to check burstiness/periodicity knobs actually move the signal).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Coefficient of variation of inter-arrival times; >1 indicates bursty.
pub fn cv(xs: &[f64]) -> f64 {
    let mut w = Welford::new();
    for &x in xs {
        w.push(x);
    }
    w.stddev() / w.mean()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        let naive_var = xs.iter().map(|x| (x - 5.0) * (x - 5.0)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.variance() - naive_var).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_single_pass() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Welford::new();
        let mut b = Welford::new();
        let mut all = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 95.0) - 95.05).abs() < 0.1);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        e.push(0.0);
        for _ in 0..64 {
            e.push(1.0);
        }
        assert!((e.get() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_counts_and_quantiles() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.push(i as f64 / 10.0);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.count(), 102);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.buckets().iter().sum::<u64>(), 100);
        let q = h.quantile(0.5);
        assert!((4.0..=6.0).contains(&q), "median-ish {q}");
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-9);
    }
}
