//! A small property-based testing harness (the offline registry has no
//! `proptest`/`quickcheck`). It offers seeded random case generation with
//! a simple halving shrinker for integer tuples, and prints the failing
//! seed so any counterexample is reproducible with `PROP_SEED=<n>`.
//!
//! Usage:
//! ```ignore
//! prop_check("lru stack property", 500, |g| {
//!     let ways = g.usize(1, 16);
//!     let ops = g.vec_u64(1, 2000, 0, 1 << 20);
//!     /* ... return Err(String) on violation ... */
//!     Ok(())
//! });
//! ```

use super::rng::Xoshiro256;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Xoshiro256,
    /// Log of drawn values, reported on failure for debuggability.
    pub trace: Vec<(String, String)>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::new(seed), trace: Vec::new() }
    }

    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = if lo == hi { lo } else { self.rng.range_usize(lo, hi + 1) };
        self.trace.push(("usize".into(), v.to_string()));
        v
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let v = if lo == hi { lo } else { lo + self.rng.gen_range(hi - lo + 1) };
        self.trace.push(("u64".into(), v.to_string()));
        v
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.rng.next_f64() * (hi - lo);
        self.trace.push(("f64".into(), format!("{v}")));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(("bool".into(), v.to_string()));
        v
    }

    /// Random-length vector of u64 in [vlo, vhi].
    pub fn vec_u64(&mut self, len_lo: usize, len_hi: usize, vlo: u64, vhi: u64) -> Vec<u64> {
        let len = self.usize(len_lo, len_hi);
        (0..len).map(|_| self.u64(vlo, vhi)).collect()
    }

    /// Pick one of the provided choices.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.range_usize(0, xs.len());
        self.trace.push(("pick".into(), i.to_string()));
        &xs[i]
    }
}

/// Run `cases` random cases of `prop`. On the first failure, re-run a few
/// nearby seeds to confirm instability is not environmental, then panic with
/// the seed and the generator trace.
pub fn prop_check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xACDC_0001);
    let single = std::env::var("PROP_SEED").is_ok();
    let n = if single { 1 } else { cases };
    for case in 0..n {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            let drawn: Vec<String> =
                g.trace.iter().take(32).map(|(t, v)| format!("{t}={v}")).collect();
            panic!(
                "property '{name}' failed (case {case}, seed {seed}; rerun with PROP_SEED={seed}):\n  {msg}\n  first draws: [{}]",
                drawn.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        prop_check("tautology", 50, |g| {
            let a = g.u64(0, 100);
            let b = g.u64(0, 100);
            if a + b >= a {
                Ok(())
            } else {
                Err("u64 addition broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn reports_failures_with_seed() {
        prop_check("must fail", 50, |g| {
            let v = g.usize(0, 10);
            if v < 11 {
                Err(format!("deliberate failure v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generator_ranges_inclusive() {
        prop_check("ranges", 200, |g| {
            let x = g.usize(3, 5);
            if !(3..=5).contains(&x) {
                return Err(format!("usize out of range: {x}"));
            }
            let y = g.u64(10, 10);
            if y != 10 {
                return Err(format!("degenerate range broke: {y}"));
            }
            let f = g.f64(-1.0, 1.0);
            if !(-1.0..=1.0).contains(&f) {
                return Err(format!("f64 out of range: {f}"));
            }
            Ok(())
        });
    }
}
