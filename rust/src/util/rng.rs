//! Deterministic pseudo-random number generation.
//!
//! The image's crate registry has no `rand`, so the simulator carries its own
//! small, well-known generators: [`SplitMix64`] for seeding / cheap streams
//! and [`Xoshiro256`] (xoshiro256**) as the workhorse generator. Both are
//! reproducible across platforms, which the experiment harness relies on:
//! every trace, split and sweep is derived from an explicit `u64` seed.

/// SplitMix64: tiny, fast, passes BigCrush when used for seeding.
/// Reference: Sebastiano Vigna, <https://prng.di.unimi.it/splitmix64.c>.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — general-purpose 64-bit generator.
/// Reference: Blackman & Vigna, <https://prng.di.unimi.it/xoshiro256starstar.c>.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the authors (avoids the
    /// all-zero state and decorrelates nearby integer seeds).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent child stream. Used to give each trace stream
    /// (embedding, KV, weights, arrivals) its own generator so that changing
    /// one stream's consumption pattern does not perturb the others.
    pub fn fork(&mut self, tag: u64) -> Xoshiro256 {
        let mut sm = SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15));
        Xoshiro256::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`. Lemire's multiply-shift with rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (cached spare not kept: simplicity
    /// over speed; the generators are not on the simulator hot path).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // in (0, 1]
        -u.ln() / lambda
    }

    /// Geometric number of failures before first success, `p` in (0,1].
    pub fn next_geometric(&mut self, p: f64) -> u64 {
        if p >= 1.0 {
            return 0;
        }
        let u = 1.0 - self.next_f64();
        (u.ln() / (1.0 - p).ln()).floor() as u64
    }

    /// Poisson (Knuth for small lambda, normal approximation for large).
    pub fn next_poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = lambda + lambda.sqrt() * self.next_gaussian();
            if v < 0.0 {
                0
            } else {
                v.round() as u64
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// Zipf(θ) sampler over ranks `{0, .., n-1}` (rank 0 most popular), using
/// the classic inversion method of Gray et al. ("Quickly generating
/// billion-record synthetic databases", SIGMOD'94) as popularized by YCSB:
/// one O(n) zeta pre-computation at construction, O(1) per sample.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0, "Zipf: n > 0");
        assert!(theta > 0.0 && (theta - 1.0).abs() > 1e-9, "Zipf: theta > 0, theta != 1");
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2.min(n), theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Self { n, theta, alpha, zetan, eta }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
    }

    /// Sample a rank in `[0, n)`; rank 0 is the most popular item.
    pub fn sample(&self, rng: &mut Xoshiro256) -> u64 {
        let u = rng.next_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if self.n >= 2 && uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let rank = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        rank.min(self.n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 1234567 from the reference C implementation.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism:
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        let mut c = Xoshiro256::new(43);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Xoshiro256::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn unit_interval_bounds() {
        let mut r = Xoshiro256::new(1);
        for _ in 0..10_000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn gen_range_uniformity() {
        let mut r = Xoshiro256::new(99);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.gen_range(10) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.01, "bucket fraction {frac}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Xoshiro256::new(11);
        for lambda in [3.0, 80.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.next_poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() / lambda < 0.05, "lambda {lambda} mean {mean}");
        }
    }

    #[test]
    fn zipf_rank_ordering_and_bounds() {
        let mut r = Xoshiro256::new(3);
        let z = Zipf::new(1000, 0.9);
        let mut counts = vec![0usize; 1000];
        for _ in 0..200_000 {
            let k = z.sample(&mut r) as usize;
            assert!(k < 1000);
            counts[k] += 1;
        }
        // Head heavier than tail; rank 0 the most frequent bucket overall.
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[999] * 10);
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[990..].iter().sum();
        assert!(head > tail * 20, "head {head} tail {tail}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
