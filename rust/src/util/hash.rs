//! Fast non-cryptographic hashing for simulator-internal maps.
//!
//! `std::collections::HashMap`'s default SipHash is DoS-resistant but ~5×
//! slower than needed for the hot maps keyed by cache-line ids and PCs
//! (prefetcher tables, utility cache, in-flight prefetch attribution). This
//! is an FxHash-style multiply hasher — deterministic across processes,
//! which the reproducibility tests also rely on.

use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher (Fx-style) for integer-ish keys.
#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = (self.state.rotate_left(5) ^ b as u64).wrapping_mul(SEED);
        }
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        self.state = (self.state.rotate_left(5) ^ x).wrapping_mul(SEED);
    }

    #[inline]
    fn write_u32(&mut self, x: u32) {
        self.write_u64(x as u64);
    }

    #[inline]
    fn write_usize(&mut self, x: usize) {
        self.write_u64(x as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// HashMap with the fast deterministic hasher.
pub type FastMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

/// HashSet with the fast deterministic hasher.
pub type FastSet<K> = std::collections::HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distributes() {
        let mut m: FastMap<u64, u64> = FastMap::default();
        for i in 0..10_000u64 {
            m.insert(i * 64, i);
        }
        assert_eq!(m.len(), 10_000);
        for i in (0..10_000u64).step_by(997) {
            assert_eq!(m.get(&(i * 64)), Some(&i));
        }
        // Same inputs → same hash across instances (determinism).
        use std::hash::{BuildHasher, Hash};
        let bh = FxBuildHasher::default();
        let h1 = {
            let mut h = bh.build_hasher();
            42u64.hash(&mut h);
            h.finish()
        };
        let h2 = {
            let mut h = bh.build_hasher();
            42u64.hash(&mut h);
            h.finish()
        };
        assert_eq!(h1, h2);
    }
}
