//! Runtime wrapper around one AOT-compiled predictor (TCN or DNN): owns the
//! compiled infer/train/eval executables plus the parameter store, and
//! implements [`ReusePredictor`] for the simulator/coordinator.

use super::feature::FEATURE_DIM;
use super::{Backend, ReusePredictor};
use crate::runtime::{
    Engine, Executable, Manifest, ModelManifest, NativeModel, NativeWeights, ParamStore, Tensor,
};
use anyhow::Result;
use std::sync::Arc;

pub struct ModelRuntime {
    pub mm: ModelManifest,
    pub store: ParamStore,
    infer: Executable,
    train: Executable,
    eval: Executable,
    /// Inference batch (from the manifest; AOT shape is fixed).
    pub infer_batch: usize,
    /// Who runs `predict`: the native kernel (default) or PJRT (escape
    /// hatch / differential-test reference). Train and eval are PJRT
    /// regardless.
    backend: Backend,
    /// Repacked native weights, rebuilt lazily whenever `native_stale`
    /// (first use, after each `train_step`, after `set_params`).
    native: Option<NativeModel>,
    native_stale: bool,
    /// Reusable `[infer_batch, row]` staging buffer for chunked inference:
    /// loaned into the input `Tensor` for the PJRT call and recovered
    /// afterwards, so steady-state prediction allocates no fresh staging
    /// vector per chunk.
    stage: Vec<f32>,
    /// Cached PJRT inference input list (`params ++ x`): the parameter
    /// tensors are deep-cloned once per *weight update*, not once per
    /// chunk; only the trailing x slot is replaced per call.
    infer_inputs: Vec<Tensor>,
    /// Parameters changed since `infer_inputs` was built (train step).
    infer_params_stale: bool,
    /// Total predictions served (telemetry).
    pub predictions: u64,
    /// Train steps executed.
    pub train_steps: u64,
}

impl ModelRuntime {
    /// Load a named model straight from the AOT artifacts bundle,
    /// constructing the PJRT engine in the *calling* thread (handles are
    /// thread-affine). The one artifact-load sequence shared by the CLI,
    /// the serving predictor service, and the sweep workers.
    pub fn load_from_artifacts(model: &str) -> Result<ModelRuntime> {
        let dir = crate::runtime::artifacts_dir()
            .ok_or_else(|| anyhow::anyhow!("artifacts/ not found — run `make artifacts`"))?;
        let manifest = Manifest::load(&dir)?;
        let engine = Engine::cpu()?;
        ModelRuntime::load(&engine, &manifest, model)
    }

    pub fn load(engine: &Engine, manifest: &Manifest, model: &str) -> Result<ModelRuntime> {
        let mm = manifest.model(model)?.clone();
        let infer = engine.load_hlo(&manifest.hlo_path(&mm.infer.hlo))?;
        let train = engine.load_hlo(&manifest.hlo_path(&mm.train.hlo))?;
        let eval = engine.load_hlo(&manifest.hlo_path(&mm.eval.hlo))?;
        let store = ParamStore::load(manifest, model)?;
        let infer_batch = mm.infer.batch;
        Ok(ModelRuntime {
            mm,
            store,
            infer,
            train,
            eval,
            infer_batch,
            backend: Backend::default(),
            native: None,
            native_stale: true,
            stage: Vec::new(),
            infer_inputs: Vec::new(),
            infer_params_stale: true,
            predictions: 0,
            train_steps: 0,
        })
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Select the predict engine. `Native` re-snapshots lazily on the next
    /// predict; `Pjrt` routes through the AOT executable again.
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
    }

    /// Replace the parameters wholesale (differential fuzz tests inject
    /// random `ParamStore` contents here); both backends see the new
    /// weights on their next predict.
    pub fn set_params(&mut self, store: ParamStore) {
        self.store = store;
        self.infer_params_stale = true;
        self.native_stale = true;
    }

    /// The current native weight snapshot (repacking first if stale) —
    /// what serve/sweep hand to worker threads, and what the `adapt/`
    /// hot-swap publishes after a retrain.
    pub fn native_snapshot(&mut self) -> Result<Arc<NativeWeights>> {
        self.refresh_native()?;
        Ok(self.native.as_ref().expect("refreshed above").snapshot())
    }

    fn refresh_native(&mut self) -> Result<()> {
        if self.native_stale || self.native.is_none() {
            // Once per *weight update* (never per chunk): repack the store
            // into a fresh immutable snapshot, version = Adam step.
            self.native = Some(NativeModel::from_params(&self.mm, &self.store)?);
            self.native_stale = false;
        }
        Ok(())
    }

    /// Input row width: window*F for sequence models, F for the DNN.
    pub fn row_elems(&self) -> usize {
        if self.mm.kind == "tcn" {
            self.mm.window * FEATURE_DIM
        } else {
            FEATURE_DIM
        }
    }

    fn x_shape(&self, batch: usize) -> Vec<usize> {
        if self.mm.kind == "tcn" {
            vec![batch, self.mm.window, FEATURE_DIM]
        } else {
            vec![batch, FEATURE_DIM]
        }
    }

    /// One Adam step on a `[train_batch]` batch; returns the loss.
    pub fn train_step(&mut self, x: Vec<f32>, y: Vec<f32>) -> Result<f32> {
        let b = self.mm.train.batch;
        assert_eq!(x.len(), b * self.row_elems());
        assert_eq!(y.len(), b);
        let xt = Tensor::new(self.x_shape(b), x);
        let yt = Tensor::new(vec![b], y);
        let inputs = self.store.train_inputs(xt, yt);
        let out = self.train.run(&inputs)?;
        self.train_steps += 1;
        // Weights changed: the cached PJRT inference input list and the
        // native snapshot must both be rebuilt before the next predict
        // (hot-swap correctness on either backend).
        self.infer_params_stale = true;
        self.native_stale = true;
        self.store.absorb_train_output(out)
    }

    /// Evaluation loss (no dropout) on a `[eval_batch]` batch.
    pub fn eval_loss(&self, x: Vec<f32>, y: Vec<f32>) -> Result<f32> {
        let b = self.mm.eval.batch;
        assert_eq!(x.len(), b * self.row_elems());
        let xt = Tensor::new(self.x_shape(b), x);
        let yt = Tensor::new(vec![b], y);
        let out = self.eval.run(&self.store.eval_inputs(xt, yt))?;
        Ok(out[0].data[0])
    }

    /// Raw batched inference at the fixed AOT batch size. The staged input
    /// lives in `self.stage` (exactly `infer_batch * row_elems` elements);
    /// it is loaned into the input tensor and recovered after the call, and
    /// the output vector is *moved* out of the result tuple rather than
    /// cloned.
    fn infer_staged(&mut self) -> Result<Vec<f32>> {
        let b = self.infer_batch;
        debug_assert_eq!(self.stage.len(), b * self.row_elems());
        let xt = Tensor::new(self.x_shape(b), std::mem::take(&mut self.stage));
        if self.infer_params_stale {
            // Rebuild the whole list (clones the params) — happens once at
            // first use and after each weight update, never per chunk.
            self.infer_inputs = self.store.infer_inputs(xt);
            self.infer_params_stale = false;
        } else {
            *self.infer_inputs.last_mut().expect("x slot present") = xt;
        }
        let result = self.infer.run(&self.infer_inputs);
        // Recover the staging buffer (x is the last input) before
        // propagating any execution error. The x slot is left with an empty
        // data vec; every call overwrites it before running.
        if let Some(t) = self.infer_inputs.last_mut() {
            self.stage = std::mem::take(&mut t.data);
        }
        let mut out = result?;
        anyhow::ensure!(!out.is_empty(), "infer returned no outputs");
        Ok(out.swap_remove(0).data)
    }
}

impl ReusePredictor for ModelRuntime {
    fn name(&self) -> String {
        self.mm.name.clone()
    }

    fn window(&self) -> usize {
        if self.mm.kind == "tcn" {
            self.mm.window
        } else {
            1
        }
    }

    /// Arbitrary-n prediction: chunks into the fixed AOT batch, zero-padding
    /// the tail. Panics on malformed input length (programmer error).
    fn predict(&mut self, x: &[f32], n: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(n);
        self.predict_into(x, n, &mut out);
        out
    }

    /// Prediction into a caller-owned buffer. On the native backend
    /// (default) each row runs the pure-Rust kernel — arbitrary batch, no
    /// tail padding, zero steady-state allocation. On the PJRT backend the
    /// input is chunked to the fixed AOT batch with a zero-padded tail; the
    /// staging chunk and the params side of the input list are reused
    /// across calls (see `infer_staged`), but the per-chunk literal
    /// marshalling and result readback inside `Executable::run` still
    /// allocate — the known leftover the native kernel eliminates.
    fn predict_into(&mut self, x: &[f32], n: usize, out: &mut Vec<f32>) {
        let row = self.row_elems();
        assert_eq!(x.len(), n * row, "predict input length");
        if self.backend == Backend::Native {
            self.refresh_native().expect("native weight snapshot");
            self.native.as_mut().expect("refreshed above").predict_into(x, n, out);
            self.predictions += n as u64;
            return;
        }
        let b = self.infer_batch;
        out.clear();
        out.reserve(n);
        let mut i = 0;
        while i < n {
            let take = (n - i).min(b);
            self.stage.clear();
            self.stage.extend_from_slice(&x[i * row..(i + take) * row]);
            // Zero-pad the tail chunk up to the fixed AOT batch shape.
            self.stage.resize(b * row, 0.0);
            let probs = self.infer_staged().expect("inference failed");
            out.extend_from_slice(&probs[..take]);
            i += take;
        }
        self.predictions += n as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load_tcn() -> Option<ModelRuntime> {
        let dir = crate::runtime::artifacts_dir()?;
        let manifest = Manifest::load(&dir).ok()?;
        let engine = Engine::cpu().ok()?;
        ModelRuntime::load(&engine, &manifest, "tcn").ok()
    }

    #[test]
    fn predict_chunks_and_pads() {
        let Some(mut rt) = load_tcn() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let row = rt.row_elems();
        // n = 1.5 × batch forces a padded tail chunk on the PJRT backend.
        let n = rt.infer_batch * 3 / 2;
        let x = vec![0.1f32; n * row];
        assert_eq!(rt.backend(), Backend::Native, "native is the default");
        let native = rt.predict(&x, n);
        rt.set_backend(Backend::Pjrt);
        let pjrt = rt.predict(&x, n);
        for probs in [&native, &pjrt] {
            assert_eq!(probs.len(), n);
            for &p in probs.iter() {
                assert!((0.0..=1.0).contains(&p));
            }
            // All-identical inputs ⇒ all-identical outputs (batch-position
            // independence on either backend).
            let spread = probs.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
                - probs.iter().cloned().fold(f32::INFINITY, f32::min);
            assert!(spread < 1e-5, "spread {spread}");
        }
        // The two backends agree on the padded-tail batch shape.
        for (a, b) in native.iter().zip(&pjrt) {
            assert!((a - b).abs() <= 1e-5, "native {a} vs pjrt {b}");
        }
    }

    #[test]
    fn train_step_runs_and_loss_finite() {
        let Some(mut rt) = load_tcn() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let b = rt.mm.train.batch;
        let row = rt.row_elems();
        let mut x = vec![0.0f32; b * row];
        // Make labels learnable: label 1 iff feature[4] of last step > 0.5.
        let mut y = vec![0.0f32; b];
        for i in 0..b {
            let v = if i % 2 == 0 { 0.9 } else { 0.1 };
            x[i * row + row - FEATURE_DIM + 4] = v;
            y[i] = (v > 0.5) as u8 as f32;
        }
        let l0 = rt.train_step(x.clone(), y.clone()).unwrap();
        assert!(l0.is_finite());
        let mut last = l0;
        for _ in 0..10 {
            last = rt.train_step(x.clone(), y.clone()).unwrap();
        }
        assert!(last <= l0 + 1e-3, "loss should not explode: {l0} -> {last}");
        assert_eq!(rt.train_steps, 11);
    }
}
