//! Training dataset assembly: stream a trace through the [`FeatureExtractor`]
//! and the labeler, materialize `(window × F)` sequences + labels, and split
//! 70/15/15 (paper §4.1) with a seeded shuffle.

use super::feature::{FeatureExtractor, GeometryHints, FEATURE_DIM};
use super::labeler::{annotate, DEFAULT_HORIZON};
use crate::trace::Access;
use crate::util::rng::Xoshiro256;

/// Materialized dataset: `x` is `[n, window, F]` row-major; `x_cur` is the
/// last row of each sequence (`[n, F]`, the DNN baseline's input); `y` are
/// the {0,1} labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub window: usize,
    pub n: usize,
    pub x: Vec<f32>,
    pub x_cur: Vec<f32>,
    pub y: Vec<f32>,
}

/// Index-based view of a train/val/test split.
#[derive(Debug, Clone)]
pub struct Split {
    pub train: Vec<usize>,
    pub val: Vec<usize>,
    pub test: Vec<usize>,
}

impl Dataset {
    /// Build from a trace. `sample_every` keeps 1/k of accesses (the paper's
    /// 2.3B records are profiled, not exhaustive) — it also decorrelates
    /// consecutive samples.
    pub fn build(
        trace: &[Access],
        window: usize,
        geom: GeometryHints,
        horizon: usize,
        sample_every: usize,
    ) -> Dataset {
        let ann = annotate(trace, if horizon == 0 { DEFAULT_HORIZON } else { horizon });
        let mut fx = FeatureExtractor::new(window, geom);
        let mut seq = vec![0.0f32; window * FEATURE_DIM];
        let mut x = Vec::new();
        let mut x_cur = Vec::new();
        let mut y = Vec::new();
        let stride = sample_every.max(1);
        for (i, a) in trace.iter().enumerate() {
            fx.push(a, &mut seq);
            if i % stride == 0 {
                x.extend_from_slice(&seq);
                x_cur.extend_from_slice(&seq[(window - 1) * FEATURE_DIM..]);
                y.push(ann[i].label as u8 as f32);
            }
        }
        let n = y.len();
        Dataset { window, n, x, x_cur, y }
    }

    /// Seeded 70/15/15 split (paper §4.1).
    pub fn split(&self, seed: u64) -> Split {
        let mut idx: Vec<usize> = (0..self.n).collect();
        let mut rng = Xoshiro256::new(seed ^ 0x5EED);
        rng.shuffle(&mut idx);
        let n_train = self.n * 70 / 100;
        let n_val = self.n * 15 / 100;
        Split {
            train: idx[..n_train].to_vec(),
            val: idx[n_train..n_train + n_val].to_vec(),
            test: idx[n_train + n_val..].to_vec(),
        }
    }

    pub fn positive_rate(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        self.y.iter().sum::<f32>() as f64 / self.n as f64
    }

    /// Gather a batch of sequences into `[batch, window, F]`, padding by
    /// repeating the last index (AOT shapes are fixed).
    pub fn gather_seq(&self, idx: &[usize], batch: usize) -> (Vec<f32>, Vec<f32>) {
        let row = self.window * FEATURE_DIM;
        let mut x = Vec::with_capacity(batch * row);
        let mut y = Vec::with_capacity(batch);
        for bi in 0..batch {
            let i = idx[bi.min(idx.len() - 1)];
            x.extend_from_slice(&self.x[i * row..(i + 1) * row]);
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// Gather current-feature rows into `[batch, F]` (DNN input).
    pub fn gather_cur(&self, idx: &[usize], batch: usize) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(batch * FEATURE_DIM);
        let mut y = Vec::with_capacity(batch);
        for bi in 0..batch {
            let i = idx[bi.min(idx.len() - 1)];
            x.extend_from_slice(&self.x_cur[i * FEATURE_DIM..(i + 1) * FEATURE_DIM]);
            y.push(self.y[i]);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    fn build_small() -> Dataset {
        let cfg = GeneratorConfig::tiny(11);
        let geom = GeometryHints::from_generator(&cfg);
        let trace = TraceGenerator::new(cfg).generate(30_000);
        Dataset::build(&trace, 8, geom, 2048, 4)
    }

    #[test]
    fn shapes_consistent() {
        let ds = build_small();
        assert_eq!(ds.x.len(), ds.n * 8 * FEATURE_DIM);
        assert_eq!(ds.x_cur.len(), ds.n * FEATURE_DIM);
        assert_eq!(ds.y.len(), ds.n);
        assert!(ds.n >= 7000, "{}", ds.n);
        let rate = ds.positive_rate();
        assert!(rate > 0.1 && rate < 0.95, "{rate}");
    }

    #[test]
    fn split_is_70_15_15_partition() {
        let ds = build_small();
        let sp = ds.split(9);
        assert_eq!(sp.train.len() + sp.val.len() + sp.test.len(), ds.n);
        let frac = sp.train.len() as f64 / ds.n as f64;
        assert!((frac - 0.7).abs() < 0.01, "{frac}");
        // Disjoint.
        let mut all: Vec<usize> =
            sp.train.iter().chain(&sp.val).chain(&sp.test).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), ds.n);
        // Seed-deterministic.
        let sp2 = ds.split(9);
        assert_eq!(sp.train, sp2.train);
    }

    #[test]
    fn x_cur_is_last_row_of_x() {
        let ds = build_small();
        let row = ds.window * FEATURE_DIM;
        for i in (0..ds.n).step_by(97) {
            let last = &ds.x[i * row + (ds.window - 1) * FEATURE_DIM..(i + 1) * row];
            let cur = &ds.x_cur[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
            assert_eq!(last, cur, "sample {i}");
        }
    }

    #[test]
    fn gather_pads_with_repeats() {
        let ds = build_small();
        let idx = vec![0usize, 1, 2];
        let (x, y) = ds.gather_seq(&idx, 8);
        assert_eq!(x.len(), 8 * ds.window * FEATURE_DIM);
        assert_eq!(y.len(), 8);
        // Padded rows repeat the last real sample.
        let row = ds.window * FEATURE_DIM;
        assert_eq!(x[2 * row..3 * row], x[7 * row..8 * row]);
        let (xc, _) = ds.gather_cur(&idx, 8);
        assert_eq!(xc.len(), 8 * FEATURE_DIM);
    }
}
