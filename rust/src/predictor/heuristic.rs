//! Heuristic reuse predictor: a hand-tuned function of the current feature
//! vector (frequency up, staleness down, scratch dead). Serves three roles:
//! a no-artifacts fallback for tests, the `predictor=heuristic` ablation
//! (how much of ACPC's win is the *learned* part?), and a sanity anchor —
//! the TCN must beat it on held-out BCE.

use super::feature::FEATURE_DIM;
use super::ReusePredictor;

pub struct HeuristicPredictor;

impl HeuristicPredictor {
    pub fn score(f: &[f32]) -> f32 {
        debug_assert!(f.len() >= FEATURE_DIM);
        let is_kv = f[1] + f[2];
        let is_weight = f[3];
        let freq = f[5];
        let staleness = f[7]; // 0.5 = at the attention-window boundary
        let is_scratch = 1.0 - (f[0] + f[1] + f[2] + f[3]).min(1.0);
        // In-window KV entries are hot regardless of per-line frequency
        // (the window slides over them); beyond the window they are dead.
        let in_window = (1.0 - 2.0 * staleness).clamp(0.0, 1.0);
        let mut p = 0.2 + 0.7 * freq + 0.5 * is_weight + 0.55 * is_kv * in_window;
        p -= 0.9 * staleness * is_kv;
        p -= 0.5 * is_scratch;
        p.clamp(0.01, 0.99)
    }
}

impl ReusePredictor for HeuristicPredictor {
    fn name(&self) -> String {
        "heuristic".into()
    }

    fn window(&self) -> usize {
        1
    }

    fn predict(&mut self, x: &[f32], n: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(n);
        self.predict_into(x, n, &mut out);
        out
    }

    /// Native allocation-free scoring (the simulation hot path).
    fn predict_into(&mut self, x: &[f32], n: usize, out: &mut Vec<f32>) {
        assert_eq!(x.len(), n * FEATURE_DIM);
        out.clear();
        out.extend((0..n).map(|i| Self::score(&x[i * FEATURE_DIM..(i + 1) * FEATURE_DIM])));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_obvious_cases() {
        let mut hot_weight = [0.0f32; FEATURE_DIM];
        hot_weight[3] = 1.0; // weight
        hot_weight[5] = 0.6; // frequent
        let mut stale_kv = [0.0f32; FEATURE_DIM];
        stale_kv[1] = 1.0; // kv read
        stale_kv[7] = 1.0; // way out of window
        let mut scratch = [0.0f32; FEATURE_DIM];
        scratch[11] = 1.0;
        let mut p = HeuristicPredictor;
        let probs = p.predict(
            &[hot_weight, stale_kv, scratch].concat(),
            3,
        );
        assert!(probs[0] > probs[1], "{probs:?}");
        assert!(probs[0] > probs[2], "{probs:?}");
        for &x in &probs {
            assert!((0.0..=1.0).contains(&x));
        }
    }
}
