//! Per-access feature extraction — the runtime realization of the paper's
//! record tuple (eq. 5): address tag, instruction type, temporal locality,
//! historical reuse distance, context length — plus the engineered temporal
//! and semantic features of §4.1 (inter-access interval, burst regularity,
//! access periodicity, attention/layer locality, KV staleness).
//!
//! The extractor is *stateful*: for every cache line it maintains a bounded
//! history of its recent feature vectors, which is exactly the `(T, F)`
//! sequence the TCN consumes. The same extractor code feeds training-set
//! construction and the online simulation, so train/serve skew is
//! impossible by construction.

use crate::trace::{region, Access, StreamKind};
use crate::util::hash::FastMap;

pub const FEATURE_DIM: usize = 12;

/// Address-space geometry the extractor needs to derive the KV staleness
/// feature (position-in-attention-window). Comes from the generator config;
/// a deployment would obtain it from the serving runtime's allocator.
#[derive(Debug, Clone, Copy)]
pub struct GeometryHints {
    pub kv_layer_bytes: u64,
    pub kv_bytes_per_token: u64,
    pub attn_window: u32,
}

impl GeometryHints {
    pub fn from_generator(cfg: &crate::trace::GeneratorConfig) -> Self {
        Self {
            kv_layer_bytes: cfg.max_ctx as u64 * cfg.profile.kv_bytes_per_token,
            kv_bytes_per_token: cfg.profile.kv_bytes_per_token,
            attn_window: cfg.profile.attn_window,
        }
    }
}

#[derive(Debug, Clone)]
struct LineHist {
    /// Ring of the last `window` feature vectors (row-major).
    ring: Vec<f32>,
    /// Number of vectors written (saturates at window).
    filled: usize,
    /// Ring head (next write slot).
    head: usize,
    last_time: u64,
    last_gap: f64,
    count: u32,
    ewma_gap: f64,
}

/// Stateful extractor. `window` = TCN history length (from the manifest).
pub struct FeatureExtractor {
    window: usize,
    geom: GeometryHints,
    lines: FastMap<u64, LineHist>,
    /// Bound on tracked lines; on overflow, stale entries are swept.
    capacity: usize,
    now: u64,
}

impl FeatureExtractor {
    pub fn new(window: usize, geom: GeometryHints) -> Self {
        Self { window, geom, lines: FastMap::default(), capacity: 1 << 17, now: 0 }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    pub fn tracked_lines(&self) -> usize {
        self.lines.len()
    }

    /// Compute the current access's feature vector, append it to the line's
    /// history, and return the full `(window, FEATURE_DIM)` sequence
    /// (zero-padded at the *front* for young lines) into `out`.
    /// `out.len()` must be `window * FEATURE_DIM`.
    pub fn push(&mut self, a: &Access, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.window * FEATURE_DIM);
        self.now = a.time;
        if self.lines.len() >= self.capacity {
            self.sweep();
        }
        let feat = self.features_of(a);
        let window = self.window;
        let h = self.lines.entry(a.line()).or_insert_with(|| LineHist {
            ring: vec![0.0; window * FEATURE_DIM],
            filled: 0,
            head: 0,
            last_time: 0,
            last_gap: 0.0,
            count: 0,
            ewma_gap: 0.0,
        });
        // Append to ring.
        let base = h.head * FEATURE_DIM;
        h.ring[base..base + FEATURE_DIM].copy_from_slice(&feat);
        h.head = (h.head + 1) % window;
        h.filled = (h.filled + 1).min(window);
        // Update line dynamics.
        let gap = if h.last_time == 0 { 0.0 } else { (a.time - h.last_time) as f64 };
        h.ewma_gap = if h.count == 0 { gap } else { 0.7 * h.ewma_gap + 0.3 * gap };
        h.last_gap = gap;
        h.last_time = a.time;
        h.count = h.count.saturating_add(1);

        // Copy out the chronologically-ordered window, front-padded.
        out.fill(0.0);
        let pad = window - h.filled;
        for i in 0..h.filled {
            // Oldest-first: element i is ring slot (head - filled + i) mod w.
            let slot = (h.head + window - h.filled + i) % window;
            let src = slot * FEATURE_DIM;
            let dst = (pad + i) * FEATURE_DIM;
            out[dst..dst + FEATURE_DIM].copy_from_slice(&h.ring[src..src + FEATURE_DIM]);
        }
    }

    /// The current-access feature vector only (DNN baseline input). Uses
    /// line state *before* this access is applied — callers should use
    /// `push` + take the last row instead when both are needed.
    pub fn features_of(&self, a: &Access) -> [f32; FEATURE_DIM] {
        let mut f = [0.0f32; FEATURE_DIM];
        match a.kind {
            StreamKind::Embedding => f[0] = 1.0,
            StreamKind::KvRead => f[1] = 1.0,
            StreamKind::KvWrite => f[2] = 1.0,
            StreamKind::Weight => f[3] = 1.0,
            StreamKind::Scratch => {}
        }
        let (gap, count, ewma, last_gap) = match self.lines.get(&a.line()) {
            Some(h) => (
                if h.last_time == 0 { 0.0 } else { (a.time - h.last_time) as f64 },
                h.count as f64,
                h.ewma_gap,
                h.last_gap,
            ),
            None => (0.0, 0.0, 0.0, 0.0),
        };
        f[4] = (log2p1(gap) / 20.0) as f32; // temporal locality (reuse distance)
        f[5] = (log2p1(count) / 16.0) as f32; // access frequency
        f[6] = (a.ctx_len as f32 / 512.0).min(2.0); // context length S_i
        f[7] = self.kv_staleness(a); // position vs attention window
        f[8] = (log2p1((gap - last_gap).abs()) / 20.0) as f32; // periodicity / regularity
        f[9] = (log2p1(ewma) / 20.0) as f32; // burst scale
        f[10] = a.layer as f32 / 16.0; // layer locality
        f[11] = a.is_write as u8 as f32;
        f
    }

    /// For KV lines: how far behind the head of the context this entry sits,
    /// in units of the attention window. > 1 ⇒ outside the window ⇒ likely
    /// dead. 0 for non-KV lines.
    fn kv_staleness(&self, a: &Access) -> f32 {
        if region::of(a.addr) != region::of(region::KV) {
            return 0.0;
        }
        let rel = (a.addr - region::KV) % self.geom.kv_layer_bytes;
        let pos = (rel / self.geom.kv_bytes_per_token) as u32;
        if a.ctx_len <= pos {
            return 0.0;
        }
        let staleness = (a.ctx_len - pos) as f32 / self.geom.attn_window.max(1) as f32;
        (staleness / 2.0).min(1.0)
    }

    /// Drop lines not touched in the most recent half of observed time.
    fn sweep(&mut self) {
        let horizon = self.now.saturating_sub(self.now / 2);
        self.lines.retain(|_, h| h.last_time >= horizon);
        // Pathological case: everything recent — drop arbitrary half.
        if self.lines.len() >= self.capacity {
            let mut i = 0usize;
            self.lines.retain(|_, _| {
                i += 1;
                i % 2 == 0
            });
        }
    }
}

fn log2p1(x: f64) -> f64 {
    (1.0 + x.max(0.0)).log2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    fn geom() -> GeometryHints {
        GeometryHints::from_generator(&GeneratorConfig::tiny(1))
    }

    fn mk_access(time: u64, addr: u64, kind: StreamKind, ctx: u32) -> Access {
        Access { time, addr, pc: 1, kind, session: 0, ctx_len: ctx, layer: 2, is_write: false }
    }

    #[test]
    fn feature_vector_basics() {
        let fx = FeatureExtractor::new(4, geom());
        let a = mk_access(10, region::EMBED + 128, StreamKind::Embedding, 7);
        let f = fx.features_of(&a);
        assert_eq!(f[0], 1.0); // embedding one-hot
        assert_eq!(f[1], 0.0);
        assert!((f[6] - 7.0 / 512.0).abs() < 1e-6);
        assert_eq!(f[11], 0.0);
    }

    #[test]
    fn history_window_padding_and_order() {
        let mut fx = FeatureExtractor::new(3, geom());
        let mut out = vec![0.0; 3 * FEATURE_DIM];
        let line = region::WEIGHT + 0x40;
        // First touch: rows 0..2 padded, last row live.
        fx.push(&mk_access(1, line, StreamKind::Weight, 0), &mut out);
        assert!(out[..2 * FEATURE_DIM].iter().all(|&v| v == 0.0));
        assert_eq!(out[2 * FEATURE_DIM + 3], 1.0); // weight one-hot in last row
        // Three more touches: ring wraps, all rows populated.
        for t in [5, 9, 13] {
            fx.push(&mk_access(t, line, StreamKind::Weight, 0), &mut out);
        }
        for row in 0..3 {
            assert_eq!(out[row * FEATURE_DIM + 3], 1.0, "row {row}");
        }
        // Chronological: gap feature (idx 4) of last row reflects gap of 4.
        let g_last = out[2 * FEATURE_DIM + 4];
        assert!(g_last > 0.0);
    }

    #[test]
    fn kv_staleness_grows_out_of_window() {
        let g = geom();
        let fx = FeatureExtractor::new(2, g);
        // KV line at position 0, context head far beyond the window.
        let addr = region::KV; // slot 0, layer 0, pos 0
        let fresh = mk_access(1, addr, StreamKind::KvRead, 4);
        let stale = mk_access(2, addr, StreamKind::KvRead, g.attn_window * 3);
        assert!(fx.features_of(&fresh)[7] < fx.features_of(&stale)[7]);
        assert!(fx.features_of(&stale)[7] >= 1.0);
    }

    #[test]
    fn frequency_feature_increases() {
        let mut fx = FeatureExtractor::new(2, geom());
        let mut out = vec![0.0; 2 * FEATURE_DIM];
        let line = region::EMBED;
        let f0 = fx.features_of(&mk_access(1, line, StreamKind::Embedding, 0))[5];
        for t in 1..20 {
            fx.push(&mk_access(t, line, StreamKind::Embedding, 0), &mut out);
        }
        let f1 = fx.features_of(&mk_access(21, line, StreamKind::Embedding, 0))[5];
        assert!(f1 > f0);
    }

    #[test]
    fn capacity_sweep_keeps_extractor_bounded() {
        let mut fx = FeatureExtractor::new(2, geom());
        fx.capacity = 1000;
        let mut out = vec![0.0; 2 * FEATURE_DIM];
        let mut gen = TraceGenerator::new(GeneratorConfig::tiny(3));
        for _ in 0..50_000 {
            let a = gen.next_access();
            fx.push(&a, &mut out);
        }
        assert!(fx.tracked_lines() <= 1000, "{}", fx.tracked_lines());
    }

    #[test]
    fn all_features_bounded() {
        let mut fx = FeatureExtractor::new(4, geom());
        let mut out = vec![0.0; 4 * FEATURE_DIM];
        let mut gen = TraceGenerator::new(GeneratorConfig::tiny(9));
        for _ in 0..20_000 {
            let a = gen.next_access();
            fx.push(&a, &mut out);
            for (i, &v) in out.iter().enumerate() {
                assert!((0.0..=2.5).contains(&v), "feature {} = {v}", i % FEATURE_DIM);
            }
        }
    }
}
