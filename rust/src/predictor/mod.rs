//! Reuse prediction pipeline: per-access feature extraction (the paper's
//! eq. 5 tuple), forward-window reuse labeling, dataset assembly, and the
//! runtime wrappers that execute the AOT-compiled TCN / DNN predictors.

pub mod dataset;
pub mod feature;
pub mod heuristic;
pub mod labeler;
pub mod model;

pub use dataset::{Dataset, Split};
pub use feature::{FeatureExtractor, GeometryHints, FEATURE_DIM};
pub use heuristic::HeuristicPredictor;
pub use labeler::{annotate, Annotation};
pub use model::ModelRuntime;

use anyhow::{bail, Result};

/// Inference engine selection for learned predictors. Training and
/// evaluation always run on PJRT (Adam stays in XLA); this only chooses who
/// executes `predict`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// The pure-Rust kernel (`runtime::native`): allocation-free steady
    /// state, arbitrary batch, `Send` snapshots. The default.
    #[default]
    Native,
    /// The AOT-compiled HLO via PJRT — the escape hatch (and the reference
    /// the native kernel is differentially tested against).
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => bail!("unknown backend '{other}' (expected 'native' or 'pjrt')"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// A batched reuse predictor: maps per-line feature sequences to reuse
/// probabilities in [0,1]. `window() == 1` means the model consumes only the
/// current feature vector (the DNN baseline).
///
/// The trait itself is deliberately `Send`-agnostic: PJRT-backed
/// implementations hold thread-affine handles and must be constructed
/// inside the thread that runs them, while the native kernel
/// (`runtime::NativeModel`) is `Send` and shares one weight snapshot across
/// threads — the reason sweeps, shard pools, and serve workers no longer
/// reload artifacts per thread.
pub trait ReusePredictor {
    fn name(&self) -> String;

    fn window(&self) -> usize;

    /// `x` is row-major `[n, window(), FEATURE_DIM]` (or `[n, FEATURE_DIM]`
    /// when `window() == 1`). Returns `n` probabilities.
    fn predict(&mut self, x: &[f32], n: usize) -> Vec<f32>;

    /// Allocation-free variant for the simulation hot loop: write the `n`
    /// probabilities into `out` (cleared first; capacity is reused across
    /// batches, so steady state performs no heap allocation). The default
    /// delegates to [`predict`](Self::predict); hot-path implementations
    /// override it natively.
    fn predict_into(&mut self, x: &[f32], n: usize, out: &mut Vec<f32>) {
        let probs = self.predict(x, n);
        out.clear();
        out.extend_from_slice(&probs);
    }
}

/// Concrete predictor dispatch for the simulator/coordinator: keeps the
/// learned runtime accessible for the online-learning feedback path (which
/// needs `train_step`, not just `predict`).
pub enum PredictorBox {
    None,
    Heuristic(HeuristicPredictor),
    Model(Box<ModelRuntime>),
    /// Native-kernel predictor over a shared weight snapshot — `Send`, no
    /// PJRT anywhere, for runs that never train (see
    /// [`PredictorBox::model_mut`]).
    Native(crate::runtime::NativeModel),
}

impl PredictorBox {
    pub fn is_some(&self) -> bool {
        !matches!(self, PredictorBox::None)
    }

    pub fn window(&self) -> usize {
        match self {
            PredictorBox::None => 1,
            PredictorBox::Heuristic(p) => p.window(),
            PredictorBox::Model(m) => m.window(),
            PredictorBox::Native(m) => ReusePredictor::window(m),
        }
    }

    pub fn name(&self) -> String {
        match self {
            PredictorBox::None => "none".into(),
            PredictorBox::Heuristic(p) => p.name(),
            PredictorBox::Model(m) => ReusePredictor::name(&**m),
            PredictorBox::Native(m) => ReusePredictor::name(m),
        }
    }

    pub fn predict(&mut self, x: &[f32], n: usize) -> Vec<f32> {
        match self {
            PredictorBox::None => vec![0.5; n],
            PredictorBox::Heuristic(p) => p.predict(x, n),
            PredictorBox::Model(m) => m.predict(x, n),
            PredictorBox::Native(m) => m.predict(x, n),
        }
    }

    /// Allocation-free dispatch of [`ReusePredictor::predict_into`]: the
    /// simulation loop owns `out` and reuses its capacity across batches.
    pub fn predict_into(&mut self, x: &[f32], n: usize, out: &mut Vec<f32>) {
        match self {
            PredictorBox::None => {
                out.clear();
                out.resize(n, 0.5);
            }
            PredictorBox::Heuristic(p) => p.predict_into(x, n, out),
            PredictorBox::Model(m) => m.predict_into(x, n, out),
            PredictorBox::Native(m) => m.predict_into(x, n, out),
        }
    }

    /// Online-learning hook; `None` for non-trainable predictors. A
    /// [`PredictorBox::Native`] snapshot is inference-only by construction —
    /// runs that train (feedback or adaptive retraining) use
    /// [`PredictorBox::Model`], whose `ModelRuntime` trains on PJRT and
    /// re-snapshots native weights after each step.
    pub fn model_mut(&mut self) -> Option<&mut ModelRuntime> {
        match self {
            PredictorBox::Model(m) => Some(m),
            _ => None,
        }
    }
}
