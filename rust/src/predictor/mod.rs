//! Reuse prediction pipeline: per-access feature extraction (the paper's
//! eq. 5 tuple), forward-window reuse labeling, dataset assembly, and the
//! runtime wrappers that execute the AOT-compiled TCN / DNN predictors.

pub mod dataset;
pub mod feature;
pub mod heuristic;
pub mod labeler;
pub mod model;

pub use dataset::{Dataset, Split};
pub use feature::{FeatureExtractor, GeometryHints, FEATURE_DIM};
pub use heuristic::HeuristicPredictor;
pub use labeler::{annotate, Annotation};
pub use model::ModelRuntime;

/// A batched reuse predictor: maps per-line feature sequences to reuse
/// probabilities in [0,1]. `window() == 1` means the model consumes only the
/// current feature vector (the DNN baseline).
///
/// Deliberately *not* `Send`: PJRT executables hold thread-affine handles,
/// so learned predictors are constructed inside the thread that runs them
/// (see `coordinator::server::serve`'s factory parameter).
pub trait ReusePredictor {
    fn name(&self) -> String;

    fn window(&self) -> usize;

    /// `x` is row-major `[n, window(), FEATURE_DIM]` (or `[n, FEATURE_DIM]`
    /// when `window() == 1`). Returns `n` probabilities.
    fn predict(&mut self, x: &[f32], n: usize) -> Vec<f32>;

    /// Allocation-free variant for the simulation hot loop: write the `n`
    /// probabilities into `out` (cleared first; capacity is reused across
    /// batches, so steady state performs no heap allocation). The default
    /// delegates to [`predict`](Self::predict); hot-path implementations
    /// override it natively.
    fn predict_into(&mut self, x: &[f32], n: usize, out: &mut Vec<f32>) {
        let probs = self.predict(x, n);
        out.clear();
        out.extend_from_slice(&probs);
    }
}

/// Concrete predictor dispatch for the simulator/coordinator: keeps the
/// learned runtime accessible for the online-learning feedback path (which
/// needs `train_step`, not just `predict`).
pub enum PredictorBox {
    None,
    Heuristic(HeuristicPredictor),
    Model(Box<ModelRuntime>),
}

impl PredictorBox {
    pub fn is_some(&self) -> bool {
        !matches!(self, PredictorBox::None)
    }

    pub fn window(&self) -> usize {
        match self {
            PredictorBox::None => 1,
            PredictorBox::Heuristic(p) => p.window(),
            PredictorBox::Model(m) => m.window(),
        }
    }

    pub fn name(&self) -> String {
        match self {
            PredictorBox::None => "none".into(),
            PredictorBox::Heuristic(p) => p.name(),
            PredictorBox::Model(m) => ReusePredictor::name(&**m),
        }
    }

    pub fn predict(&mut self, x: &[f32], n: usize) -> Vec<f32> {
        match self {
            PredictorBox::None => vec![0.5; n],
            PredictorBox::Heuristic(p) => p.predict(x, n),
            PredictorBox::Model(m) => m.predict(x, n),
        }
    }

    /// Allocation-free dispatch of [`ReusePredictor::predict_into`]: the
    /// simulation loop owns `out` and reuses its capacity across batches.
    pub fn predict_into(&mut self, x: &[f32], n: usize, out: &mut Vec<f32>) {
        match self {
            PredictorBox::None => {
                out.clear();
                out.resize(n, 0.5);
            }
            PredictorBox::Heuristic(p) => p.predict_into(x, n, out),
            PredictorBox::Model(m) => m.predict_into(x, n, out),
        }
    }

    /// Online-learning hook; `None` for non-trainable predictors.
    pub fn model_mut(&mut self) -> Option<&mut ModelRuntime> {
        match self {
            PredictorBox::Model(m) => Some(m),
            _ => None,
        }
    }
}
