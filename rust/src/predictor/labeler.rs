//! Ground-truth reuse labeling (the paper's `L_i ∈ {0,1}` — eq. 5): a
//! backward pass over the trace annotates every access with (a) whether its
//! line is touched again within the next `horizon` accesses (the supervised
//! label) and (b) the absolute index of that next touch (`next_use`, feeding
//! the Belady oracle).

use crate::trace::Access;
use crate::util::hash::FastMap;

/// Default forward window: "reused within the next prediction window".
pub const DEFAULT_HORIZON: usize = 4096;

#[derive(Debug, Clone, Copy)]
pub struct Annotation {
    /// Reused within `horizon` future accesses?
    pub label: bool,
    /// Index (into the trace) of the next access to the same line, if any.
    pub next_use: Option<u64>,
}

/// Annotate every access. O(n) backward sweep with a line → next-index map.
pub fn annotate(trace: &[Access], horizon: usize) -> Vec<Annotation> {
    let mut next: FastMap<u64, usize> = FastMap::default();
    let mut out = vec![Annotation { label: false, next_use: None }; trace.len()];
    for i in (0..trace.len()).rev() {
        let line = trace[i].line();
        let nu = next.get(&line).copied();
        out[i] = Annotation {
            label: matches!(nu, Some(j) if j - i <= horizon),
            next_use: nu.map(|j| j as u64),
        };
        next.insert(line, i);
    }
    out
}

/// Label base rate — used by tests and dataset balance checks.
pub fn positive_rate(ann: &[Annotation]) -> f64 {
    if ann.is_empty() {
        return f64::NAN;
    }
    ann.iter().filter(|a| a.label).count() as f64 / ann.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{GeneratorConfig, StreamKind, TraceGenerator};

    fn acc(time: u64, addr: u64) -> Access {
        Access {
            time,
            addr,
            pc: 0,
            kind: StreamKind::Weight,
            session: 0,
            ctx_len: 0,
            layer: 0,
            is_write: false,
        }
    }

    #[test]
    fn labels_within_horizon() {
        // lines: A B A C B ... horizon 2: A@0 reused at 2 (≤2) → true;
        // B@1 reused at 4 (gap 3 > 2) → false.
        let trace =
            vec![acc(0, 0), acc(1, 64), acc(2, 0), acc(3, 128), acc(4, 64)];
        let ann = annotate(&trace, 2);
        assert!(ann[0].label);
        assert!(!ann[1].label);
        assert!(!ann[2].label, "A never reused after idx 2");
        assert_eq!(ann[0].next_use, Some(2));
        assert_eq!(ann[1].next_use, Some(4));
        assert_eq!(ann[4].next_use, None);
    }

    #[test]
    fn horizon_extremes() {
        let trace = vec![acc(0, 0), acc(1, 64), acc(2, 0)];
        let zero = annotate(&trace, 0);
        assert!(zero.iter().all(|a| !a.label));
        let inf = annotate(&trace, usize::MAX);
        assert!(inf[0].label);
        assert!(!inf[1].label);
    }

    #[test]
    fn generated_trace_has_mixed_labels() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(5)).generate(50_000);
        let ann = annotate(&trace, DEFAULT_HORIZON);
        let rate = positive_rate(&ann);
        // LLM traces must contain both hot reuse and dead lines — the whole
        // premise of pollution control.
        assert!(rate > 0.2 && rate < 0.95, "positive rate {rate}");
        // next_use is consistent: trace[next_use] touches the same line.
        for (i, a) in ann.iter().enumerate().take(1000) {
            if let Some(j) = a.next_use {
                assert_eq!(trace[j as usize].line(), trace[i].line());
                assert!(j as usize > i);
            }
        }
    }
}
