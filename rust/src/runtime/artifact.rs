//! `artifacts/manifest.json` schema — the contract between `aot.py` and the
//! rust runtime. Field names/ordering must stay in lock-step with
//! `python/compile/aot.py::lower_model`.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered entry point (infer / train / eval).
#[derive(Debug, Clone)]
pub struct EntryPoint {
    pub hlo: String,
    pub batch: usize,
}

/// One parameter tensor's name + shape (ordering = binary layout).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One model (tcn, tcn_flat, tcn_short, dnn).
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    /// "tcn" (sequence input B,T,F) or "dnn" (current features B,F).
    pub kind: String,
    pub window: usize,
    pub feature_dim: usize,
    pub dilations: Vec<usize>,
    pub params: Vec<ParamSpec>,
    pub params_bin: String,
    pub infer: EntryPoint,
    pub train: EntryPoint,
    pub eval: EntryPoint,
    pub n_params: usize,
}

impl ModelManifest {
    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

/// The whole bundle.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
    pub adam_lr: f64,
    pub dropout_p: f64,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let version = j.req("version").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let adam = j.get("adam").ok_or_else(|| anyhow!("missing adam"))?;
        let adam_lr = adam.get("lr").and_then(|v| v.as_f64()).unwrap_or(1e-4);
        let dropout_p = j.get("dropout_p").and_then(|v| v.as_f64()).unwrap_or(0.3);

        let models_j = j
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| anyhow!("missing models object"))?;
        let mut models = BTreeMap::new();
        for (name, mj) in models_j {
            models.insert(name.clone(), Self::parse_model(name, mj)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models, adam_lr, dropout_p })
    }

    fn parse_model(name: &str, j: &Json) -> Result<ModelManifest> {
        let entry = |key: &str| -> Result<EntryPoint> {
            let e = j.get(key).ok_or_else(|| anyhow!("model {name}: missing {key}"))?;
            Ok(EntryPoint {
                hlo: e
                    .get("hlo")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("{name}.{key}.hlo"))?
                    .to_string(),
                batch: e.get("batch").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("{name}.{key}.batch"))?,
            })
        };
        let params_j = j
            .get("params")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("model {name}: params"))?;
        let mut params = Vec::new();
        for p in params_j {
            params.push(ParamSpec {
                name: p
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("param name"))?
                    .to_string(),
                shape: p.usize_array("shape").map_err(|e| anyhow!("param shape: {e}"))?,
            });
        }
        let train = entry("train")?;
        let n_params = j
            .get("train")
            .and_then(|t| t.get("n_params"))
            .and_then(|v| v.as_usize())
            .unwrap_or(params.len());
        if n_params != params.len() {
            bail!("model {name}: n_params {} != params len {}", n_params, params.len());
        }
        Ok(ModelManifest {
            name: name.to_string(),
            kind: j
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("{name}.kind"))?
                .to_string(),
            window: j.get("window").and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("{name}.window"))?,
            feature_dim: j
                .get("feature_dim")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("{name}.feature_dim"))?,
            dilations: j.usize_array("dilations").unwrap_or_default(),
            params,
            params_bin: j
                .get("params_bin")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("{name}.params_bin"))?
                .to_string(),
            infer: entry("infer")?,
            train,
            eval: entry("eval")?,
            n_params,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models.get(name).ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_if_present() {
        let Some(dir) = crate::runtime::artifacts_dir() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("tcn"), "models: {:?}", m.models.keys());
        let tcn = m.model("tcn").unwrap();
        assert_eq!(tcn.kind, "tcn");
        assert_eq!(tcn.params.len(), 10);
        assert_eq!(tcn.n_params, 10);
        assert!(tcn.window >= 8);
        assert_eq!(tcn.dilations, vec![1, 2, 4]);
        // params bin size must equal total elems * 4 bytes.
        let bin = dir.join(&tcn.params_bin);
        let len = std::fs::metadata(bin).unwrap().len() as usize;
        assert_eq!(len, tcn.total_param_elems() * 4);
        let dnn = m.model("dnn").unwrap();
        assert_eq!(dnn.kind, "dnn");
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("acpc_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version": 99, "models": {}}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
