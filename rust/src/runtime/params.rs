//! Parameter store: loads `params_<model>.bin` (f32 LE, manifest order),
//! tracks Adam state, and checkpoints to disk so trained predictors can be
//! reused across runs (`acpc train --save`).

use super::artifact::{Manifest, ModelManifest};
use super::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Model parameters + optimizer state, in manifest order.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub model: String,
    params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// Adam step count (f32 to match the train-step scalar input).
    pub step: f32,
}

impl ParamStore {
    /// Load initial parameters from the AOT bundle.
    pub fn load(manifest: &Manifest, model: &str) -> Result<ParamStore> {
        let mm = manifest.model(model)?;
        let path = manifest.dir.join(&mm.params_bin);
        let bytes = std::fs::read(&path).with_context(|| format!("read {path:?}"))?;
        Self::from_bytes(mm, &bytes)
    }

    pub fn from_bytes(mm: &ModelManifest, bytes: &[u8]) -> Result<ParamStore> {
        let want = mm.total_param_elems() * 4;
        if bytes.len() != want {
            bail!("params bin for {}: {} bytes, expected {want}", mm.name, bytes.len());
        }
        let mut params = Vec::with_capacity(mm.params.len());
        let mut off = 0;
        for spec in &mm.params {
            let n = spec.numel();
            let data: Vec<f32> = bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            off += n * 4;
            params.push(Tensor::new(spec.shape.clone(), data));
        }
        let m = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        let v = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        Ok(ParamStore { model: mm.name.clone(), params, m, v, step: 0.0 })
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.params
    }

    /// Replace params + Adam state from a train-step output
    /// (layout: params' ++ m' ++ v' ++ loss).
    pub fn absorb_train_output(&mut self, outputs: Vec<Tensor>) -> Result<f32> {
        let n = self.params.len();
        if outputs.len() != 3 * n + 1 {
            bail!("train output arity {} != {}", outputs.len(), 3 * n + 1);
        }
        let mut it = outputs.into_iter();
        for p in self.params.iter_mut() {
            *p = it.next().unwrap();
        }
        for m in self.m.iter_mut() {
            *m = it.next().unwrap();
        }
        for v in self.v.iter_mut() {
            *v = it.next().unwrap();
        }
        let loss = it.next().unwrap();
        self.step += 1.0;
        Ok(loss.data[0])
    }

    /// Assemble the train-step input list: params ++ m ++ v ++ step ++ x ++ y.
    pub fn train_inputs(&self, x: Tensor, y: Tensor) -> Vec<Tensor> {
        let mut v: Vec<Tensor> = Vec::with_capacity(3 * self.params.len() + 3);
        v.extend(self.params.iter().cloned());
        v.extend(self.m.iter().cloned());
        v.extend(self.v.iter().cloned());
        v.push(Tensor::scalar(self.step));
        v.push(x);
        v.push(y);
        v
    }

    /// Inference inputs: params ++ x.
    pub fn infer_inputs(&self, x: Tensor) -> Vec<Tensor> {
        let mut v = self.params.clone();
        v.push(x);
        v
    }

    /// Eval inputs: params ++ x ++ y.
    pub fn eval_inputs(&self, x: Tensor, y: Tensor) -> Vec<Tensor> {
        let mut v = self.params.clone();
        v.push(x);
        v.push(y);
        v
    }

    // ---- checkpointing ----------------------------------------------------

    const MAGIC: u64 = 0x4143_5043_434B_5031; // "ACPCCKP1"

    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::new(f);
        w.write_all(&Self::MAGIC.to_le_bytes())?;
        w.write_all(&(self.step as f64).to_le_bytes())?;
        w.write_all(&(self.params.len() as u64).to_le_bytes())?;
        for group in [&self.params, &self.m, &self.v] {
            for t in group.iter() {
                for &x in &t.data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
        w.flush()?;
        Ok(())
    }

    /// Restore params (+Adam state) from a checkpoint; shapes come from the
    /// manifest, so the checkpoint must match the model.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = std::io::BufReader::new(f);
        let mut hdr = [0u8; 24];
        r.read_exact(&mut hdr)?;
        if u64::from_le_bytes(hdr[0..8].try_into().unwrap()) != Self::MAGIC {
            bail!("not an acpc checkpoint");
        }
        let step = f64::from_le_bytes(hdr[8..16].try_into().unwrap()) as f32;
        let n = u64::from_le_bytes(hdr[16..24].try_into().unwrap()) as usize;
        if n != self.params.len() {
            bail!("checkpoint has {n} tensors, model has {}", self.params.len());
        }
        // Borrow-friendly: collect shapes then read groups sequentially.
        for group_idx in 0..3 {
            for ti in 0..n {
                let len = self.params[ti].len();
                let mut buf = vec![0u8; len * 4];
                r.read_exact(&mut buf)?;
                let data: Vec<f32> = buf
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let tgt = match group_idx {
                    0 => &mut self.params[ti],
                    1 => &mut self.m[ti],
                    _ => &mut self.v[ti],
                };
                tgt.data = data;
            }
        }
        self.step = step;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{EntryPoint, ParamSpec};

    fn tiny_manifest_model() -> ModelManifest {
        ModelManifest {
            name: "toy".into(),
            kind: "dnn".into(),
            window: 1,
            feature_dim: 2,
            dilations: vec![],
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![2, 3] },
                ParamSpec { name: "b".into(), shape: vec![3] },
            ],
            params_bin: "x.bin".into(),
            infer: EntryPoint { hlo: "i".into(), batch: 4 },
            train: EntryPoint { hlo: "t".into(), batch: 4 },
            eval: EntryPoint { hlo: "e".into(), batch: 4 },
            n_params: 2,
        }
    }

    #[test]
    fn from_bytes_layout() {
        let mm = tiny_manifest_model();
        let vals: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|x| x.to_le_bytes()).collect();
        let ps = ParamStore::from_bytes(&mm, &bytes).unwrap();
        assert_eq!(ps.tensors()[0].shape, vec![2, 3]);
        assert_eq!(ps.tensors()[0].data, vals[..6]);
        assert_eq!(ps.tensors()[1].data, vals[6..]);
        assert!(ParamStore::from_bytes(&mm, &bytes[..8]).is_err());
    }

    #[test]
    fn train_io_roundtrip() {
        let mm = tiny_manifest_model();
        let bytes = vec![0u8; 9 * 4];
        let mut ps = ParamStore::from_bytes(&mm, &bytes).unwrap();
        let x = Tensor::zeros(&[4, 2]);
        let y = Tensor::zeros(&[4]);
        let inputs = ps.train_inputs(x, y);
        assert_eq!(inputs.len(), 2 * 3 + 3);
        // Simulate a train-step output.
        let mut out: Vec<Tensor> = Vec::new();
        for _ in 0..3 {
            out.push(Tensor::new(vec![2, 3], vec![1.0; 6]));
            out.push(Tensor::new(vec![3], vec![2.0; 3]));
        }
        out.push(Tensor::scalar(0.42));
        let loss = ps.absorb_train_output(out).unwrap();
        assert!((loss - 0.42).abs() < 1e-6);
        assert_eq!(ps.step, 1.0);
        assert_eq!(ps.tensors()[0].data, vec![1.0; 6]);
        assert_eq!(ps.m[1].data, vec![2.0; 3]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mm = tiny_manifest_model();
        let mut ps = ParamStore::from_bytes(&mm, &vec![0u8; 36]).unwrap();
        ps.step = 17.0;
        let dir = std::env::temp_dir().join("acpc_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.ckpt");
        ps.save_checkpoint(&path).unwrap();
        let mut ps2 = ParamStore::from_bytes(&mm, &vec![1u8; 36]).unwrap();
        ps2.load_checkpoint(&path).unwrap();
        assert_eq!(ps2.step, 17.0);
        assert_eq!(ps2.tensors()[0].data, ps.tensors()[0].data);
        std::fs::remove_file(path).unwrap();
    }
}
