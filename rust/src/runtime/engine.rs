//! PJRT engine: compile HLO text once, execute many times.
//!
//! Wraps the `xla` crate exactly as the /opt/xla-example reference does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. All lowered
//! functions return tuples (aot.py lowers with `return_tuple=True`), which
//! `Executable::run` decomposes into `Tensor`s.

use super::tensor::Tensor;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Shared PJRT client. Cheap to clone (Arc).
#[derive(Clone)]
pub struct Engine {
    client: Arc<xla::PjRtClient>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("artifact path {path:?} is not valid UTF-8"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {path:?}"))?;
        let name = path
            .file_name()
            .ok_or_else(|| anyhow::anyhow!("artifact path {path:?} has no file name"))?
            .to_string_lossy()
            .into_owned();
        Ok(Executable { exe, name })
    }
}

/// A compiled computation. `run` takes host tensors; `run_literals` avoids
/// re-marshalling when the caller keeps literals around (hot path).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let res = self.exe.execute::<xla::Literal>(inputs).with_context(|| format!("execute {}", self.name))?;
        let lit = res[0][0].to_literal_sync().context("fetch result")?;
        lit.to_tuple().context("decompose result tuple")
    }

    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let outs = self.run_literals(&lits)?;
        outs.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end smoke: engine loads and runs the real TCN inference
    /// artifact with the initial parameters. Skips when artifacts are absent
    /// (CI stage order), loud-fails on any runtime error.
    #[test]
    fn engine_runs_tcn_infer_artifact() {
        let Some(dir) = crate::runtime::artifacts_dir() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let manifest = crate::runtime::Manifest::load(&dir).unwrap();
        let tcn = manifest.model("tcn").unwrap();
        let engine = Engine::cpu().unwrap();
        let exe = engine.load_hlo(&manifest.hlo_path(&tcn.infer.hlo)).unwrap();

        let params = crate::runtime::ParamStore::load(&manifest, "tcn").unwrap();
        let batch = tcn.infer.batch;
        let x = Tensor::zeros(&[batch, tcn.window, tcn.feature_dim]);
        let mut inputs = params.tensors().to_vec();
        inputs.push(x);
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![batch]);
        // Zero input, zero biases init aside — probabilities must be valid.
        for &p in &out[0].data {
            assert!((0.0..=1.0).contains(&p), "prob {p}");
        }
    }
}
