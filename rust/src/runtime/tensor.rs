//! Host-side f32 tensor + conversions to/from `xla::Literal`.

use anyhow::{bail, Context, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Convert to an XLA literal of matching shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        if self.shape.is_empty() {
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data).reshape(&dims).context("reshape literal")
    }

    /// Read back from an XLA literal (f32 arrays only).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().context("literal to_vec<f32>")?;
        if data.len() != dims.iter().product::<usize>() {
            bail!("literal size {} != shape {:?}", data.len(), dims);
        }
        Ok(Tensor { shape: dims, data })
    }

    /// Flat offset of a multi-index (debug/tests).
    pub fn at(&self, idx: &[usize]) -> f32 {
        assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(x < d, "index {x} out of dim {d} at axis {i}");
            off = off * d + x;
        }
        self.data[off]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_literal() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_literal() {
        let t = Tensor::scalar(2.5);
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![2.5]);
    }

    #[test]
    fn indexing() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
