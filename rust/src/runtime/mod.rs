//! Model runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` (PJRT; train/eval) and executes inference
//! through the pure-Rust [`native`] kernel on the hot path. Python never
//! runs here — the HLO text + params binary are the only interface (see
//! `artifacts/manifest.json`).

mod artifact;
mod engine;
mod native;
mod params;
mod tensor;

pub use artifact::{EntryPoint, Manifest, ModelManifest, ParamSpec};
pub use engine::{Engine, Executable};
pub use native::{synthetic_model, NativeKind, NativeModel, NativeWeights};
pub use params::ParamStore;
pub use tensor::Tensor;

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$ACPC_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (walks up from cwd so tests/benches work
/// from any target dir).
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("ACPC_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// True when the AOT bundle is present (integration tests skip otherwise
/// with a loud message rather than failing).
pub fn artifacts_available() -> bool {
    artifacts_dir().is_some()
}

/// Convenience: manifest path inside the artifacts dir.
pub fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("manifest.json")
}
