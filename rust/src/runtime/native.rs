//! Native inference kernel: a pure-Rust forward pass for the manifest's
//! model families, replacing PJRT on the predict hot path.
//!
//! Why it exists: every `predict_into` chunk through PJRT pays literal
//! marshalling, FFI, result readback, and zero-padding of tail chunks to the
//! fixed AOT batch — and PJRT handles are thread-affine (`!Send`), which
//! forced per-thread artifact reloads in sweeps, per-worker TCN caches in
//! the shard pool, and a serve predictor service pinned to one thread. This
//! module executes the same math directly on the `ParamStore` tensors:
//!
//! * `kind == "tcn"` — a stack of dilated causal 1-D convolutions (one per
//!   entry of [`ModelManifest::dilations`], ReLU between layers, each layer
//!   left-zero-padded by `(K-1)·dilation` exactly like
//!   `python/compile/kernels/tcn_conv.py`), the last timestep's features
//!   through a ReLU dense layer and a linear head, then a sigmoid.
//! * `kind == "dnn"` — the flat MLP: ReLU dense layers and a linear head
//!   over the single feature vector, then a sigmoid.
//!
//! Only the final timestep feeds the head, so the kernel evaluates just the
//! trailing suffix of each conv layer's output that the receptive field
//! actually reaches (`need_out`), not all `window` timesteps.
//!
//! Layout and vectorization: weights are repacked once at construction into
//! flat `Vec<f32>`s — conv taps as `[tap][cin][cout]`, dense as
//! `[in][out]` — so the inner loop is a pure `axpy` over contiguous
//! `cout`/`out` stripes, written with `chunks_exact` in FMA-shaped 8-wide
//! blocks the compiler can vectorize. Steady-state prediction performs no
//! heap allocation: all intermediates live in a preallocated [`Scratch`]
//! (asserted by `tests/alloc_predict.rs`). Batches are arbitrary `n` — no
//! tail padding to an AOT batch shape.
//!
//! Threading and hot-swap: the repacked weights ([`NativeWeights`]) are
//! plain data — `Send + Sync` — shared behind an `Arc` and stamped with the
//! `ParamStore` Adam step as a version, so sweep cells, shard workers, and
//! serve workers hand around snapshot handles instead of reloading
//! artifacts per thread, and the `adapt/` hot-swap can [`NativeModel::install`]
//! a retrained snapshot atomically. Training and evaluation stay on PJRT
//! (Adam runs in XLA); `ModelRuntime` re-snapshots native weights after
//! each `train_step`. Parity with the lowered HLO is enforced by
//! differential tests (≤ 1e-5 per element) in `tests/integration_native.rs`.

use super::artifact::{EntryPoint, ModelManifest, ParamSpec};
use super::params::ParamStore;
use super::tensor::Tensor;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Model family of a repacked snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeKind {
    Tcn,
    Dnn,
}

/// One dilated causal conv layer, weights flat as `[tap][cin][cout]`.
#[derive(Debug, Clone)]
struct ConvLayer {
    dilation: usize,
    k: usize,
    cin: usize,
    cout: usize,
    w: Vec<f32>,
    b: Vec<f32>,
}

/// One dense layer, weights flat as `[in][out]`.
#[derive(Debug, Clone)]
struct DenseLayer {
    out_dim: usize,
    w: Vec<f32>,
    b: Vec<f32>,
    relu: bool,
}

/// Immutable repacked weight snapshot. Plain data (`Send + Sync`); shared
/// behind an `Arc` across sweep cells, shard workers, and serve workers.
#[derive(Debug, Clone)]
pub struct NativeWeights {
    model: String,
    kind: NativeKind,
    window: usize,
    feature_dim: usize,
    /// Snapshot version: the `ParamStore` Adam step at repack time. The
    /// `adapt/` hot-swap relies on this being monotone across `train_step`s.
    version: u64,
    conv: Vec<ConvLayer>,
    dense: Vec<DenseLayer>,
    /// Per conv layer: how many trailing output timesteps the head's
    /// receptive field needs (layer L-1 needs 1; earlier layers grow by
    /// `(K-1)·dilation`, clipped to `window`).
    need_out: Vec<usize>,
}

const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    const fn assert_send<T: Send>() {}
    assert_send_sync::<NativeWeights>();
    assert_send::<NativeModel>();
};

impl NativeWeights {
    /// Repack a `ParamStore` into the flat native layout, validating every
    /// tensor by name and shape against the manifest's model family.
    pub fn from_params(mm: &ModelManifest, store: &ParamStore) -> Result<NativeWeights> {
        if mm.params.len() != store.tensors().len() {
            bail!(
                "model {}: manifest lists {} params, store holds {}",
                mm.name,
                mm.params.len(),
                store.tensors().len()
            );
        }
        let mut by_name: HashMap<&str, &Tensor> = HashMap::new();
        for (spec, t) in mm.params.iter().zip(store.tensors()) {
            if spec.shape != t.shape {
                bail!(
                    "model {}: param '{}' manifest shape {:?} != store shape {:?}",
                    mm.name,
                    spec.name,
                    spec.shape,
                    t.shape
                );
            }
            by_name.insert(spec.name.as_str(), t);
        }

        let kind = match mm.kind.as_str() {
            "tcn" => NativeKind::Tcn,
            "dnn" => NativeKind::Dnn,
            other => bail!("model {}: no native kernel for kind '{other}'", mm.name),
        };

        let mut conv = Vec::new();
        let mut dense = Vec::new();
        let mut used = 0usize;
        match kind {
            NativeKind::Tcn => {
                if mm.dilations.is_empty() {
                    bail!("model {}: tcn with no dilations", mm.name);
                }
                let mut cin = mm.feature_dim;
                for (i, &dilation) in mm.dilations.iter().enumerate() {
                    let w = lookup(&mm.name, &by_name, &format!("conv{i}_w"))?;
                    let (k, cout) = match w.shape[..] {
                        [k, wc, cout] if wc == cin && k >= 1 && cout >= 1 => (k, cout),
                        _ => bail!(
                            "model {}: conv{i}_w shape {:?}, expected [K, {cin}, C]",
                            mm.name,
                            w.shape
                        ),
                    };
                    let b = lookup(&mm.name, &by_name, &format!("conv{i}_b"))?;
                    if b.shape != [cout] {
                        bail!(
                            "model {}: conv{i}_b shape {:?}, expected [{cout}]",
                            mm.name,
                            b.shape
                        );
                    }
                    // Manifest layout [K, Cin, Cout] row-major is already the
                    // tap-major stripe order the kernel consumes.
                    conv.push(ConvLayer {
                        dilation,
                        k,
                        cin,
                        cout,
                        w: w.data.clone(),
                        b: b.data.clone(),
                    });
                    cin = cout;
                    used += 2;
                }
                for (name, relu) in [("fc1", true), ("fc2", false)] {
                    let (dl, out) = dense_from(mm, &by_name, name, cin, relu)?;
                    dense.push(dl);
                    cin = out;
                    used += 2;
                }
                if cin != 1 {
                    bail!("model {}: head emits {cin} values, expected 1", mm.name);
                }
            }
            NativeKind::Dnn => {
                let mut cin = mm.feature_dim;
                let mut i = 0;
                while by_name.contains_key(format!("fc{i}_w").as_str()) {
                    let relu = by_name.contains_key(format!("fc{}_w", i + 1).as_str());
                    let (dl, out) = dense_from(mm, &by_name, &format!("fc{i}"), cin, relu)?;
                    dense.push(dl);
                    cin = out;
                    used += 2;
                    i += 1;
                }
                if dense.is_empty() {
                    bail!("model {}: dnn with no fc layers", mm.name);
                }
                if cin != 1 {
                    bail!("model {}: head emits {cin} values, expected 1", mm.name);
                }
            }
        }
        if used != mm.params.len() {
            bail!(
                "model {}: {} params unaccounted for by the {} family",
                mm.name,
                mm.params.len() - used,
                mm.kind
            );
        }

        // Trailing-suffix plan: only the last timestep feeds the head.
        let mut need_out = vec![0usize; conv.len()];
        let mut need = 1usize;
        for l in (0..conv.len()).rev() {
            need_out[l] = need.min(mm.window);
            need = need_out[l] + (conv[l].k - 1) * conv[l].dilation;
        }

        Ok(NativeWeights {
            model: mm.name.clone(),
            kind,
            window: mm.window,
            feature_dim: mm.feature_dim,
            version: store.step as u64,
            conv,
            dense,
            need_out,
        })
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn kind(&self) -> NativeKind {
        self.kind
    }

    /// Predictor window: the sequence length for TCN, 1 for the DNN.
    pub fn window(&self) -> usize {
        match self.kind {
            NativeKind::Tcn => self.window,
            NativeKind::Dnn => 1,
        }
    }

    pub fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    /// Input row width: `window·F` for sequence models, `F` for the DNN.
    pub fn row_elems(&self) -> usize {
        match self.kind {
            NativeKind::Tcn => self.window * self.feature_dim,
            NativeKind::Dnn => self.feature_dim,
        }
    }

    /// Snapshot version (the `ParamStore` Adam step at repack time).
    pub fn version(&self) -> u64 {
        self.version
    }
}

fn lookup<'a>(
    model: &str,
    by_name: &HashMap<&str, &'a Tensor>,
    name: &str,
) -> Result<&'a Tensor> {
    by_name
        .get(name)
        .copied()
        .ok_or_else(|| anyhow::anyhow!("model {model}: missing param '{name}'"))
}

fn dense_from(
    mm: &ModelManifest,
    by_name: &HashMap<&str, &Tensor>,
    name: &str,
    cin: usize,
    relu: bool,
) -> Result<(DenseLayer, usize)> {
    let w = lookup(&mm.name, by_name, &format!("{name}_w"))?;
    let out_dim = match w.shape[..] {
        [inp, out] if inp == cin && out >= 1 => out,
        _ => bail!("model {}: {name}_w shape {:?}, expected [{cin}, N]", mm.name, w.shape),
    };
    let b = lookup(&mm.name, by_name, &format!("{name}_b"))?;
    if b.shape != [out_dim] {
        bail!("model {}: {name}_b shape {:?}, expected [{out_dim}]", mm.name, b.shape);
    }
    Ok((DenseLayer { out_dim, w: w.data.clone(), b: b.data.clone(), relu }, out_dim))
}

/// Preallocated per-model intermediates: conv ping-pong (`a`/`b`) and dense
/// ping-pong (`d0`/`d1`). Sized once from the weight geometry so the
/// forward pass never grows them.
#[derive(Debug)]
struct Scratch {
    a: Vec<f32>,
    b: Vec<f32>,
    d0: Vec<f32>,
    d1: Vec<f32>,
}

impl Scratch {
    fn for_weights(w: &NativeWeights) -> Scratch {
        let conv_cap = w
            .conv
            .iter()
            .zip(&w.need_out)
            .map(|(cl, &nt)| nt * cl.cout)
            .max()
            .unwrap_or(0);
        let mut dense_cap = 0usize;
        for (i, dl) in w.dense.iter().enumerate() {
            if i == 0 {
                // First layer's input (the conv features / raw row) also
                // lives in the dense ping-pong.
                dense_cap = dense_cap.max(dl.w.len() / dl.out_dim);
            }
            dense_cap = dense_cap.max(dl.out_dim);
        }
        Scratch {
            a: Vec::with_capacity(conv_cap),
            b: Vec::with_capacity(conv_cap),
            d0: Vec::with_capacity(dense_cap),
            d1: Vec::with_capacity(dense_cap),
        }
    }
}

/// A runnable native predictor: a shared weight snapshot plus thread-local
/// scratch. `Send`, so one loaded model fans out across worker threads.
#[derive(Debug)]
pub struct NativeModel {
    weights: Arc<NativeWeights>,
    scratch: Scratch,
    /// Total predictions served (telemetry).
    pub predictions: u64,
}

impl NativeModel {
    /// Repack and wrap in one step.
    pub fn from_params(mm: &ModelManifest, store: &ParamStore) -> Result<NativeModel> {
        Ok(Self::from_weights(Arc::new(NativeWeights::from_params(mm, store)?)))
    }

    /// Wrap an existing shared snapshot (the cheap per-thread constructor:
    /// clones an `Arc` and allocates scratch, nothing else).
    pub fn from_weights(weights: Arc<NativeWeights>) -> NativeModel {
        let scratch = Scratch::for_weights(&weights);
        NativeModel { weights, scratch, predictions: 0 }
    }

    pub fn weights(&self) -> &Arc<NativeWeights> {
        &self.weights
    }

    /// Clone the current snapshot handle (hot-swap producers hand these to
    /// workers).
    pub fn snapshot(&self) -> Arc<NativeWeights> {
        Arc::clone(&self.weights)
    }

    /// Swap in a new snapshot (the consumer side of the `adapt/` hot-swap);
    /// scratch is resized for the new geometry.
    pub fn install(&mut self, weights: Arc<NativeWeights>) {
        self.scratch = Scratch::for_weights(&weights);
        self.weights = weights;
    }

    pub fn version(&self) -> u64 {
        self.weights.version
    }
}

impl crate::predictor::ReusePredictor for NativeModel {
    fn name(&self) -> String {
        self.weights.model.clone()
    }

    fn window(&self) -> usize {
        self.weights.window()
    }

    fn predict(&mut self, x: &[f32], n: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(n);
        self.predict_into(x, n, &mut out);
        out
    }

    /// Arbitrary-batch prediction, no tail padding: each row runs the
    /// trailing-suffix forward pass in preallocated scratch.
    fn predict_into(&mut self, x: &[f32], n: usize, out: &mut Vec<f32>) {
        let row = self.weights.row_elems();
        assert_eq!(x.len(), n * row, "predict input length");
        out.clear();
        out.reserve(n);
        for i in 0..n {
            let z = forward_row(&self.weights, &mut self.scratch, &x[i * row..(i + 1) * row]);
            out.push(sigmoid(z));
        }
        self.predictions += n as u64;
    }
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// `acc += x · w`, 8-wide FMA-shaped blocks over contiguous stripes. Plain
/// mul+add (not `f32::mul_add`): on targets without hardware FMA the fused
/// intrinsic falls back to a slow libm call, and the unfused form matches
/// XLA's CPU lowering bit-for-bit more closely anyway.
#[inline]
fn axpy(acc: &mut [f32], w: &[f32], x: f32) {
    debug_assert_eq!(acc.len(), w.len());
    let mut ac = acc.chunks_exact_mut(8);
    let mut wc = w.chunks_exact(8);
    for (a, ww) in ac.by_ref().zip(wc.by_ref()) {
        a[0] += x * ww[0];
        a[1] += x * ww[1];
        a[2] += x * ww[2];
        a[3] += x * ww[3];
        a[4] += x * ww[4];
        a[5] += x * ww[5];
        a[6] += x * ww[6];
        a[7] += x * ww[7];
    }
    for (a, &wv) in ac.into_remainder().iter_mut().zip(wc.remainder()) {
        *a += x * wv;
    }
}

fn dense_forward(dl: &DenseLayer, input: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend_from_slice(&dl.b);
    let acc = &mut out[..];
    for (i, &xv) in input.iter().enumerate() {
        // Zero activations (common after ReLU) contribute nothing; skipping
        // them is exact for finite weights.
        if xv != 0.0 {
            axpy(acc, &dl.w[i * dl.out_dim..(i + 1) * dl.out_dim], xv);
        }
    }
    if dl.relu {
        for v in acc.iter_mut() {
            *v = v.max(0.0);
        }
    }
}

/// One row through the stack; returns the pre-sigmoid logit.
fn forward_row(w: &NativeWeights, s: &mut Scratch, row: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), w.row_elems());
    if w.kind == NativeKind::Tcn {
        let t = w.window;
        // Conv stack over the trailing suffix. `prev_base` is the absolute
        // timestep of the source buffer's element 0; layer l emits
        // `need_out[l]` timesteps starting at `t - need_out[l]`. Causality
        // is the left zero-pad of `tcn_conv.py`: taps reaching before t=0
        // are skipped (each layer pads its own input with zeros).
        let mut prev_base = 0usize;
        let mut first = true;
        for (cl, &nt) in w.conv.iter().zip(&w.need_out) {
            let base = t - nt;
            s.b.clear();
            s.b.resize(nt * cl.cout, 0.0);
            let src: &[f32] = if first { row } else { &s.a };
            for ti in 0..nt {
                let at = base + ti;
                let dst = &mut s.b[ti * cl.cout..(ti + 1) * cl.cout];
                dst.copy_from_slice(&cl.b);
                for j in 0..cl.k {
                    let back = (cl.k - 1 - j) * cl.dilation;
                    if back > at {
                        continue;
                    }
                    // In-range by construction: the suffix plan keeps every
                    // reachable tap inside the previous layer's stored span.
                    let si = at - back - prev_base;
                    let xrow = &src[si * cl.cin..(si + 1) * cl.cin];
                    let wj = &cl.w[j * cl.cin * cl.cout..(j + 1) * cl.cin * cl.cout];
                    for (c, &xv) in xrow.iter().enumerate() {
                        if xv != 0.0 {
                            axpy(dst, &wj[c * cl.cout..(c + 1) * cl.cout], xv);
                        }
                    }
                }
                for v in dst.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            std::mem::swap(&mut s.a, &mut s.b);
            prev_base = base;
            first = false;
        }
        // Head input: the last timestep's features.
        let cout = w.conv.last().map_or(w.feature_dim, |cl| cl.cout);
        let start = s.a.len() - cout;
        s.d0.clear();
        s.d0.extend_from_slice(&s.a[start..]);
    } else {
        s.d0.clear();
        s.d0.extend_from_slice(row);
    }
    for dl in &w.dense {
        dense_forward(dl, &s.d0, &mut s.d1);
        std::mem::swap(&mut s.d0, &mut s.d1);
    }
    s.d0[0]
}

// ---- synthetic models (tests/benches without the AOT bundle) --------------

/// splitmix64: the repo-standard tiny deterministic generator.
fn splitmix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform in [-scale, scale).
fn unit(state: &mut u64, scale: f32) -> f32 {
    let u = (splitmix(state) >> 40) as f32 / (1u64 << 24) as f32;
    (2.0 * u - 1.0) * scale
}

/// A deterministic synthetic model — manifest plus seeded params — for
/// tests and benches that must run without the AOT artifact bundle (CI has
/// no artifacts; integration crates and `benches/predictor_latency.rs` use
/// this to exercise the kernel and the serve/shard sharing paths).
///
/// `kind` is `"tcn"` (conv stack per `dilations`, K=3, `channels` wide, a
/// 16-wide fc1 and scalar head) or `"dnn"` (`[F→channels→1]` MLP; the
/// `window`/`dilations` arguments are ignored). Weights are uniform in
/// [-0.3, 0.3), small enough that logits stay in sigmoid's sensitive range.
pub fn synthetic_model(
    kind: &str,
    window: usize,
    feature_dim: usize,
    channels: usize,
    dilations: &[usize],
    seed: u64,
) -> (ModelManifest, ParamStore) {
    assert!(window >= 1 && feature_dim >= 1 && channels >= 1);
    let mut specs: Vec<ParamSpec> = Vec::new();
    let push = |specs: &mut Vec<ParamSpec>, name: String, shape: Vec<usize>| {
        specs.push(ParamSpec { name, shape });
    };
    match kind {
        "tcn" => {
            assert!(!dilations.is_empty(), "synthetic tcn needs dilations");
            let mut cin = feature_dim;
            for i in 0..dilations.len() {
                push(&mut specs, format!("conv{i}_w"), vec![3, cin, channels]);
                push(&mut specs, format!("conv{i}_b"), vec![channels]);
                cin = channels;
            }
            push(&mut specs, "fc1_w".into(), vec![cin, 16]);
            push(&mut specs, "fc1_b".into(), vec![16]);
            push(&mut specs, "fc2_w".into(), vec![16, 1]);
            push(&mut specs, "fc2_b".into(), vec![1]);
        }
        "dnn" => {
            push(&mut specs, "fc0_w".into(), vec![feature_dim, channels]);
            push(&mut specs, "fc0_b".into(), vec![channels]);
            push(&mut specs, "fc1_w".into(), vec![channels, 1]);
            push(&mut specs, "fc1_b".into(), vec![1]);
        }
        other => panic!("synthetic_model: unknown kind '{other}'"),
    }
    let n_params = specs.len();
    let mm = ModelManifest {
        name: kind.to_string(),
        kind: kind.to_string(),
        window: if kind == "tcn" { window } else { 1 },
        feature_dim,
        dilations: if kind == "tcn" { dilations.to_vec() } else { vec![] },
        params: specs,
        params_bin: "synthetic".into(),
        infer: EntryPoint { hlo: "synthetic".into(), batch: 256 },
        train: EntryPoint { hlo: "synthetic".into(), batch: 64 },
        eval: EntryPoint { hlo: "synthetic".into(), batch: 256 },
        n_params,
    };
    let mut state = seed ^ 0xACDC_CAFE_F00D_5EED;
    let bytes: Vec<u8> = (0..mm.total_param_elems())
        .flat_map(|_| unit(&mut state, 0.3).to_le_bytes())
        .collect();
    let store = ParamStore::from_bytes(&mm, &bytes).expect("synthetic params");
    (mm, store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::ReusePredictor;

    /// Straight-line reference: full-`window` conv stack with explicit left
    /// zero-padding (the `tcn_conv.py` semantics), no suffix trimming, no
    /// repacked layout — everything the kernel optimizes away.
    fn ref_forward(mm: &ModelManifest, ps: &ParamStore, row: &[f32]) -> f32 {
        let by_name: HashMap<&str, &Tensor> = mm
            .params
            .iter()
            .zip(ps.tensors())
            .map(|(s, t)| (s.name.as_str(), t))
            .collect();
        let mut cur: Vec<Vec<f32>> = if mm.kind == "tcn" {
            (0..mm.window)
                .map(|t| row[t * mm.feature_dim..(t + 1) * mm.feature_dim].to_vec())
                .collect()
        } else {
            vec![row.to_vec()]
        };
        if mm.kind == "tcn" {
            for (i, &d) in mm.dilations.iter().enumerate() {
                let w = by_name[format!("conv{i}_w").as_str()];
                let b = by_name[format!("conv{i}_b").as_str()];
                let (k, cin, cout) = (w.shape[0], w.shape[1], w.shape[2]);
                let mut next = vec![vec![0.0f32; cout]; cur.len()];
                for (t, dst) in next.iter_mut().enumerate() {
                    for (o, slot) in dst.iter_mut().enumerate() {
                        let mut acc = b.data[o];
                        for j in 0..k {
                            let back = (k - 1 - j) * d;
                            if back > t {
                                continue;
                            }
                            for c in 0..cin {
                                acc += cur[t - back][c] * w.at(&[j, c, o]);
                            }
                        }
                        *slot = acc.max(0.0);
                    }
                }
                cur = next;
            }
            cur = vec![cur.last().unwrap().clone()];
        }
        let heads: Vec<String> = if mm.kind == "tcn" {
            vec!["fc1".into(), "fc2".into()]
        } else {
            let mut v = Vec::new();
            let mut i = 0;
            while by_name.contains_key(format!("fc{i}_w").as_str()) {
                v.push(format!("fc{i}"));
                i += 1;
            }
            v
        };
        let mut x = cur.pop().unwrap();
        for (li, name) in heads.iter().enumerate() {
            let w = by_name[format!("{name}_w").as_str()];
            let b = by_name[format!("{name}_b").as_str()];
            let (cin, cout) = (w.shape[0], w.shape[1]);
            let mut y = vec![0.0f32; cout];
            for (o, slot) in y.iter_mut().enumerate() {
                let mut acc = b.data[o];
                for c in 0..cin {
                    acc += x[c] * w.at(&[c, o]);
                }
                *slot = if li + 1 < heads.len() { acc.max(0.0) } else { acc };
            }
            x = y;
        }
        sigmoid(x[0])
    }

    fn random_rows(mm: &ModelManifest, n: usize, seed: u64) -> Vec<f32> {
        let elems = if mm.kind == "tcn" {
            mm.window * mm.feature_dim
        } else {
            mm.feature_dim
        };
        let mut state = seed;
        (0..n * elems)
            .map(|i| {
                // Sprinkle exact zeros: the kernel's zero-skip must be a
                // no-op numerically, and real post-ReLU inputs are sparse.
                if splitmix(&mut state) % 5 == 0 {
                    0.0
                } else {
                    unit(&mut state, 1.0) + (i % 3) as f32 * 0.01
                }
            })
            .collect()
    }

    #[test]
    fn tcn_matches_reference_forward() {
        let (mm, ps) = synthetic_model("tcn", 16, 12, 32, &[1, 2, 4], 7);
        let mut m = NativeModel::from_params(&mm, &ps).unwrap();
        let n = 37;
        let x = random_rows(&mm, n, 99);
        let got = m.predict(&x, n);
        let row = mm.window * mm.feature_dim;
        for i in 0..n {
            let want = ref_forward(&mm, &ps, &x[i * row..(i + 1) * row]);
            assert!(
                (got[i] - want).abs() <= 1e-5,
                "row {i}: native {} vs reference {want}",
                got[i]
            );
            assert!((0.0..=1.0).contains(&got[i]));
        }
    }

    /// Receptive field larger than the window: the suffix plan clips at T
    /// and the zero-pad path does the rest.
    #[test]
    fn tcn_matches_reference_when_receptive_field_exceeds_window() {
        let (mm, ps) = synthetic_model("tcn", 4, 5, 8, &[1, 2, 4, 8], 11);
        let mut m = NativeModel::from_params(&mm, &ps).unwrap();
        let n = 9;
        let x = random_rows(&mm, n, 3);
        let got = m.predict(&x, n);
        let row = mm.window * mm.feature_dim;
        for i in 0..n {
            let want = ref_forward(&mm, &ps, &x[i * row..(i + 1) * row]);
            assert!((got[i] - want).abs() <= 1e-5, "row {i}");
        }
    }

    #[test]
    fn dnn_matches_reference_forward() {
        let (mm, ps) = synthetic_model("dnn", 1, 12, 24, &[], 5);
        let mut m = NativeModel::from_params(&mm, &ps).unwrap();
        assert_eq!(ReusePredictor::window(&m), 1);
        let n = 21;
        let x = random_rows(&mm, n, 42);
        let got = m.predict(&x, n);
        for i in 0..n {
            let want = ref_forward(&mm, &ps, &x[i * 12..(i + 1) * 12]);
            assert!((got[i] - want).abs() <= 1e-5, "row {i}");
        }
    }

    /// Row i of a batch equals the same row predicted alone (no batch
    /// coupling, no tail-padding artifacts at any n).
    #[test]
    fn batch_results_are_position_independent() {
        let (mm, ps) = synthetic_model("tcn", 16, 12, 32, &[1, 2, 4], 1);
        let mut m = NativeModel::from_params(&mm, &ps).unwrap();
        let row = mm.window * mm.feature_dim;
        for n in [1usize, 2, 7, 33] {
            let x = random_rows(&mm, n, n as u64);
            let batch = m.predict(&x, n);
            for i in 0..n {
                let solo = m.predict(&x[i * row..(i + 1) * row], 1);
                assert_eq!(batch[i], solo[0], "n={n} row {i}");
            }
        }
    }

    #[test]
    fn predict_into_reuses_buffer() {
        let (mm, ps) = synthetic_model("dnn", 1, 6, 8, &[], 2);
        let mut m = NativeModel::from_params(&mm, &ps).unwrap();
        let x = random_rows(&mm, 16, 8);
        let mut out = Vec::new();
        m.predict_into(&x, 16, &mut out);
        let first = out.clone();
        out.push(999.0); // stale content must be cleared, capacity kept
        let cap = out.capacity();
        m.predict_into(&x, 16, &mut out);
        assert_eq!(out, first);
        assert_eq!(out.capacity(), cap);
        assert_eq!(m.predictions, 32);
    }

    #[test]
    fn from_params_validates_names_shapes_and_kind() {
        let (mm, ps) = synthetic_model("tcn", 16, 12, 32, &[1, 2, 4], 7);
        assert!(NativeWeights::from_params(&mm, &ps).is_ok());

        // Wrong kind.
        let mut bad = mm.clone();
        bad.kind = "transformer".into();
        assert!(NativeWeights::from_params(&bad, &ps).is_err());

        // A renamed tensor breaks the name contract.
        let mut bad = mm.clone();
        bad.params[0].name = "conv0_weights".into();
        assert!(NativeWeights::from_params(&bad, &ps).is_err());

        // A reshaped tensor breaks the cin chain. The store was built for
        // the true shapes, so lie about the manifest only.
        let mut bad = mm.clone();
        bad.params[0].shape = vec![3, 11, 32];
        assert!(NativeWeights::from_params(&bad, &ps).is_err());
    }

    #[test]
    fn version_tracks_param_store_step() {
        let (mm, mut ps) = synthetic_model("dnn", 1, 4, 4, &[], 3);
        assert_eq!(NativeWeights::from_params(&mm, &ps).unwrap().version(), 0);
        ps.step = 17.0;
        let w = Arc::new(NativeWeights::from_params(&mm, &ps).unwrap());
        assert_eq!(w.version(), 17);
        let mut m = NativeModel::from_weights(Arc::clone(&w));
        assert_eq!(m.version(), 17);
        ps.step = 18.0;
        m.install(Arc::new(NativeWeights::from_params(&mm, &ps).unwrap()));
        assert_eq!(m.version(), 18);
        assert_eq!(w.version(), 17, "snapshots are immutable");
    }

    /// The point of the whole module: one snapshot, many threads.
    #[test]
    fn shared_snapshot_predicts_identically_across_threads() {
        let (mm, ps) = synthetic_model("tcn", 16, 12, 32, &[1, 2, 4], 21);
        let w = Arc::new(NativeWeights::from_params(&mm, &ps).unwrap());
        let x = random_rows(&mm, 8, 77);
        let here = NativeModel::from_weights(Arc::clone(&w)).predict(&x, 8);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (w, x) = (Arc::clone(&w), x.clone());
                std::thread::spawn(move || NativeModel::from_weights(w).predict(&x, 8))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), here);
        }
    }

    #[test]
    fn synthetic_model_is_deterministic() {
        let (_, a) = synthetic_model("tcn", 16, 12, 32, &[1, 2, 4], 9);
        let (_, b) = synthetic_model("tcn", 16, 12, 32, &[1, 2, 4], 9);
        let (_, c) = synthetic_model("tcn", 16, 12, 32, &[1, 2, 4], 10);
        assert_eq!(a.tensors()[0].data, b.tensors()[0].data);
        assert_ne!(a.tensors()[0].data, c.tensors()[0].data);
    }
}
