//! The paper's evaluation metrics (§4.3) and table rendering.
//!
//! - **CHR** — cache hit rate (we report the L2 demand hit rate, the level
//!   the policy under test governs);
//! - **PPR** — prefetch pollution ratio (dead prefetch evictions / fills);
//! - **MPR** — L2 miss-penalty reduction relative to the LRU baseline;
//! - **MAL** — average memory access latency (AMAT, cycles);
//! - **TGT** — token generation throughput from the analytic timing model;
//! - **EMU** — effective memory utilization (useful resident lines / occupied).
//!
//! Open-loop runs (a `traffic` block or an open-loop scenario) additionally
//! report the [`crate::traffic::TrafficSummary`] counters — offered vs
//! admitted vs shed arrivals and admission-queue delay — under the report's
//! `traffic` key.

pub mod report;
mod throughput;

pub use report::{render_sweep, render_table1, MetricsReport, Row, SweepRowView};
pub use throughput::{ThroughputModel, TOKENS_PER_SEC_CALIBRATION};
