//! Per-run metrics assembly and paper-style table rendering.

use crate::mem::Hierarchy;
use crate::util::json::Json;

/// One evaluated configuration = one row of Table 1.
#[derive(Debug, Clone)]
pub struct Row {
    pub model: String,
    /// Cache hit rate, % (L2 demand).
    pub chr: f64,
    /// Prefetch pollution ratio, %.
    pub ppr: f64,
    /// L2 miss-penalty reduction vs the LRU anchor, %; NaN = undefined
    /// baseline (rendered as `n/a`).
    pub mpr: f64,
    /// Token generation throughput, tokens/s.
    pub tgt: f64,
    /// Final training loss (BCE); NaN for rows without a trained model —
    /// the implicit-predictor loss is substituted where defined.
    pub final_loss: f64,
    /// Loss-curve stability descriptor (computed from curve variance).
    pub stability: String,
}

/// Snapshot of everything the metrics layer needs from one simulation.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub name: String,
    pub policy: String,
    pub accesses: u64,
    pub tokens: u64,
    pub l1_hit_rate: f64,
    pub l2_hit_rate: f64,
    pub l3_hit_rate: f64,
    pub l2_pollution_ratio: f64,
    pub l2_prefetch_accuracy: f64,
    pub l2_dead_prefetch_evictions: u64,
    pub l2_demand_evicted_by_prefetch: u64,
    pub l2_miss_cycles: u64,
    pub amat: f64,
    pub emu: f64,
    pub prefetches_issued: u64,
    /// Prefetch candidates discarded because they fell outside the issuing
    /// shard's set partition (always 0 in unsharded runs) — the diagnostic
    /// for per-bank prefetcher coverage loss under `--shards`.
    pub cross_shard_prefetches_dropped: u64,
    pub total_latency: u64,
}

impl MetricsReport {
    /// Harvest from a finished hierarchy. `emu` is sampled by the simulator
    /// during the run (time-averaged useful fraction); pass the average.
    pub fn from_hierarchy(name: &str, h: &Hierarchy, tokens: u64, emu: f64) -> Self {
        Self::from_hierarchies(name, &[h], tokens, emu)
    }

    /// Exact merge over the shards of a set-partitioned run: every derived
    /// metric is recomputed from the *summed* per-level counters (never
    /// averaged from per-shard rates), so an N-shard run reports the same
    /// aggregates a 1-shard run would for set-local state. All shards must
    /// share one [`crate::mem::HierarchyConfig`] (latencies read from the
    /// first). Panics on an empty slice.
    pub fn from_hierarchies(name: &str, parts: &[&Hierarchy], tokens: u64, emu: f64) -> Self {
        let first = parts[0];
        let mut l1 = crate::mem::CacheStats::default();
        let mut l2 = crate::mem::CacheStats::default();
        let mut l3 = crate::mem::CacheStats::default();
        let mut accesses = 0u64;
        let mut total_latency = 0u64;
        let mut prefetches_issued = 0u64;
        let mut cross_shard_dropped = 0u64;
        for h in parts {
            l1.merge(&h.l1.stats);
            l2.merge(&h.l2.stats);
            l3.merge(&h.l3.stats);
            accesses += h.accesses;
            total_latency += h.total_latency;
            prefetches_issued += h.prefetches_issued();
            cross_shard_dropped += h.cross_shard_prefetches_dropped;
        }
        // L2 miss penalty: cycles spent below L2 on L2 demand misses.
        let l3_hit_lat = first.latency_of(crate::mem::ServiceLevel::L3)
            - first.latency_of(crate::mem::ServiceLevel::L2);
        let dram_lat = first.latency_of(crate::mem::ServiceLevel::Dram)
            - first.latency_of(crate::mem::ServiceLevel::L2);
        let l2_miss_cycles = l3.demand_hits * l3_hit_lat + l3.demand_misses * dram_lat;
        let amat = if accesses == 0 { f64::NAN } else { total_latency as f64 / accesses as f64 };
        Self {
            name: name.to_string(),
            policy: first.policy_name().to_string(),
            accesses,
            tokens,
            l1_hit_rate: l1.hit_rate(),
            l2_hit_rate: l2.hit_rate(),
            l3_hit_rate: l3.hit_rate(),
            l2_pollution_ratio: l2.pollution_ratio(),
            l2_prefetch_accuracy: l2.prefetch_accuracy(),
            l2_dead_prefetch_evictions: l2.dead_prefetch_evictions,
            l2_demand_evicted_by_prefetch: l2.demand_evicted_by_prefetch,
            l2_miss_cycles,
            amat,
            emu,
            prefetches_issued,
            cross_shard_prefetches_dropped: cross_shard_dropped,
            total_latency,
        }
    }

    /// Miss-penalty reduction (%) of `self` relative to `baseline`
    /// (both normalized per demand access). `None` when the baseline is
    /// degenerate (zero accesses or zero miss cycles): "reduction vs
    /// nothing" is undefined, and silently reporting `0.0%` would read as
    /// "no improvement" — callers render it as `n/a` instead.
    pub fn miss_penalty_reduction_vs(&self, baseline: &MetricsReport) -> Option<f64> {
        if self.accesses == 0 || baseline.accesses == 0 {
            return None;
        }
        let mine = self.l2_miss_cycles as f64 / self.accesses as f64;
        let base = baseline.l2_miss_cycles as f64 / baseline.accesses as f64;
        if base <= 0.0 || !base.is_finite() {
            return None;
        }
        Some((1.0 - mine / base) * 100.0)
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", Json::Str(self.name.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("accesses", Json::Num(self.accesses as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("l1_hit_rate", Json::Num(self.l1_hit_rate)),
            ("l2_hit_rate", Json::Num(self.l2_hit_rate)),
            ("l3_hit_rate", Json::Num(self.l3_hit_rate)),
            ("l2_pollution_ratio", Json::Num(self.l2_pollution_ratio)),
            ("l2_prefetch_accuracy", Json::Num(self.l2_prefetch_accuracy)),
            ("l2_dead_prefetch_evictions", Json::Num(self.l2_dead_prefetch_evictions as f64)),
            (
                "l2_demand_evicted_by_prefetch",
                Json::Num(self.l2_demand_evicted_by_prefetch as f64),
            ),
            ("l2_miss_cycles", Json::Num(self.l2_miss_cycles as f64)),
            ("amat", Json::Num(self.amat)),
            ("emu", Json::Num(self.emu)),
            ("prefetches_issued", Json::Num(self.prefetches_issued as f64)),
            (
                "cross_shard_prefetches_dropped",
                Json::Num(self.cross_shard_prefetches_dropped as f64),
            ),
            ("total_latency", Json::Num(self.total_latency as f64)),
        ])
    }

    /// Inverse of [`Self::to_json`], used by the report store to rehydrate
    /// cached runs. Numeric `null` decodes as NaN (the serializer writes
    /// non-finite numbers as `null`), so a NaN field round-trips to the
    /// same serialized bytes.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let str_field = |key: &str| -> anyhow::Result<String> {
            let s = j
                .req(key)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("metrics.{key}: expected string"))?;
            Ok(s.to_string())
        };
        let f64_field = |key: &str| -> anyhow::Result<f64> {
            match j.req(key)? {
                Json::Null => Ok(f64::NAN),
                v => v.as_f64().ok_or_else(|| anyhow::anyhow!("metrics.{key}: expected number")),
            }
        };
        let u64_field = |key: &str| -> anyhow::Result<u64> {
            let v = f64_field(key)?;
            if v.is_finite() && v >= 0.0 && v.fract() == 0.0 {
                Ok(v as u64)
            } else {
                anyhow::bail!("metrics.{key}: expected non-negative integer")
            }
        };
        Ok(Self {
            name: str_field("name")?,
            policy: str_field("policy")?,
            accesses: u64_field("accesses")?,
            tokens: u64_field("tokens")?,
            l1_hit_rate: f64_field("l1_hit_rate")?,
            l2_hit_rate: f64_field("l2_hit_rate")?,
            l3_hit_rate: f64_field("l3_hit_rate")?,
            l2_pollution_ratio: f64_field("l2_pollution_ratio")?,
            l2_prefetch_accuracy: f64_field("l2_prefetch_accuracy")?,
            l2_dead_prefetch_evictions: u64_field("l2_dead_prefetch_evictions")?,
            l2_demand_evicted_by_prefetch: u64_field("l2_demand_evicted_by_prefetch")?,
            l2_miss_cycles: u64_field("l2_miss_cycles")?,
            amat: f64_field("amat")?,
            emu: f64_field("emu")?,
            prefetches_issued: u64_field("prefetches_issued")?,
            cross_shard_prefetches_dropped: u64_field("cross_shard_prefetches_dropped")?,
            total_latency: u64_field("total_latency")?,
        })
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<18} L2-CHR={:5.1}% PPR={:5.2}% AMAT={:6.2} EMU={:4.2} pf_acc={:4.2}",
            self.policy,
            self.l2_hit_rate * 100.0,
            self.l2_pollution_ratio * 100.0,
            self.amat,
            self.emu,
            self.l2_prefetch_accuracy
        )
    }
}

/// Borrowed view of one policy×scenario sweep cell for table rendering.
#[derive(Debug, Clone, Copy)]
pub struct SweepRowView<'a> {
    pub policy: &'a str,
    pub scenario: &'a str,
    pub report: &'a MetricsReport,
}

/// Render a policy×scenario sweep grid: one row per cell, grouped in input
/// order. MPR is reported against the same scenario's `lru` cell when the
/// grid contains one (dash otherwise).
pub fn render_sweep(rows: &[SweepRowView]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "| {:<17} | {:<10} | {:>7} | {:>7} | {:>7} | {:>5} | {:>5} |\n",
        "Scenario", "Policy", "CHR (%)", "PPR (%)", "MPR (%)", "AMAT", "EMU"
    ));
    out.push_str(&format!("|{}|\n", "-".repeat(80)));
    for r in rows {
        let baseline = rows.iter().find(|b| b.scenario == r.scenario && b.policy == "lru");
        let mpr = match baseline {
            Some(b) => match r.report.miss_penalty_reduction_vs(b.report) {
                Some(v) => format!("{v:>7.1}"),
                // Degenerate baseline (no misses / no accesses): not zero.
                None => format!("{:>7}", "n/a"),
            },
            None => format!("{:>7}", "—"),
        };
        out.push_str(&format!(
            "| {:<17} | {:<10} | {:>7.1} | {:>7.2} | {} | {:>5.1} | {:>5.2} |\n",
            r.scenario,
            r.policy,
            r.report.l2_hit_rate * 100.0,
            r.report.l2_pollution_ratio * 100.0,
            mpr,
            r.report.amat,
            r.report.emu,
        ));
    }
    out
}

/// Render rows in the paper's Table 1 layout.
pub fn render_table1(rows: &[Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "| {:<18} | {:>8} | {:>8} | {:>8} | {:>12} | {:>10} | {:<13} |\n",
        "Model", "CHR (%)", "PPR (%)", "MPR (%)", "TGT (tok/s)", "Final Loss", "Stability"
    ));
    out.push_str(&format!("|{}|\n", "-".repeat(102)));
    for r in rows {
        let loss = if r.final_loss.is_nan() { "—".to_string() } else { format!("{:.2}", r.final_loss) };
        // NaN MPR = undefined baseline (see `miss_penalty_reduction_vs`).
        let mpr = if r.mpr.is_nan() { format!("{:>8}", "n/a") } else { format!("{:>8.1}", r.mpr) };
        out.push_str(&format!(
            "| {:<18} | {:>8.1} | {:>8.1} | {} | {:>12.0} | {:>10} | {:<13} |\n",
            r.model, r.chr, r.ppr, mpr, r.tgt, loss, r.stability
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{Hierarchy, HierarchyConfig};
    use crate::policy::AccessMeta;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    fn run_small(policy: &str) -> MetricsReport {
        let mut cfg = HierarchyConfig::scaled();
        cfg.prefetcher = "nextline".into();
        let mut h = Hierarchy::new(cfg, policy);
        let mut gen = TraceGenerator::new(GeneratorConfig::tiny(3));
        for _ in 0..30_000 {
            let a = gen.next_access();
            let meta = AccessMeta::demand(a.line(), a.pc, a.kind);
            h.access(&a, &meta);
        }
        MetricsReport::from_hierarchy("test", &h, gen.tokens_done(), 0.8)
    }

    #[test]
    fn report_fields_sane() {
        let r = run_small("lru");
        assert!(r.l1_hit_rate > 0.0 && r.l1_hit_rate <= 1.0);
        assert!(r.l2_hit_rate > 0.0 && r.l2_hit_rate <= 1.0);
        assert!(r.amat >= 4.0);
        assert!(r.l2_miss_cycles > 0);
        assert!(r.tokens > 0);
        let j = r.to_json();
        assert!(j.get("l2_hit_rate").unwrap().as_f64().unwrap() > 0.0);
    }

    /// Driving the same access stream through one full hierarchy vs two
    /// set-shards and merging must produce identical aggregate metrics
    /// (prefetcher off, set-local policy): the partition is exact.
    #[test]
    fn sharded_merge_equals_unsharded_run() {
        let mut cfg = HierarchyConfig::scaled();
        cfg.prefetcher = "none".into();
        // DRRIP's global PSEL/RNG would make the LLC shard-sensitive; use a
        // set-local L3 policy so the partition is exact end to end.
        cfg.l3_policy = "srrip".into();
        let mut full = Hierarchy::new(cfg.clone(), "lru");
        let mut shards = vec![
            Hierarchy::new_sharded(cfg.clone(), "lru", 0, 2),
            Hierarchy::new_sharded(cfg, "lru", 1, 2),
        ];
        let mut gen = TraceGenerator::new(GeneratorConfig::tiny(17));
        for _ in 0..40_000 {
            let a = gen.next_access();
            let meta = AccessMeta::demand(a.line(), a.pc, a.kind);
            full.access(&a, &meta);
            shards[(a.line() & 1) as usize].access(&a, &meta);
        }
        let whole = MetricsReport::from_hierarchy("w", &full, 1, 0.5);
        let parts: Vec<&Hierarchy> = shards.iter().collect();
        let merged = MetricsReport::from_hierarchies("w", &parts, 1, 0.5);
        assert_eq!(whole.to_json().to_pretty(), merged.to_json().to_pretty());
        assert_eq!(whole.total_latency, merged.total_latency);
        assert_eq!(whole.l2_miss_cycles, merged.l2_miss_cycles);
    }

    /// JSON round-trip is byte-exact, including NaN fields (NaN → `null`
    /// → NaN → `null`) — the invariant the report store's byte-identical
    /// cache hits rest on.
    #[test]
    fn json_roundtrip_is_byte_exact() {
        let mut r = run_small("lru");
        r.emu = f64::NAN;
        let text = r.to_json().to_pretty();
        let back =
            MetricsReport::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(text, back.to_json().to_pretty());
        assert!(back.emu.is_nan());
        assert_eq!(back.total_latency, r.total_latency);
    }

    #[test]
    fn mpr_zero_against_self_and_signed_vs_other() {
        let lru = run_small("lru");
        assert!(lru.miss_penalty_reduction_vs(&lru).unwrap().abs() < 1e-9);
        let srrip = run_small("srrip");
        let mpr = srrip.miss_penalty_reduction_vs(&lru).unwrap();
        assert!(mpr.is_finite());
    }

    #[test]
    fn mpr_undefined_against_degenerate_baseline() {
        let real = run_small("lru");
        // A baseline that never missed (or never ran) yields None, not a
        // silent 0.0%.
        let mut zero_miss = real.clone();
        zero_miss.l2_miss_cycles = 0;
        assert_eq!(real.miss_penalty_reduction_vs(&zero_miss), None);
        let mut no_accesses = real.clone();
        no_accesses.accesses = 0;
        assert_eq!(real.miss_penalty_reduction_vs(&no_accesses), None);
        // And the sweep table renders it as n/a instead of 0.0.
        let rows = vec![
            SweepRowView { policy: "lru", scenario: "s", report: &zero_miss },
            SweepRowView { policy: "srrip", scenario: "s", report: &real },
        ];
        let t = render_sweep(&rows);
        assert!(t.contains("n/a"), "{t}");
    }

    #[test]
    fn sweep_table_renders_with_and_without_baseline() {
        let lru = run_small("lru");
        let srrip = run_small("srrip");
        let rows = vec![
            SweepRowView { policy: "lru", scenario: "decode-heavy", report: &lru },
            SweepRowView { policy: "srrip", scenario: "decode-heavy", report: &srrip },
            SweepRowView { policy: "srrip", scenario: "rag-embedding", report: &srrip },
        ];
        let t = render_sweep(&rows);
        assert!(t.contains("decode-heavy"));
        assert!(t.contains("srrip"));
        // The baseline-less scenario renders a dash in the MPR column.
        assert!(t.contains('—'), "{t}");
    }

    #[test]
    fn table_renders() {
        let rows = vec![Row {
            model: "LRU Baseline".into(),
            chr: 71.4,
            ppr: 18.7,
            mpr: 0.0,
            tgt: 187.0,
            final_loss: 0.84,
            stability: "Moderate".into(),
        }];
        let t = render_table1(&rows);
        assert!(t.contains("LRU Baseline"));
        assert!(t.contains("71.4"));
    }
}
