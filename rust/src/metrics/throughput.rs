//! Token-generation throughput (TGT) model.
//!
//! The paper reports tokens/s measured on its serving testbed. We can't
//! measure wall-clock tokens on a simulator, so TGT is derived analytically
//! (DESIGN.md §3): a token's latency is a fixed compute cost plus the sum of
//! its memory access latencies from the simulated hierarchy,
//!
//! ```text
//!   token_cycles = compute_cycles + Σ_access latency(access)
//!   TGT          = clock_hz / mean(token_cycles)
//! ```
//!
//! `compute_cycles` and `clock_hz` are calibrated once so the *LRU baseline*
//! lands near the paper's 187 tokens/s; every other policy is then mapped
//! through the identical model, so relative improvements are driven purely
//! by simulated memory behaviour.

/// Calibration constants (see EXPERIMENTS.md §Calibration).
#[derive(Debug, Clone, Copy)]
pub struct ThroughputModel {
    /// Fixed compute cycles per generated token (MACs not overlapped with
    /// memory stalls).
    pub compute_cycles_per_token: f64,
    /// Simulated core clock.
    pub clock_hz: f64,
}

pub const TOKENS_PER_SEC_CALIBRATION: f64 = 187.0;

impl Default for ThroughputModel {
    fn default() -> Self {
        // With the scaled hierarchy + gpt3ish trace, LRU produces roughly
        // ~280 accesses/token at ~30 cycles AMAT ⇒ ~8.4k stall cycles.
        // compute and clock chosen so LRU ≈ 187 tok/s (paper's Table 1).
        Self { compute_cycles_per_token: 8_000.0, clock_hz: 3.0e6 }
    }
}

impl ThroughputModel {
    /// Tokens/s given measured per-token memory stalls.
    pub fn tokens_per_sec(&self, mem_cycles_per_token: f64) -> f64 {
        let token_cycles = self.compute_cycles_per_token + mem_cycles_per_token;
        self.clock_hz / token_cycles
    }

    /// Mean memory cycles per token from totals.
    pub fn mem_cycles_per_token(total_latency: u64, tokens: u64) -> f64 {
        if tokens == 0 {
            return f64::NAN;
        }
        total_latency as f64 / tokens as f64
    }

    /// Re-derive the calibration: what `clock_hz` makes `baseline_mem_cycles`
    /// hit `TOKENS_PER_SEC_CALIBRATION`? Used by the table1 bench so the
    /// anchor row always matches the paper even if trace knobs drift.
    pub fn calibrated(baseline_mem_cycles_per_token: f64) -> Self {
        let d = Self::default();
        let token_cycles = d.compute_cycles_per_token + baseline_mem_cycles_per_token;
        Self {
            compute_cycles_per_token: d.compute_cycles_per_token,
            clock_hz: TOKENS_PER_SEC_CALIBRATION * token_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lower_stalls_mean_higher_throughput() {
        let m = ThroughputModel::default();
        assert!(m.tokens_per_sec(5_000.0) > m.tokens_per_sec(10_000.0));
    }

    #[test]
    fn calibration_hits_anchor() {
        let m = ThroughputModel::calibrated(9_000.0);
        let t = m.tokens_per_sec(9_000.0);
        assert!((t - TOKENS_PER_SEC_CALIBRATION).abs() < 1e-6, "{t}");
    }

    #[test]
    fn mem_cycles_per_token() {
        assert!((ThroughputModel::mem_cycles_per_token(1000, 10) - 100.0).abs() < 1e-9);
        assert!(ThroughputModel::mem_cycles_per_token(1000, 0).is_nan());
    }
}
