//! Live observability: a lock-free telemetry bus plus the consumers that
//! make an in-flight run visible.
//!
//! The paper's pitch is that ACPC *recognizes* pollution and drift as they
//! happen; this module makes that recognition observable while a run or
//! serve session is in flight instead of only post-hoc in a report.
//!
//! ## Architecture
//!
//! ```text
//! AccessDriver ──┐                       ┌── acpc monitor (table / --ndjson)
//! shard workers ─┤→ TelemetryBus (ring) ─┤── serve dashboard (/health, /metrics.json, /events)
//! serve workers ─┘        │              └── any TelemetrySubscriber
//!                    drop-counting,
//!                    zero-alloc publish
//! ```
//!
//! - [`TelemetryBus`] is a bounded multi-producer broadcast ring
//!   (seqlock slots). Publishing is wait-free and allocation-free: a
//!   [`TelemetryEvent`] is `Copy` and lands in a pre-allocated slot.
//!   Publishers NEVER block on slow subscribers — a lapped subscriber
//!   skips ahead and counts the overwritten events as
//!   [`dropped`](TelemetrySubscriber::dropped).
//! - Events carry a [`SourceId`] (`sim/3`, `serve/0`) and a per-source
//!   monotone `seq` assigned by the owning [`TelemetryPublisher`], so a
//!   fixed spec+seed yields the same per-source event sequence on every
//!   rerun; independent streams merge by sorting on `(source, seq)`.
//! - Attaching a subscriber must not perturb results: a subscribed run's
//!   `RunReport` is byte-identical to an unsubscribed one (asserted in
//!   `tests/integration_obs.rs`).
//!
//! The wire schema is [`TELEMETRY_SCHEMA`] (`acpc-telemetry-v1`); see
//! [`event`] for the event model, [`aggregate`] for the monitor/dashboard
//! fold (including the composite cache health score), and [`http`] for the
//! dependency-free dashboard endpoint.

pub mod aggregate;
pub mod bus;
pub mod event;
pub mod http;

pub use aggregate::{MonitorState, SourceState};
pub use bus::{TelemetryBus, TelemetryPublisher, TelemetrySubscriber};
pub use event::{validate_ndjson, Payload, SourceId, SourceKind, TelemetryEvent, TELEMETRY_SCHEMA};
pub use http::{start_dashboard, DashboardHandle};

/// Accesses between periodic [`Payload::Sample`] events on the sim/serve
/// hot paths. Matches the adaptive controller's default window so adaptive
/// runs interleave roughly one sample per window.
pub const SAMPLE_PERIOD: u64 = 8192;
