//! Subscriber-side aggregation: fold a telemetry stream into per-source
//! live state, render the monitor table, and compute the composite cache
//! health score.
//!
//! Both consumers of the stream — `acpc monitor` and the serve
//! coordinator's `/metrics.json` dashboard endpoint — share this one
//! folder, so the table a terminal shows and the JSON a dashboard serves
//! can never disagree.
//!
//! ## Cache health score
//!
//! A composite in `[0, 1]` per source, weighing the three signals the
//! paper's controller acts on:
//!
//! ```text
//! health = 0.5 * hit_rate                 (latest window, else cumulative sample)
//!        + 0.3 * (1 - min(1, pollution))
//!        + 0.2 * stability
//! stability = 0                            while throttled
//!           = min(1, windows_since_last_drift / 8)   after a drift
//!           = 1                            with no drift observed
//! ```
//!
//! Hit rate dominates (it is the paper's primary metric), pollution is the
//! signal ACPC exists to suppress, and drift-recency makes a recently
//! destabilized source visibly "unhealthy" even after its averages recover.

use super::event::{Payload, SourceId, TelemetryEvent};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Health-score weights (documented in the module docs and the README).
pub const HEALTH_WEIGHT_HIT: f64 = 0.5;
pub const HEALTH_WEIGHT_POLLUTION: f64 = 0.3;
pub const HEALTH_WEIGHT_STABILITY: f64 = 0.2;
/// Windows of drift-free operation for stability to fully recover.
pub const HEALTH_STABILITY_WINDOWS: u64 = 8;

/// Live state of one event source, folded from its stream.
#[derive(Debug, Clone, Default)]
pub struct SourceState {
    /// Events seen from this source.
    pub events: u64,
    /// Highest per-source sequence number seen.
    pub last_seq: u64,
    /// Source engine's access count at the last event.
    pub access: u64,
    /// Telemetry windows seen (window events).
    pub windows: u64,
    /// Latest window hit rate / pollution (NaN before the first window or
    /// sample).
    pub hit_rate: f64,
    pub pollution: f64,
    /// Latest sampled L2 occupancy (NaN before the first sample).
    pub occupancy: f64,
    /// Index of the latest harvested window (for drift recency).
    pub last_window_index: u64,
    pub drift_events: u64,
    /// Window index of the most recent drift, if any.
    pub last_drift_window: Option<u64>,
    pub retrains: u64,
    pub throttles: u64,
    pub resumes: u64,
    pub throttled: bool,
}

impl SourceState {
    fn new() -> SourceState {
        let nan = f64::NAN;
        SourceState { hit_rate: nan, pollution: nan, occupancy: nan, ..Default::default() }
    }

    /// Composite cache health score in `[0, 1]` (see the module docs).
    pub fn health(&self) -> f64 {
        let hit = if self.hit_rate.is_finite() { self.hit_rate.clamp(0.0, 1.0) } else { 0.0 };
        let pollution =
            if self.pollution.is_finite() { self.pollution.clamp(0.0, 1.0) } else { 0.0 };
        let stability = if self.throttled {
            0.0
        } else {
            match self.last_drift_window {
                Some(d) => {
                    let since = self.last_window_index.saturating_sub(d);
                    (since as f64 / HEALTH_STABILITY_WINDOWS as f64).min(1.0)
                }
                None => 1.0,
            }
        };
        HEALTH_WEIGHT_HIT * hit
            + HEALTH_WEIGHT_POLLUTION * (1.0 - pollution)
            + HEALTH_WEIGHT_STABILITY * stability
    }

    /// One-word controller state for the monitor table.
    pub fn state_label(&self) -> &'static str {
        if self.throttled {
            "throttled"
        } else if self.last_drift_window.is_some()
            && self.last_window_index.saturating_sub(self.last_drift_window.unwrap_or(0))
                < HEALTH_STABILITY_WINDOWS
        {
            "recovering"
        } else {
            "ok"
        }
    }
}

/// Aggregated monitor state: every source seen so far, in deterministic
/// (`BTreeMap`) order, plus stream-level accounting.
#[derive(Debug, Clone, Default)]
pub struct MonitorState {
    sources: BTreeMap<SourceId, SourceState>,
    /// Events folded in.
    pub events: u64,
    /// Events the feeding subscriber reported dropped (set by the caller).
    pub dropped: u64,
}

impl MonitorState {
    pub fn new() -> MonitorState {
        MonitorState::default()
    }

    pub fn sources(&self) -> impl Iterator<Item = (&SourceId, &SourceState)> {
        self.sources.iter()
    }

    pub fn is_empty(&self) -> bool {
        self.sources.is_empty()
    }

    /// Fold one event in.
    pub fn apply(&mut self, ev: &TelemetryEvent) {
        self.events += 1;
        let s = self.sources.entry(ev.source).or_insert_with(SourceState::new);
        s.events += 1;
        s.last_seq = s.last_seq.max(ev.seq);
        s.access = s.access.max(ev.access);
        match &ev.payload {
            Payload::Window { stats, throttled } => {
                s.windows += 1;
                s.last_window_index = stats.index;
                s.hit_rate = stats.hit_rate;
                s.pollution = stats.pollution;
                s.throttled = *throttled;
            }
            Payload::Drift { window } => {
                s.drift_events += 1;
                s.last_drift_window = Some(*window);
            }
            Payload::Adaptation(e) => {
                use crate::adapt::AdaptationAction;
                match e.action {
                    AdaptationAction::Retrain { .. } => {
                        s.retrains += 1;
                        s.throttled = false;
                    }
                    AdaptationAction::Throttle => {
                        s.throttles += 1;
                        s.throttled = true;
                    }
                    AdaptationAction::Resume => {
                        s.resumes += 1;
                        s.throttled = false;
                    }
                }
            }
            Payload::Sample { occupancy, hit_rate, pollution, throttled } => {
                s.occupancy = *occupancy;
                // Windows carry sharper (per-window) signals; only fall
                // back to cumulative sample rates for sources that never
                // emit windows (non-adaptive runs).
                if s.windows == 0 {
                    s.hit_rate = *hit_rate;
                    s.pollution = *pollution;
                }
                s.throttled = *throttled;
            }
        }
    }

    /// The dashboard's `/metrics.json` body (schema `acpc-metrics-v1`):
    /// per-source snapshots with health scores plus stream accounting.
    /// Tenant sources (the serve engine's per-tenant attribution streams)
    /// are partitioned into their own `tenants` array so per-worker and
    /// per-tenant health read side by side without label parsing.
    pub fn metrics_json(&self) -> Json {
        let snapshot = |id: &SourceId, s: &SourceState| {
            let mut j = Json::from_pairs(vec![
                ("source", Json::Str(id.label())),
                ("events", Json::Num(s.events as f64)),
                ("last_seq", Json::Num(s.last_seq as f64)),
                ("access", Json::Num(s.access as f64)),
                ("windows", Json::Num(s.windows as f64)),
                ("hit_rate", Json::Num(s.hit_rate)),
                ("pollution", Json::Num(s.pollution)),
                ("occupancy", Json::Num(s.occupancy)),
                ("drift_events", Json::Num(s.drift_events as f64)),
                ("retrains", Json::Num(s.retrains as f64)),
                ("throttles", Json::Num(s.throttles as f64)),
                ("resumes", Json::Num(s.resumes as f64)),
                ("throttled", Json::Bool(s.throttled)),
                ("state", Json::Str(s.state_label().into())),
                ("health", Json::Num(s.health())),
            ]);
            if let Some(d) = s.last_drift_window {
                j.set("last_drift_window", Json::Num(d as f64));
            }
            j
        };
        let (mut sources, mut tenants) = (Vec::new(), Vec::new());
        for (id, s) in &self.sources {
            if id.kind == super::event::SourceKind::Tenant {
                tenants.push(snapshot(id, s));
            } else {
                sources.push(snapshot(id, s));
            }
        }
        let mut j = Json::from_pairs(vec![
            ("schema", Json::Str("acpc-metrics-v1".into())),
            ("events", Json::Num(self.events as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("sources", Json::Arr(sources)),
        ]);
        if !tenants.is_empty() {
            j.set("tenants", Json::Arr(tenants));
        }
        j
    }

    /// Render the refreshing monitor table.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        let header = format!(
            "{:<9} {:>10} {:>7} {:>6} {:>6} {:>5} {:>4} {:>4} {:>4} {:<10} {:>6}\n",
            "source", "access", "windows", "hit", "poll", "occ", "drft", "rtrn", "thr", "state",
            "health"
        );
        out.push_str(&header);
        out.push_str(&"-".repeat(header.len().saturating_sub(1)));
        out.push('\n');
        let pct = |v: f64| if v.is_finite() { format!("{:.1}%", v * 100.0) } else { "-".into() };
        for (id, s) in &self.sources {
            out.push_str(&format!(
                "{:<9} {:>10} {:>7} {:>6} {:>6} {:>5} {:>4} {:>4} {:>4} {:<10} {:>6.3}\n",
                id.label(),
                s.access,
                s.windows,
                pct(s.hit_rate),
                pct(s.pollution),
                pct(s.occupancy),
                s.drift_events,
                s.retrains,
                s.throttles,
                s.state_label(),
                s.health(),
            ));
        }
        out.push_str(&format!("events={} dropped={}\n", self.events, self.dropped));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::{AdaptationAction, AdaptationEvent, WindowStats};

    fn window(index: u64, hit: f64, pollution: f64) -> Payload {
        Payload::Window {
            stats: WindowStats {
                index,
                accesses: 8192,
                l2_demand: 1000,
                hit_rate: hit,
                pollution,
                prefetch_accuracy: 0.5,
                reuse_p50_log2: 8,
            },
            throttled: false,
        }
    }

    fn ev(source: SourceId, seq: u64, payload: Payload) -> TelemetryEvent {
        TelemetryEvent { source, seq, access: (seq + 1) * 8192, payload }
    }

    #[test]
    fn health_score_composition() {
        let mut m = MonitorState::new();
        let s = SourceId::sim(0);
        m.apply(&ev(s, 0, window(0, 0.8, 0.1)));
        let st = m.sources.get(&s).unwrap();
        // No drift, not throttled: 0.5*0.8 + 0.3*0.9 + 0.2*1.0
        assert!((st.health() - (0.4 + 0.27 + 0.2)).abs() < 1e-12);
        assert_eq!(st.state_label(), "ok");

        // A drift zeroes stability proportionally to recency.
        m.apply(&ev(s, 1, Payload::Drift { window: 0 }));
        let st = m.sources.get(&s).unwrap();
        assert!((st.health() - (0.4 + 0.27)).abs() < 1e-12, "fresh drift → stability 0");
        assert_eq!(st.state_label(), "recovering");

        // 8 clean windows later stability is fully recovered.
        for i in 1..=8 {
            m.apply(&ev(s, 1 + i, window(i, 0.8, 0.1)));
        }
        let st = m.sources.get(&s).unwrap();
        assert!((st.health() - (0.4 + 0.27 + 0.2)).abs() < 1e-12);
        assert_eq!(st.state_label(), "ok");
    }

    #[test]
    fn throttle_zeroes_stability_until_resume() {
        let mut m = MonitorState::new();
        let s = SourceId::serve(1);
        m.apply(&ev(s, 0, window(0, 0.6, 0.0)));
        let act = |action| {
            Payload::Adaptation(AdaptationEvent {
                window: 1,
                access: 16384,
                action,
                hit_rate: 0.5,
                predictor_version: 1,
            })
        };
        m.apply(&ev(s, 1, act(AdaptationAction::Throttle)));
        let st = m.sources.get(&s).unwrap();
        assert!(st.throttled);
        assert_eq!(st.state_label(), "throttled");
        assert!((st.health() - (0.3 + 0.3)).abs() < 1e-12);
        m.apply(&ev(s, 2, act(AdaptationAction::Resume)));
        assert!(!m.sources.get(&s).unwrap().throttled);
    }

    #[test]
    fn samples_feed_sources_without_windows_only() {
        let mut m = MonitorState::new();
        let s = SourceId::sim(2);
        let sample = Payload::Sample {
            occupancy: 0.9,
            hit_rate: 0.7,
            pollution: 0.05,
            throttled: false,
        };
        m.apply(&ev(s, 0, sample));
        assert!((m.sources.get(&s).unwrap().hit_rate - 0.7).abs() < 1e-12);
        // Once a window arrives, its per-window rate wins over cumulative.
        m.apply(&ev(s, 1, window(0, 0.5, 0.0)));
        m.apply(&ev(s, 2, sample));
        assert!((m.sources.get(&s).unwrap().hit_rate - 0.5).abs() < 1e-12);
        assert!((m.sources.get(&s).unwrap().occupancy - 0.9).abs() < 1e-12);
    }

    #[test]
    fn metrics_json_shape_and_table_render() {
        let mut m = MonitorState::new();
        m.apply(&ev(SourceId::sim(0), 0, window(0, 0.8, 0.1)));
        m.apply(&ev(SourceId::sim(1), 0, window(0, 0.7, 0.2)));
        m.dropped = 3;
        let j = m.metrics_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("acpc-metrics-v1"));
        assert_eq!(j.get("events").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("dropped").unwrap().as_f64(), Some(3.0));
        let sources = j.get("sources").unwrap().as_arr().unwrap();
        assert_eq!(sources.len(), 2);
        for s in sources {
            assert!(s.get("health").unwrap().as_f64().is_some());
            assert!(s.get("state").unwrap().as_str().is_some());
        }
        let table = m.render_table();
        assert!(table.contains("sim/0") && table.contains("sim/1"));
        assert!(table.contains("dropped=3"));
    }

    #[test]
    fn tenant_sources_partition_into_their_own_array() {
        let mut m = MonitorState::new();
        m.apply(&ev(SourceId::serve(0), 0, window(0, 0.8, 0.1)));
        m.apply(&ev(
            SourceId::tenant(1),
            0,
            Payload::Sample { occupancy: 0.4, hit_rate: 0.9, pollution: 0.02, throttled: false },
        ));
        let j = m.metrics_json();
        let sources = j.get("sources").unwrap().as_arr().unwrap();
        assert_eq!(sources.len(), 1, "tenant stream must not appear among workers");
        assert_eq!(sources[0].get("source").unwrap().as_str(), Some("serve/0"));
        let tenants = j.get("tenants").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), 1);
        assert_eq!(tenants[0].get("source").unwrap().as_str(), Some("tenant/1"));
        assert!(tenants[0].get("health").unwrap().as_f64().is_some());

        // No tenant streams → no tenants key (legacy shape unchanged).
        let mut plain = MonitorState::new();
        plain.apply(&ev(SourceId::serve(0), 0, window(0, 0.8, 0.1)));
        assert!(plain.metrics_json().get("tenants").is_none());
    }
}
