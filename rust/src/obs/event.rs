//! Telemetry event model (wire schema `acpc-telemetry-v1`).
//!
//! A [`TelemetryEvent`] is a fixed-size `Copy` value: publishing one onto
//! the [`super::TelemetryBus`] is a plain memcpy into a pre-allocated ring
//! slot — no `String`, no `Vec`, no heap traffic on the hot path (asserted
//! by `tests/alloc_publish.rs`). Serialization to JSON/NDJSON happens only
//! on the *subscriber* side (the monitor, the dashboard), never where the
//! event is produced.
//!
//! Every event is tagged with its [`SourceId`] (which shard/worker of which
//! subsystem emitted it) and a per-source sequence number that the
//! publisher derives monotonically — so for a fixed spec and seed, the
//! `(source, seq) → payload` mapping is deterministic across reruns even
//! though the *global* interleaving on the bus is transport-order only.
//! Streams from different sources merge without coordination: sort by
//! `(source, seq)`.

use crate::adapt::{AdaptationEvent, WindowStats};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// Wire-schema tag carried by every serialized event line.
pub const TELEMETRY_SCHEMA: &str = "acpc-telemetry-v1";

/// Which subsystem an event came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SourceKind {
    /// A batch-simulation shard (shard 0 covers the single-threaded path).
    Sim,
    /// A serving-coordinator worker.
    Serve,
    /// A serving tenant (QoS engine attribution — cuts across workers).
    Tenant,
}

impl SourceKind {
    pub fn label(self) -> &'static str {
        match self {
            SourceKind::Sim => "sim",
            SourceKind::Serve => "serve",
            SourceKind::Tenant => "tenant",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "sim" => Ok(SourceKind::Sim),
            "serve" => Ok(SourceKind::Serve),
            "tenant" => Ok(SourceKind::Tenant),
            other => bail!("telemetry source kind '{other}' (expected sim|serve|tenant)"),
        }
    }
}

/// Identity of one event stream: subsystem + shard/worker index. Renders as
/// `sim/3` or `serve/0`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SourceId {
    pub kind: SourceKind,
    pub index: u32,
}

impl SourceId {
    /// Simulation shard `k` (0 for single-threaded runs).
    pub fn sim(k: usize) -> SourceId {
        SourceId { kind: SourceKind::Sim, index: k as u32 }
    }

    /// Serving-coordinator worker `w`.
    pub fn serve(w: usize) -> SourceId {
        SourceId { kind: SourceKind::Serve, index: w as u32 }
    }

    /// Serving tenant `t` (tenant-aware serve engine attribution).
    pub fn tenant(t: usize) -> SourceId {
        SourceId { kind: SourceKind::Tenant, index: t as u32 }
    }

    /// `kind/index` label (allocates — subscriber-side only).
    pub fn label(&self) -> String {
        format!("{}/{}", self.kind.label(), self.index)
    }

    /// Inverse of [`Self::label`].
    pub fn parse(s: &str) -> Result<SourceId> {
        let (kind, index) = s
            .split_once('/')
            .ok_or_else(|| anyhow!("telemetry source '{s}': expected kind/index"))?;
        Ok(SourceId {
            kind: SourceKind::parse(kind)?,
            index: index.parse().map_err(|_| anyhow!("telemetry source '{s}': bad index"))?,
        })
    }
}

/// What happened. All variants are `Copy` — see the module docs.
#[derive(Debug, Clone, Copy)]
pub enum Payload {
    /// A controller telemetry window was harvested.
    Window { stats: WindowStats, throttled: bool },
    /// The Page–Hinkley drift detector fired at `window`.
    Drift { window: u64 },
    /// The controller acted (retrain / throttle / resume).
    Adaptation(AdaptationEvent),
    /// Periodic cache-health sample (cumulative counters), emitted every
    /// [`SAMPLE_PERIOD`](crate::obs::SAMPLE_PERIOD) accesses — the only
    /// event kind non-adaptive runs produce.
    Sample { occupancy: f64, hit_rate: f64, pollution: f64, throttled: bool },
}

impl Payload {
    /// The serialized `type` tag.
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Window { .. } => "window",
            Payload::Drift { .. } => "drift",
            Payload::Adaptation(_) => "adaptation",
            Payload::Sample { .. } => "sample",
        }
    }
}

/// One telemetry event: source identity, per-source sequence number, the
/// emitting engine's access count, and the payload.
#[derive(Debug, Clone, Copy)]
pub struct TelemetryEvent {
    pub source: SourceId,
    /// Monotone per-source sequence number (0-based), assigned by the
    /// publisher handle — deterministic across reruns of the same spec.
    pub seq: u64,
    /// Source engine's access count when the event was emitted.
    pub access: u64,
    pub payload: Payload,
}

impl TelemetryEvent {
    /// Serialize to one `acpc-telemetry-v1` JSON object (one NDJSON line
    /// via [`Json::to_string`]).
    pub fn to_json(&self) -> Json {
        let mut j = Json::from_pairs(vec![
            ("schema", Json::Str(TELEMETRY_SCHEMA.into())),
            ("source", Json::Str(self.source.label())),
            ("seq", Json::Num(self.seq as f64)),
            ("access", Json::Num(self.access as f64)),
            ("type", Json::Str(self.payload.kind().into())),
        ]);
        match &self.payload {
            Payload::Window { stats, throttled } => {
                j.set("window", stats.to_json());
                j.set("throttled", Json::Bool(*throttled));
            }
            Payload::Drift { window } => {
                j.set("window", Json::Num(*window as f64));
            }
            Payload::Adaptation(e) => {
                j.set("event", e.to_json());
            }
            Payload::Sample { occupancy, hit_rate, pollution, throttled } => {
                j.set("occupancy", Json::Num(*occupancy));
                j.set("hit_rate", Json::Num(*hit_rate));
                j.set("pollution", Json::Num(*pollution));
                j.set("throttled", Json::Bool(*throttled));
            }
        }
        j
    }

    /// Inverse of [`Self::to_json`]: parse + schema-validate one event
    /// object (the `acpc monitor --validate` / `--attach` decode path).
    pub fn from_json(j: &Json) -> Result<TelemetryEvent> {
        match j.req("schema")?.as_str() {
            Some(TELEMETRY_SCHEMA) => {}
            other => {
                bail!("telemetry schema mismatch: expected {TELEMETRY_SCHEMA:?}, got {other:?}")
            }
        }
        let source = SourceId::parse(
            j.req("source")?.as_str().ok_or_else(|| anyhow!("telemetry source: expected string"))?,
        )?;
        let u = |key: &str| -> Result<u64> {
            j.req(key)?
                .as_f64()
                .filter(|v| v.is_finite() && *v >= 0.0 && v.fract() == 0.0)
                .map(|v| v as u64)
                .ok_or_else(|| anyhow!("telemetry.{key}: expected non-negative integer"))
        };
        let f = |key: &str| -> Result<f64> {
            match j.req(key)? {
                Json::Null => Ok(f64::NAN),
                v => v.as_f64().ok_or_else(|| anyhow!("telemetry.{key}: expected number")),
            }
        };
        let b = |key: &str| -> Result<bool> {
            j.req(key)?.as_bool().ok_or_else(|| anyhow!("telemetry.{key}: expected bool"))
        };
        let payload = match j.req("type")?.as_str() {
            Some("window") => Payload::Window {
                stats: WindowStats::from_json(j.req("window")?)?,
                throttled: b("throttled")?,
            },
            Some("drift") => Payload::Drift { window: u("window")? },
            Some("adaptation") => Payload::Adaptation(AdaptationEvent::from_json(j.req("event")?)?),
            Some("sample") => Payload::Sample {
                occupancy: f("occupancy")?,
                hit_rate: f("hit_rate")?,
                pollution: f("pollution")?,
                throttled: b("throttled")?,
            },
            other => bail!("telemetry.type: unknown event type {other:?}"),
        };
        Ok(TelemetryEvent { source, seq: u("seq")?, access: u("access")?, payload })
    }
}

/// Validate an NDJSON telemetry stream: every non-empty line must parse as
/// a schema-`acpc-telemetry-v1` event, and per-source sequence numbers must
/// be strictly increasing. Returns the number of validated events.
/// (`acpc monitor --validate`, also the CI smoke gate.)
pub fn validate_ndjson(text: &str) -> Result<usize> {
    use std::collections::BTreeMap;
    let mut last_seq: BTreeMap<SourceId, u64> = BTreeMap::new();
    let mut n = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        let ev = TelemetryEvent::from_json(&j).map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        if let Some(&prev) = last_seq.get(&ev.source) {
            if ev.seq <= prev {
                bail!(
                    "line {}: source {} seq {} not strictly increasing (prev {})",
                    lineno + 1,
                    ev.source.label(),
                    ev.seq,
                    prev
                );
            }
        }
        last_seq.insert(ev.source, ev.seq);
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::AdaptationAction;

    fn sample_events() -> Vec<TelemetryEvent> {
        let w = WindowStats {
            index: 3,
            accesses: 8192,
            l2_demand: 4000,
            hit_rate: 0.71,
            pollution: 0.04,
            prefetch_accuracy: 0.5,
            reuse_p50_log2: 9,
        };
        vec![
            TelemetryEvent {
                source: SourceId::sim(0),
                seq: 0,
                access: 32768,
                payload: Payload::Window { stats: w, throttled: false },
            },
            TelemetryEvent {
                source: SourceId::sim(0),
                seq: 1,
                access: 32768,
                payload: Payload::Drift { window: 3 },
            },
            TelemetryEvent {
                source: SourceId::serve(2),
                seq: 0,
                access: 40960,
                payload: Payload::Adaptation(AdaptationEvent {
                    window: 4,
                    access: 40960,
                    action: AdaptationAction::Throttle,
                    hit_rate: 0.41,
                    predictor_version: 1,
                }),
            },
            TelemetryEvent {
                source: SourceId::serve(2),
                seq: 1,
                access: 49152,
                payload: Payload::Sample {
                    occupancy: 0.97,
                    hit_rate: 0.66,
                    pollution: 0.02,
                    throttled: true,
                },
            },
        ]
    }

    #[test]
    fn json_roundtrip_is_byte_exact() {
        for ev in sample_events() {
            let text = ev.to_json().to_string();
            let back = TelemetryEvent::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_json().to_string(), text);
            assert_eq!(back.source, ev.source);
            assert_eq!(back.seq, ev.seq);
        }
    }

    #[test]
    fn source_labels_roundtrip() {
        for s in [SourceId::sim(0), SourceId::sim(15), SourceId::serve(3), SourceId::tenant(1)] {
            assert_eq!(SourceId::parse(&s.label()).unwrap(), s);
        }
        assert!(SourceId::parse("bogus/1").is_err());
        assert!(SourceId::parse("sim").is_err());
    }

    #[test]
    fn ndjson_validation_accepts_valid_and_rejects_defects() {
        let good: String =
            sample_events().iter().map(|e| e.to_json().to_string() + "\n").collect();
        assert_eq!(validate_ndjson(&good).unwrap(), 4);
        // Blank lines are tolerated.
        assert_eq!(validate_ndjson(&format!("\n{good}\n")).unwrap(), 4);
        // Schema mismatch.
        assert!(validate_ndjson(r#"{"schema":"nope","type":"drift"}"#).is_err());
        // Truncated JSON.
        assert!(validate_ndjson(&good[..good.len() / 2]).is_err());
        // Non-monotone per-source seq.
        let ev = &sample_events()[1];
        let dup = format!("{}\n{}\n", ev.to_json().to_string(), ev.to_json().to_string());
        assert!(validate_ndjson(&dup).is_err());
    }
}
