//! Lock-free broadcast ring: bounded, drop-counting, zero-allocation
//! publish.
//!
//! The bus is a power-of-two array of seqlock-guarded slots over a single
//! monotone ticket counter (`tail`). A publisher claims ticket `t` with one
//! `fetch_add`, marks slot `t & mask` as *writing* (`2t+1`), memcpys the
//! `Copy` event in, and marks it *ready* (`2t+2`). No locks, no waiting, no
//! heap: a full ring overwrites the oldest slot instead of blocking the
//! simulation hot path (observation must never perturb the run).
//!
//! Subscribers are independent cursors. A subscriber that keeps up sees
//! every event in ticket order; one that falls more than a ring's capacity
//! behind loses the oldest events and *counts* them
//! ([`TelemetrySubscriber::dropped`]) — losses are always accounted, never
//! silent, and a torn slot (overwritten mid-read, detected by seq
//! revalidation) is likewise counted and skipped, never surfaced.
//!
//! Slot payload reads/writes use volatile copies guarded by the per-slot
//! sequence word (crossbeam's seqlock discipline): writers bump to odd
//! before touching the payload and to even after, readers validate the
//! sequence on both sides of the copy and discard racy reads.

use super::event::{SourceId, TelemetryEvent};
use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::Arc;

/// Default ring capacity (events). Enough to absorb a full adaptive sweep
/// cell's event stream without drops when the subscriber polls at any
/// human-scale interval.
pub const DEFAULT_CAPACITY: usize = 4096;

struct Slot {
    /// Seqlock word: `0` = never written; `2t+1` = ticket `t` being
    /// written; `2t+2` = ticket `t` ready.
    seq: AtomicU64,
    data: UnsafeCell<MaybeUninit<TelemetryEvent>>,
}

struct Inner {
    mask: u64,
    /// Next ticket to claim == total events ever published.
    tail: AtomicU64,
    slots: Box<[Slot]>,
}

// Slot payloads are `Copy` + `Send`; all cross-thread access is mediated by
// the seqlock words.
unsafe impl Send for Inner {}
unsafe impl Sync for Inner {}

/// The shared telemetry bus. Cheap to clone (an `Arc` around the ring);
/// create publishers with [`publisher`](Self::publisher) and cursors with
/// [`subscribe`](Self::subscribe).
#[derive(Clone)]
pub struct TelemetryBus {
    inner: Arc<Inner>,
}

impl Default for TelemetryBus {
    fn default() -> Self {
        Self::new()
    }
}

impl TelemetryBus {
    /// Bus with the [`DEFAULT_CAPACITY`].
    pub fn new() -> TelemetryBus {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    /// Bus holding at least `capacity` events (rounded up to a power of
    /// two, minimum 2).
    pub fn with_capacity(capacity: usize) -> TelemetryBus {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Vec<Slot> = (0..cap)
            .map(|_| Slot { seq: AtomicU64::new(0), data: UnsafeCell::new(MaybeUninit::uninit()) })
            .collect();
        TelemetryBus {
            inner: Arc::new(Inner {
                mask: cap as u64 - 1,
                tail: AtomicU64::new(0),
                slots: slots.into_boxed_slice(),
            }),
        }
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.inner.mask as usize + 1
    }

    /// Total events ever published (monotone; independent of subscribers).
    pub fn published(&self) -> u64 {
        self.inner.tail.load(Ordering::Acquire)
    }

    /// A publisher handle for one event source. The handle owns the
    /// source's monotone sequence counter, so create exactly one per
    /// (shard, worker, …) stream — two handles for the same source would
    /// interleave duplicate sequence numbers.
    pub fn publisher(&self, source: SourceId) -> TelemetryPublisher {
        TelemetryPublisher { inner: Arc::clone(&self.inner), source, seq: 0 }
    }

    /// A cursor starting at the current bus position (future events only).
    pub fn subscribe(&self) -> TelemetrySubscriber {
        TelemetrySubscriber {
            cursor: self.inner.tail.load(Ordering::Acquire),
            inner: Arc::clone(&self.inner),
            dropped: 0,
        }
    }
}

fn publish_inner(inner: &Inner, ev: TelemetryEvent) {
    let t = inner.tail.fetch_add(1, Ordering::AcqRel);
    let slot = &inner.slots[(t & inner.mask) as usize];
    slot.seq.store(2 * t + 1, Ordering::Relaxed);
    fence(Ordering::Release);
    // SAFETY: between the odd and even seq stores this writer owns the
    // payload; concurrent readers revalidate seq and discard torn copies,
    // and a lapped writer racing on the same slot resolves through the seq
    // word too (readers accept a slot only when seq exactly matches the
    // ticket they expect).
    unsafe { std::ptr::write_volatile((*slot.data.get()).as_mut_ptr(), ev) };
    slot.seq.store(2 * t + 2, Ordering::Release);
}

/// Write handle for one source's event stream. Not `Clone` — the per-source
/// sequence counter must have a single owner (see
/// [`TelemetryBus::publisher`]). `Send`, so shard/worker threads can own
/// theirs.
pub struct TelemetryPublisher {
    inner: Arc<Inner>,
    source: SourceId,
    seq: u64,
}

impl TelemetryPublisher {
    pub fn source(&self) -> SourceId {
        self.source
    }

    /// Events published through this handle so far (== the next seq).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Publish one payload, stamping the source identity and the next
    /// per-source sequence number. Never blocks, never allocates.
    pub fn publish(&mut self, access: u64, payload: super::event::Payload) {
        let ev = TelemetryEvent { source: self.source, seq: self.seq, access, payload };
        self.seq += 1;
        publish_inner(&self.inner, ev);
    }
}

/// Read cursor over the bus. Each subscriber advances independently;
/// falling behind loses the oldest events (counted in
/// [`dropped`](Self::dropped)), and the simulation is never back-pressured.
pub struct TelemetrySubscriber {
    inner: Arc<Inner>,
    /// Next ticket to read.
    cursor: u64,
    dropped: u64,
}

impl TelemetrySubscriber {
    /// Events this cursor has lost to ring wrap-around (bounded-buffer
    /// drop accounting).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Events currently available without blocking (approximate under
    /// concurrent publishing).
    pub fn backlog(&self) -> u64 {
        self.inner.tail.load(Ordering::Acquire).saturating_sub(self.cursor)
    }

    /// Next event, or `None` when caught up (or the next ticket is still
    /// being written). Skips over — and counts — events lost to wrap.
    pub fn poll(&mut self) -> Option<TelemetryEvent> {
        loop {
            let tail = self.inner.tail.load(Ordering::Acquire);
            if self.cursor >= tail {
                return None;
            }
            // More than a ring behind: the oldest backlog is gone.
            let cap = self.inner.mask + 1;
            if tail - self.cursor > cap {
                let skip = tail - cap - self.cursor;
                self.dropped += skip;
                self.cursor += skip;
            }
            let t = self.cursor;
            let slot = &self.inner.slots[(t & self.inner.mask) as usize];
            let ready = 2 * t + 2;
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == ready {
                // SAFETY: seq said ticket t is ready; the copy is validated
                // below — a concurrent overwrite flips seq first, so a
                // matching re-read proves the copy was not torn.
                let ev = unsafe { std::ptr::read_volatile((*slot.data.get()).as_ptr()) };
                fence(Ordering::Acquire);
                if slot.seq.load(Ordering::Relaxed) == ready {
                    self.cursor += 1;
                    return Some(ev);
                }
                // Overwritten mid-read: ticket t is lost.
                self.dropped += 1;
                self.cursor += 1;
            } else if s1 < ready {
                // Claimed but not yet ready (writer mid-flight).
                return None;
            } else {
                // A later ticket already owns the slot: t was lapped.
                self.dropped += 1;
                self.cursor += 1;
            }
        }
    }

    /// Drain everything currently available into `out`; returns the number
    /// of events appended.
    pub fn drain(&mut self, out: &mut Vec<TelemetryEvent>) -> usize {
        let mut n = 0;
        while let Some(ev) = self.poll() {
            out.push(ev);
            n += 1;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::Payload;

    fn sample(p: u64) -> Payload {
        Payload::Sample { occupancy: 1.0, hit_rate: p as f64, pollution: 0.0, throttled: false }
    }

    #[test]
    fn publish_poll_in_order_with_source_seqs() {
        let bus = TelemetryBus::with_capacity(64);
        let mut sub = bus.subscribe();
        let mut p = bus.publisher(SourceId::sim(0));
        for i in 0..10 {
            p.publish(i * 100, sample(i));
        }
        assert_eq!(bus.published(), 10);
        let mut got = Vec::new();
        sub.drain(&mut got);
        assert_eq!(got.len(), 10);
        for (i, ev) in got.iter().enumerate() {
            assert_eq!(ev.seq, i as u64);
            assert_eq!(ev.access, i as u64 * 100);
            assert_eq!(ev.source, SourceId::sim(0));
        }
        assert_eq!(sub.dropped(), 0);
        assert!(sub.poll().is_none());
    }

    #[test]
    fn slow_subscriber_drops_oldest_and_accounts() {
        let bus = TelemetryBus::with_capacity(8);
        let mut sub = bus.subscribe();
        let mut p = bus.publisher(SourceId::sim(0));
        for i in 0..100 {
            p.publish(i, sample(i));
        }
        let mut got = Vec::new();
        sub.drain(&mut got);
        assert_eq!(got.len(), 8, "only one ring's worth survives");
        assert_eq!(sub.dropped(), 92, "every lost event is counted");
        // The survivors are the newest, in order.
        let seqs: Vec<u64> = got.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (92..100).collect::<Vec<u64>>());
    }

    #[test]
    fn subscribers_are_independent_cursors() {
        let bus = TelemetryBus::with_capacity(32);
        let mut a = bus.subscribe();
        let mut p = bus.publisher(SourceId::sim(0));
        p.publish(0, sample(0));
        // b subscribes after the first event: sees only what follows.
        let mut b = bus.subscribe();
        p.publish(1, sample(1));
        let (mut va, mut vb) = (Vec::new(), Vec::new());
        a.drain(&mut va);
        b.drain(&mut vb);
        assert_eq!(va.len(), 2);
        assert_eq!(vb.len(), 1);
        assert_eq!(vb[0].seq, 1);
    }

    #[test]
    fn concurrent_publishers_lose_nothing_when_ring_is_big_enough() {
        let bus = TelemetryBus::with_capacity(4096);
        let mut sub = bus.subscribe();
        let threads = 4;
        let per = 500u64;
        std::thread::scope(|s| {
            for k in 0..threads {
                let mut p = bus.publisher(SourceId::sim(k));
                s.spawn(move || {
                    for i in 0..per {
                        p.publish(i, sample(i));
                    }
                });
            }
        });
        let mut got = Vec::new();
        sub.drain(&mut got);
        assert_eq!(got.len(), (threads as u64 * per) as usize);
        assert_eq!(sub.dropped(), 0);
        // Per-source streams are gapless and ordered even though the global
        // interleave is arbitrary.
        for k in 0..threads {
            let seqs: Vec<u64> =
                got.iter().filter(|e| e.source == SourceId::sim(k)).map(|e| e.seq).collect();
            assert_eq!(seqs, (0..per).collect::<Vec<u64>>(), "source {k}");
        }
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(TelemetryBus::with_capacity(100).capacity(), 128);
        assert_eq!(TelemetryBus::with_capacity(1).capacity(), 2);
        assert_eq!(TelemetryBus::new().capacity(), DEFAULT_CAPACITY);
    }

    #[test]
    fn raw_publish_preserves_stamped_event() {
        let bus = TelemetryBus::with_capacity(4);
        let mut sub = bus.subscribe();
        publish_inner(
            &bus.inner,
            TelemetryEvent { source: SourceId::serve(0), seq: 7, access: 1, payload: sample(1) },
        );
        assert_eq!(sub.poll().unwrap().seq, 7);
    }
}
