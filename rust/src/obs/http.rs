//! Dependency-free HTTP/JSON dashboard endpoint (`std::net` only).
//!
//! [`start_dashboard`] spawns one background thread that owns a
//! [`TelemetrySubscriber`]: it continuously drains the bus into a
//! [`MonitorState`] plus a bounded replay log, and answers plain HTTP/1.1
//! GETs:
//!
//! | route                 | body                                         |
//! |-----------------------|----------------------------------------------|
//! | `/health`             | `{"schema":"acpc-dashboard-v1","status":"ok",…}` |
//! | `/metrics.json`       | [`MonitorState::metrics_json`] (`acpc-metrics-v1`) |
//! | `/events?since=<n>`   | NDJSON replay of retained events with replay index ≥ n |
//!
//! The listener is non-blocking so one thread can interleave accepting
//! connections with draining the subscriber; requests are served serially
//! (this is an introspection port, not a serving path). Stop via
//! [`DashboardHandle::shutdown`], which drains once more and joins.

use super::aggregate::MonitorState;
use super::bus::TelemetrySubscriber;
use super::event::TelemetryEvent;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Retained events for `/events` replay; older events are discarded (the
/// replay index keeps counting, so clients detect the gap).
const EVENT_LOG_CAP: usize = 65536;

/// Schema tag served by `/health`.
pub const DASHBOARD_SCHEMA: &str = "acpc-dashboard-v1";

/// Handle to a running dashboard thread. Dropping without calling
/// [`shutdown`](Self::shutdown) detaches the thread (it stops at the next
/// poll tick after the flag is set by drop).
pub struct DashboardHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl DashboardHandle {
    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the server thread to stop, drain remaining events, and join.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for DashboardHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start the dashboard endpoint on `127.0.0.1:port` (port 0 picks a free
/// one), serving state folded from `sub`.
pub fn start_dashboard(port: u16, sub: TelemetrySubscriber) -> Result<DashboardHandle> {
    let listener = TcpListener::bind(("127.0.0.1", port))
        .with_context(|| format!("dashboard: bind 127.0.0.1:{port}"))?;
    listener.set_nonblocking(true).context("dashboard: set_nonblocking")?;
    let addr = listener.local_addr().context("dashboard: local_addr")?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let join = std::thread::Builder::new()
        .name("acpc-dashboard".into())
        .spawn(move || serve_loop(listener, sub, stop2))
        .context("dashboard: spawn server thread")?;
    Ok(DashboardHandle { addr, stop, join: Some(join) })
}

struct EventLog {
    /// Replay index of `buf[0]` (total events ever logged minus retained).
    base: u64,
    buf: std::collections::VecDeque<TelemetryEvent>,
}

impl EventLog {
    fn push(&mut self, ev: TelemetryEvent) {
        if self.buf.len() == EVENT_LOG_CAP {
            self.buf.pop_front();
            self.base += 1;
        }
        self.buf.push_back(ev);
    }

    fn ndjson_since(&self, since: u64) -> String {
        let skip = since.saturating_sub(self.base) as usize;
        let mut out = String::new();
        for ev in self.buf.iter().skip(skip) {
            out.push_str(&ev.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

fn serve_loop(listener: TcpListener, mut sub: TelemetrySubscriber, stop: Arc<AtomicBool>) {
    let mut state = MonitorState::new();
    let mut log = EventLog { base: 0, buf: std::collections::VecDeque::new() };
    let mut scratch = Vec::new();
    loop {
        let stopping = stop.load(Ordering::Acquire);
        scratch.clear();
        sub.drain(&mut scratch);
        for ev in &scratch {
            state.apply(ev);
            log.push(*ev);
        }
        state.dropped = sub.dropped();
        match listener.accept() {
            Ok((stream, _)) => {
                if let Err(e) = handle_conn(stream, &state, &log) {
                    crate::log_debug!("dashboard: connection error: {e:#}");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if stopping {
                    return; // drained once after the flag — safe to exit
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                crate::log_warn!("dashboard: accept error: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn handle_conn(mut stream: TcpStream, state: &MonitorState, log: &EventLog) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(2000)))?;
    let mut buf = [0u8; 2048];
    let mut req = Vec::new();
    // Read until the end of the request head (we ignore any body).
    loop {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        req.extend_from_slice(&buf[..n]);
        if req.windows(4).any(|w| w == b"\r\n\r\n") || req.len() > 16 * 1024 {
            break;
        }
    }
    let head = String::from_utf8_lossy(&req);
    let line = head.lines().next().unwrap_or_default();
    let mut parts = line.split_whitespace();
    let (method, target) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return respond(&mut stream, 405, "text/plain", "method not allowed\n");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target, None),
    };
    match path {
        "/health" => {
            let body = Json::from_pairs(vec![
                ("schema", Json::Str(DASHBOARD_SCHEMA.into())),
                ("status", Json::Str("ok".into())),
                ("events", Json::Num(state.events as f64)),
                ("dropped", Json::Num(state.dropped as f64)),
                ("sources", Json::Num(state.sources().count() as f64)),
            ]);
            respond(&mut stream, 200, "application/json", &(body.to_string() + "\n"))
        }
        "/metrics.json" => {
            let body = state.metrics_json().to_pretty() + "\n";
            respond(&mut stream, 200, "application/json", &body)
        }
        "/events" => {
            let since = query
                .and_then(|q| {
                    q.split('&').find_map(|kv| kv.strip_prefix("since=")).map(str::parse::<u64>)
                })
                .transpose()
                .map_err(|_| anyhow!("bad since= value"));
            match since {
                Ok(since) => respond(
                    &mut stream,
                    200,
                    "application/x-ndjson",
                    &log.ndjson_since(since.unwrap_or(0)),
                ),
                Err(_) => respond(&mut stream, 400, "text/plain", "bad since= value\n"),
            }
        }
        _ => respond(&mut stream, 404, "text/plain", "not found\n"),
    }
}

fn respond(stream: &mut TcpStream, code: u16, ctype: &str, body: &str) -> Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\ncontent-type: {ctype}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Minimal HTTP GET returning the response body (the `acpc monitor
/// --attach` client; also the CI smoke check's fallback to `curl`).
pub fn http_get(addr: &str, path: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    write!(stream, "GET {path} HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\r\n")?;
    stream.flush()?;
    let mut resp = Vec::new();
    stream.read_to_end(&mut resp)?;
    let text = String::from_utf8_lossy(&resp);
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("malformed HTTP response from {addr}{path}"))?;
    let status = head.split_whitespace().nth(1).unwrap_or("");
    if status != "200" {
        bail!("GET {addr}{path}: HTTP {status}");
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::WindowStats;
    use crate::obs::event::{Payload, SourceId};
    use crate::obs::TelemetryBus;

    fn publish_windows(bus: &TelemetryBus, n: u64) {
        let mut p = bus.publisher(SourceId::sim(0));
        for i in 0..n {
            p.publish(
                (i + 1) * 8192,
                Payload::Window {
                    stats: WindowStats {
                        index: i,
                        accesses: 8192,
                        l2_demand: 100,
                        hit_rate: 0.5,
                        pollution: 0.1,
                        prefetch_accuracy: 0.5,
                        reuse_p50_log2: 8,
                    },
                    throttled: false,
                },
            );
        }
    }

    #[test]
    fn dashboard_serves_health_metrics_and_events() {
        let bus = TelemetryBus::new();
        let handle = start_dashboard(0, bus.subscribe()).unwrap();
        let addr = handle.addr().to_string();
        publish_windows(&bus, 5);

        // The server drains asynchronously; retry briefly until folded.
        let mut health = Json::Null;
        for _ in 0..100 {
            let body = http_get(&addr, "/health").unwrap();
            health = Json::parse(body.trim()).unwrap();
            if health.get("events").and_then(Json::as_f64) == Some(5.0) {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(health.get("schema").unwrap().as_str(), Some(DASHBOARD_SCHEMA));
        assert_eq!(health.get("events").unwrap().as_f64(), Some(5.0));

        let metrics = Json::parse(http_get(&addr, "/metrics.json").unwrap().trim()).unwrap();
        assert_eq!(metrics.get("schema").unwrap().as_str(), Some("acpc-metrics-v1"));
        assert_eq!(metrics.get("sources").unwrap().as_arr().unwrap().len(), 1);

        let ndjson = http_get(&addr, "/events?since=0").unwrap();
        assert_eq!(crate::obs::validate_ndjson(&ndjson).unwrap(), 5);
        let tail = http_get(&addr, "/events?since=3").unwrap();
        assert_eq!(crate::obs::validate_ndjson(&tail).unwrap(), 2);

        assert!(http_get(&addr, "/nope").is_err());
        handle.shutdown();
    }

    #[test]
    fn event_log_replay_indexing() {
        let mut log = EventLog { base: 0, buf: std::collections::VecDeque::new() };
        let mk = |seq| TelemetryEvent {
            source: SourceId::sim(0),
            seq,
            access: seq,
            payload: Payload::Drift { window: seq },
        };
        for i in 0..10 {
            log.push(mk(i));
        }
        assert_eq!(log.ndjson_since(0).lines().count(), 10);
        assert_eq!(log.ndjson_since(7).lines().count(), 3);
        assert_eq!(log.ndjson_since(99).lines().count(), 0);
    }
}
