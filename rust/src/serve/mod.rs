//! Tenant-aware serving core: spec-driven QoS serving on top of the cache
//! simulator.
//!
//! The classic [`crate::coordinator`] answers "how fast does a threaded
//! serving node go?" — wall-clock workers, load-balancing router, one
//! anonymous stream of sessions. This subsystem answers the *multi-tenant*
//! question the paper's pollution-control story leads to: when several
//! tenants with different traffic shapes share one cache hierarchy, who
//! gets hurt, and what does admission-level QoS buy?
//!
//! Three pieces, each its own module:
//!
//! - [`spec`] — [`ServeSpec`] (schema [`SERVE_SPEC_SCHEMA`]), the
//!   JSON-round-trippable description of a run: workers, workload
//!   template, hierarchy, router geometry, arbiter thresholds, and one
//!   block per tenant (arrival process, token-bucket contract, optional
//!   worker pin). Resolution follows the `acpc-run-v1` discipline: all
//!   validation at the boundary, and the resolved spec — every default
//!   made explicit — is embedded in the report for bit-for-bit replay.
//! - [`router`] — [`SessionRouter`], consistent-hash session → tenant →
//!   worker placement with per-tenant pinning. Placement is a pure
//!   function of identity and seed, not of load.
//! - [`admission`] — per-tenant [`TokenBucket`] rate contracts plus the
//!   [`Arbiter`], an LLaMCAT-style noisy-neighbor throttle scoring
//!   tenants each window on miss share, inflicted prefetch pollution, and
//!   reuse distance.
//!
//! [`engine`] (entrypoint [`run`]) executes a resolved spec on a
//! single-threaded virtual-tick loop — fully seed-deterministic, with
//! per-tenant cache attribution, telemetry-bus streaming (`serve/w` and
//! `tenant/t` sources feed the dashboard's `/metrics.json`), and optional
//! v2 trace capture stamped with real tenant ids. It fills the same
//! [`crate::coordinator::ServeReport`] the classic path produces, plus
//! per-tenant [`TenantReport`] blocks and the embedded resolved spec.

pub mod admission;
pub mod engine;
pub mod router;
pub mod spec;

pub use admission::{
    Arbiter, ArbiterConfig, ArbiterDecision, TenantCounters, TenantWindow, TokenBucket,
};
pub use engine::{run, run_with_bus, TenantReport, TENANT_STRIDE};
pub use router::{SessionRouter, MAX_WORKERS};
pub use spec::{
    ArbiterSpec, ResolvedServe, ResolvedTenant, RouterSpec, ServeSpec, ServeSpecBuilder,
    TenantSpec, MAX_TENANTS, SERVE_SPEC_SCHEMA,
};
