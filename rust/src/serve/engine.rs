//! The tenant-aware QoS serve engine: deterministic, virtual-tick
//! execution of a [`ServeSpec`].
//!
//! The classic coordinator ([`crate::coordinator::serve`]) is wall-clock
//! threaded — faithful to a live serving node, but its counters race
//! arrivals and cannot be reproduced bit-for-bit. This engine is the
//! spec-driven complement: one thread, virtual ticks, every random draw
//! seeded, so per-tenant admission counters and cache attribution are
//! identical across reruns of the same resolved spec.
//!
//! Per tick:
//!
//! 1. **Arrivals** — each tenant's [`ArrivalProcess`] samples new sessions.
//!    An arrival is *offered*; it is *shed* immediately when the tenant's
//!    token bucket is dry or its admission queue is full, else it queues.
//! 2. **Admission** — queued sessions route via the consistent-hash
//!    [`SessionRouter`] (per-tenant pins honored, full workers walked
//!    past) onto per-(worker, tenant) generator slots. A tenant the
//!    arbiter throttled defers — its queue simply waits.
//! 3. **Service** — each worker drives `quantum` accesses through its
//!    [`Engine`], split across tenants in proportion to their live
//!    sessions. KV/scratch addresses are rebased per tenant by
//!    [`TENANT_STRIDE`] so tenants contend for cache *capacity* without
//!    aliasing each other's lines. L2 counter deltas around each access
//!    attribute hits, misses, and dead prefetch fills to the serving
//!    tenant; a per-(worker, tenant) [`ReuseSketch`] histograms reuse.
//! 4. **Arbitration** — every `window_ticks`, the [`Arbiter`] scores
//!    tenants on their windowed telemetry and throttles the noisiest
//!    (see [`super::admission`]); per-tenant `Sample` events go to the
//!    telemetry bus (source `tenant/t`) next to the per-worker `serve/w`
//!    stream.
//!
//! After the arrival horizon (`ticks`) the engine stops admitting and
//! drains in-flight sessions; whatever is still queued then is *deferred*.
//! Every offered session thus lands in exactly one of admitted/shed/
//! deferred — [`TenantCounters::reconcile`] audits this before the report
//! serializes.
//!
//! In the produced [`ServeReport`], `adapt_windows` counts arbitration
//! windows, `throttled_windows` counts windows with a tenant throttled,
//! and `session_latency_ms_*` are zero (queueing delay is reported
//! per-tenant in ticks instead — virtual time has no milliseconds).

use super::admission::{Arbiter, TenantCounters, TenantWindow, TokenBucket};
use super::router::SessionRouter;
use super::spec::{ResolvedServe, ServeSpec, MAX_TENANTS};
use crate::adapt::telemetry::ReuseSketch;
use crate::config::PredictorKind;
use crate::coordinator::ServeReport;
use crate::obs::{Payload, SourceId, TelemetryBus, TelemetryPublisher, SAMPLE_PERIOD};
use crate::predictor::{GeometryHints, HeuristicPredictor, ReusePredictor};
use crate::sim::{Engine, PredictionBatch};
use crate::trace::{region, Access, TraceGenerator};
use crate::traffic::{ArrivalProcess, CaptureSink};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// Address-space stride separating tenants inside the KV and scratch
/// regions. Region tags live at bit [`region::SHIFT`] (40); with at most
/// [`MAX_TENANTS`] (8) tenants the largest rebase offset is `9 × 2^36 <
/// 2^40`, so rebased addresses never cross into the next region, while
/// realistic per-tenant footprints stay far below the stride.
pub const TENANT_STRIDE: u64 = 1 << 36;

/// Rebase one access into `tenant`'s private KV/scratch address space.
/// Embedding and weight regions are genuinely shared between tenants (same
/// model), so they keep their addresses — constructive sharing stays,
/// capacity contention stays, aliasing of private state goes.
fn rebase(mut a: Access, tenant: usize) -> Access {
    let r = region::of(a.addr);
    if r == region::of(region::KV) || r == region::of(region::SCRATCH) {
        a.addr += (tenant as u64 + 1) * TENANT_STRIDE;
    }
    a
}

/// One tenant's slice of the final report.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    /// Sessions the arrival process generated.
    pub offered: u64,
    /// Sessions placed on a worker.
    pub admitted: u64,
    /// Sessions dropped (token bucket dry or queue full at arrival).
    pub shed: u64,
    /// Sessions still queued when the run drained (never admitted).
    pub deferred: u64,
    pub completed: u64,
    pub tokens: u64,
    /// L2 demand accesses attributed to this tenant.
    pub accesses: u64,
    pub l2_hit_rate: f64,
    pub l2_pollution_ratio: f64,
    /// Median log2 reuse-distance bucket over the whole run.
    pub reuse_p50_log2: Option<u8>,
    pub queue_delay_mean_ticks: f64,
    pub queue_delay_max_ticks: u64,
    /// Arbitration windows this tenant spent throttled.
    pub throttled_windows: u64,
}

impl TenantReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::from_pairs(vec![
            ("name", Json::Str(self.name.clone())),
            ("offered", Json::Num(self.offered as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("deferred", Json::Num(self.deferred as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("accesses", Json::Num(self.accesses as f64)),
            ("l2_hit_rate", Json::Num(self.l2_hit_rate)),
            ("l2_pollution_ratio", Json::Num(self.l2_pollution_ratio)),
            ("queue_delay_mean_ticks", Json::Num(self.queue_delay_mean_ticks)),
            ("queue_delay_max_ticks", Json::Num(self.queue_delay_max_ticks as f64)),
            ("throttled_windows", Json::Num(self.throttled_windows as f64)),
        ]);
        if let Some(b) = self.reuse_p50_log2 {
            j.set("reuse_p50_log2", Json::Num(b as f64));
        }
        j
    }
}

/// Run a serve spec to completion (resolves, drives, reports).
pub fn run(spec: &ServeSpec) -> Result<ServeReport> {
    run_with_bus(spec, None)
}

/// [`run`], streaming telemetry (sources `serve/w` and `tenant/t`) onto
/// `bus`; when the spec asks for a dashboard and no bus is supplied, an
/// internal one feeds the HTTP endpoint, mirroring the classic
/// coordinator's behavior.
pub fn run_with_bus(spec: &ServeSpec, bus: Option<&TelemetryBus>) -> Result<ServeReport> {
    let resolved = spec.resolve()?;
    let internal_bus =
        (bus.is_none() && resolved.dashboard_port.is_some()).then(TelemetryBus::new);
    let bus = bus.or(internal_bus.as_ref());
    let dashboard = resolved.dashboard_port.and_then(|port| {
        let sub = bus.expect("dashboard_port implies a bus").subscribe();
        match crate::obs::start_dashboard(port, sub) {
            Ok(h) => {
                crate::log_info!("dashboard: listening on http://{}/", h.addr());
                Some(h)
            }
            Err(e) => {
                crate::log_warn!("dashboard: disabled: {e:#}");
                None
            }
        }
    });
    let report = drive(&resolved, bus);
    if let Some(dash) = dashboard {
        if !resolved.dashboard_linger.is_zero() {
            crate::log_info!(
                "dashboard: run drained; lingering {:?} at http://{}/",
                resolved.dashboard_linger,
                dash.addr()
            );
            std::thread::sleep(resolved.dashboard_linger);
        }
        dash.shutdown();
    }
    report
}

struct WorkerSlot {
    engine: Engine,
    /// One generator per tenant: session slots (KV capacity) are a
    /// per-(worker, tenant) resource, so a noisy tenant can exhaust its
    /// own slots but never a neighbor's.
    gens: Vec<TraceGenerator>,
    /// Per-tenant reuse sketches (positions are this worker's monotone
    /// access counter; merged per tenant at window close).
    sketches: Vec<ReuseSketch>,
    /// Per-tenant `sessions_completed` watermark.
    completed_seen: Vec<u64>,
    batch: PredictionBatch,
}

struct TenantState {
    process: ArrivalProcess,
    bucket: Option<TokenBucket>,
    /// Enqueue tick of each waiting session (FIFO).
    queue: VecDeque<u64>,
    queue_depth: usize,
    counters: TenantCounters,
    /// Session key counter — the router input, so placement is a pure
    /// function of (tenant, admission ordinal).
    admit_seq: u64,
    /// Total accesses served (all levels; capture ordinal + bus stamp).
    served: u64,
    /// Current-window L2 attribution deltas.
    window: TenantWindow,
    /// Whole-run L2 attribution totals.
    cum: TenantWindow,
    /// Whole-run merged reuse histogram.
    cum_sketch: ReuseSketch,
    completed: u64,
    queue_delay_sum: u64,
    queue_delay_max: u64,
    throttled_windows: u64,
}

fn drive(r: &ResolvedServe, bus: Option<&TelemetryBus>) -> Result<ServeReport> {
    let t0 = Instant::now();
    let nt = r.tenants.len();
    let use_pred = r.predictor == PredictorKind::Heuristic;
    let window = if use_pred { 1 } else { 0 };

    let mut workers: Vec<WorkerSlot> = (0..r.workers)
        .map(|w| {
            let geom = GeometryHints::from_generator(&r.generator);
            let engine = Engine::new(r.hierarchy.clone(), &r.policy, geom, window);
            let row = engine.row();
            let gens = (0..nt)
                .map(|t| {
                    let mut g = r.generator.clone();
                    // Independent per-(worker, tenant) content streams off
                    // the template seed (splitmix odd-constant spacing).
                    g.seed = r.generator.seed.wrapping_add(
                        ((w * MAX_TENANTS + t) as u64 + 1)
                            .wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    TraceGenerator::new(g)
                })
                .collect();
            WorkerSlot {
                engine,
                gens,
                sketches: (0..nt).map(|_| ReuseSketch::new(1 << 14)).collect(),
                completed_seen: vec![0; nt],
                batch: PredictionBatch::new(row, r.predict_batch),
            }
        })
        .collect();

    let mut tenants: Vec<TenantState> = r
        .tenants
        .iter()
        .map(|t| TenantState {
            process: ArrivalProcess::new(t.arrivals.clone()),
            bucket: t.bucket.map(|(rate, burst)| TokenBucket::new(rate, burst)),
            queue: VecDeque::new(),
            queue_depth: t.arrivals.queue_depth,
            counters: TenantCounters::default(),
            admit_seq: 0,
            served: 0,
            window: TenantWindow::default(),
            cum: TenantWindow::default(),
            cum_sketch: ReuseSketch::new(1 << 14),
            completed: 0,
            queue_delay_sum: 0,
            queue_delay_max: 0,
            throttled_windows: 0,
        })
        .collect();

    let mut router = SessionRouter::new(r.workers, r.vnodes, r.seed, r.pins());
    let mut arbiter = Arbiter::new(r.arbiter.clone(), r.arbiter_enabled);
    let mut heuristic = HeuristicPredictor;
    let mut sink = r.capture.is_some().then(CaptureSink::new);

    let mut worker_pubs: Vec<Option<TelemetryPublisher>> = (0..r.workers)
        .map(|w| bus.map(|b| b.publisher(SourceId::serve(w))))
        .collect();
    let mut tenant_pubs: Vec<Option<TelemetryPublisher>> = (0..nt)
        .map(|t| bus.map(|b| b.publisher(SourceId::tenant(t))))
        .collect();

    let mut pred_batches = 0u64;
    let mut pred_filled = 0u64;
    let mut max_imbalance = 0u64;

    // Hard bound on the drain phase: sessions are finite, so this only
    // trips if service stalls entirely (a bug, not a workload property).
    let drain_deadline = r.ticks.saturating_mul(16).saturating_add(1_000_000);
    let mut tick = 0u64;
    loop {
        let arrivals_open = tick < r.ticks;

        if arrivals_open {
            for ts in tenants.iter_mut() {
                if let Some(b) = &mut ts.bucket {
                    b.tick();
                }
                // Offered → shed (bucket dry / queue full) or queued.
                for _ in 0..ts.process.step(tick) {
                    ts.counters.offered += 1;
                    let has_token =
                        ts.bucket.as_mut().map(|b| b.try_take()).unwrap_or(true);
                    if !has_token || ts.queue.len() >= ts.queue_depth {
                        ts.counters.shed += 1;
                    } else {
                        ts.queue.push_back(tick);
                    }
                }
            }
            // Admission, start tenant rotated per tick for fairness.
            for k in 0..nt {
                let ti = (tick as usize + k) % nt;
                while !tenants[ti].queue.is_empty() {
                    if arbiter.throttled(ti) {
                        break; // defer: the queue waits the window out
                    }
                    let key = tenants[ti].admit_seq;
                    let w = {
                        let avail = |w: usize| workers[w].gens[ti].free_slots() > 0;
                        router.route(ti, key, &avail)
                    };
                    let Some(w) = w else {
                        break; // no slot anywhere (or pin full): wait
                    };
                    let enq = tenants[ti].queue.pop_front().expect("checked non-empty");
                    let placed = workers[w].gens[ti].force_arrival();
                    debug_assert!(placed, "router probed free_slots");
                    router.admit(w);
                    max_imbalance = max_imbalance.max(router.imbalance());
                    let ts = &mut tenants[ti];
                    ts.counters.admitted += 1;
                    ts.admit_seq += 1;
                    let delay = tick - enq;
                    ts.queue_delay_sum += delay;
                    ts.queue_delay_max = ts.queue_delay_max.max(delay);
                }
            }
        }

        // Service: each worker spends `quantum` accesses, split across
        // tenants in proportion to live sessions (integer shares, the
        // remainder rotating with the tick).
        for w in 0..r.workers {
            let lives: Vec<u64> =
                workers[w].gens.iter().map(|g| g.live_sessions() as u64).collect();
            let total_live: u64 = lives.iter().sum();
            if total_live == 0 {
                continue;
            }
            let mut alloc: Vec<u64> =
                lives.iter().map(|&l| r.quantum * l / total_live).collect();
            let mut rem = r.quantum - alloc.iter().sum::<u64>();
            let mut k = 0usize;
            while rem > 0 {
                let ti = (tick as usize + k) % nt;
                if lives[ti] > 0 {
                    alloc[ti] += 1;
                    rem -= 1;
                }
                k += 1;
            }
            for k in 0..nt {
                let ti = (tick as usize + k) % nt;
                for _ in 0..alloc[ti] {
                    if !workers[w].gens[ti].has_work() {
                        break;
                    }
                    let ws = &mut workers[w];
                    let a = rebase(ws.gens[ti].next_access(), ti);
                    if let Some(s) = sink.as_mut() {
                        s.record(a, ti as u32, tenants[ti].served);
                    }
                    let before = {
                        let s = &ws.engine.hier.l2.stats;
                        (
                            s.demand_accesses,
                            s.demand_hits,
                            s.demand_misses,
                            s.demand_misses + s.prefetch_fills,
                            s.dead_prefetch_evictions,
                        )
                    };
                    let pos = ws.engine.steps();
                    let full = match ws.engine.step(&a, None) {
                        Some(feats) => ws.batch.push(a.line(), feats),
                        None => false,
                    };
                    if full {
                        let (lines, x) = ws.batch.take();
                        let n = lines.len();
                        let probs = heuristic.predict(&x, n);
                        for (&line, &p) in lines.iter().zip(probs.iter()) {
                            ws.engine.update_utility(line, p);
                        }
                        pred_batches += 1;
                        pred_filled += n as u64;
                    }
                    ws.sketches[ti].touch(pos, a.line());
                    let s = &ws.engine.hier.l2.stats;
                    let ts = &mut tenants[ti];
                    ts.served += 1;
                    for acc in [&mut ts.window, &mut ts.cum] {
                        acc.accesses += s.demand_accesses - before.0;
                        acc.hits += s.demand_hits - before.1;
                        acc.misses += s.demand_misses - before.2;
                        acc.fills += s.demand_misses + s.prefetch_fills - before.3;
                        acc.dead_fills += s.dead_prefetch_evictions - before.4;
                    }
                    if ws.engine.steps() % SAMPLE_PERIOD == 0 {
                        if let Some(p) = worker_pubs[w].as_mut() {
                            let l2 = &ws.engine.hier.l2;
                            p.publish(
                                ws.engine.steps(),
                                Payload::Sample {
                                    occupancy: l2.occupancy(),
                                    hit_rate: l2.stats.hit_rate(),
                                    pollution: l2.stats.pollution_ratio(),
                                    throttled: false,
                                },
                            );
                        }
                    }
                }
            }
            // Completions free router load and per-tenant slots.
            for ti in 0..nt {
                let done = workers[w].gens[ti].sessions_completed();
                let seen = workers[w].completed_seen[ti];
                if done > seen {
                    workers[w].completed_seen[ti] = done;
                    tenants[ti].completed += done - seen;
                    for _ in 0..(done - seen) {
                        router.complete(w);
                    }
                }
            }
        }

        // Arbitration window boundary.
        if (tick + 1) % r.window_ticks == 0 {
            let mut wins = Vec::with_capacity(nt);
            for (ti, ts) in tenants.iter_mut().enumerate() {
                let mut merged = ReuseSketch::new(0);
                for ws in workers.iter() {
                    merged.absorb(&ws.sketches[ti]);
                }
                ts.cum_sketch.absorb(&merged);
                let mut win = ts.window;
                win.from_sketch(&merged);
                wins.push(win);
                for ws in workers.iter_mut() {
                    ws.sketches[ti].reset_window();
                }
            }
            arbiter.close_window(&wins);
            let total: u64 = wins.iter().map(|w| w.accesses).sum();
            for (ti, ts) in tenants.iter_mut().enumerate() {
                let throttled = arbiter.throttled(ti);
                if throttled {
                    ts.throttled_windows += 1;
                }
                if let Some(p) = tenant_pubs[ti].as_mut() {
                    let w = &wins[ti];
                    let ratio = |num: u64, den: u64| {
                        if den == 0 {
                            0.0
                        } else {
                            num as f64 / den as f64
                        }
                    };
                    p.publish(
                        ts.served,
                        Payload::Sample {
                            occupancy: ratio(w.accesses, total),
                            hit_rate: ratio(w.hits, w.accesses),
                            pollution: ratio(w.dead_fills, w.fills),
                            throttled,
                        },
                    );
                }
                ts.window = TenantWindow::default();
            }
        }

        tick += 1;
        if !arrivals_open {
            let busy = workers.iter().any(|ws| ws.gens.iter().any(|g| g.has_work()));
            if !busy {
                break;
            }
            if tick >= drain_deadline {
                crate::log_warn!("serve engine: drain deadline hit at tick {tick}");
                break;
            }
        }
    }

    // Terminal disposition of everything still queued.
    for ts in tenants.iter_mut() {
        ts.counters.deferred += ts.queue.len() as u64;
        ts.queue.clear();
    }

    let mut tenant_reports = Vec::with_capacity(nt);
    for (ti, ts) in tenants.iter().enumerate() {
        ts.counters
            .reconcile()
            .map_err(|e| anyhow!("tenant '{}': {e}", r.tenants[ti].name))?;
        let tokens: u64 = workers.iter().map(|ws| ws.gens[ti].tokens_done()).sum();
        let c = &ts.cum;
        tenant_reports.push(TenantReport {
            name: r.tenants[ti].name.clone(),
            offered: ts.counters.offered,
            admitted: ts.counters.admitted,
            shed: ts.counters.shed,
            deferred: ts.counters.deferred,
            completed: ts.completed,
            tokens,
            accesses: c.accesses,
            l2_hit_rate: c.hits as f64 / c.accesses.max(1) as f64,
            l2_pollution_ratio: c.dead_fills as f64 / c.fills.max(1) as f64,
            reuse_p50_log2: ts.cum_sketch.p50_bucket(),
            queue_delay_mean_ticks: ts.queue_delay_sum as f64
                / ts.counters.admitted.max(1) as f64,
            queue_delay_max_ticks: ts.queue_delay_max,
            throttled_windows: ts.throttled_windows,
        });
    }

    let tokens: u64 =
        workers.iter().flat_map(|ws| ws.gens.iter().map(|g| g.tokens_done())).sum();
    let accesses: u64 = workers.iter().map(|ws| ws.engine.hier.accesses).sum();
    let (l2_hits, l2_acc, l2_fills, l2_dead) =
        workers.iter().fold((0u64, 0u64, 0u64, 0u64), |acc, ws| {
            let s = &ws.engine.hier.l2.stats;
            (
                acc.0 + s.demand_hits,
                acc.1 + s.demand_accesses,
                acc.2 + s.demand_misses + s.prefetch_fills,
                acc.3 + s.dead_prefetch_evictions,
            )
        });
    let completed: u64 = tenant_reports.iter().map(|t| t.completed).sum();

    if let (Some(s), Some(path)) = (sink.as_mut(), r.capture.as_ref()) {
        s.set_totals(tokens, completed);
        match s.finish(path) {
            Ok(()) => crate::log_info!(
                "capture: wrote {} accesses to {}",
                s.len(),
                path.display()
            ),
            Err(e) => crate::log_warn!("capture: {}: {e:#}", path.display()),
        }
    }

    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    Ok(ServeReport {
        sessions_admitted: tenant_reports.iter().map(|t| t.admitted).sum(),
        sessions_completed: completed,
        sessions_rejected: tenant_reports.iter().map(|t| t.shed).sum(),
        tokens,
        accesses,
        wall_secs: wall,
        tokens_per_sec_wall: tokens as f64 / wall,
        l2_hit_rate: l2_hits as f64 / l2_acc.max(1) as f64,
        l2_pollution_ratio: l2_dead as f64 / l2_fills.max(1) as f64,
        session_latency_ms_p50: 0.0,
        session_latency_ms_p95: 0.0,
        prediction_batches: pred_batches,
        mean_batch_fill: if pred_batches > 0 {
            pred_filled as f64 / pred_batches as f64
        } else {
            0.0
        },
        router_imbalance_max: max_imbalance as usize,
        adapt_windows: arbiter.decisions.len() as u64,
        drift_events: 0,
        throttled_windows: arbiter.throttled_windows(),
        adaptation_events: Vec::new(),
        tenants: tenant_reports,
        serve_spec: Some(r.spec.to_json()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::spec::TenantSpec;

    fn small_spec() -> ServeSpec {
        ServeSpec::builder()
            .workers(2)
            .ticks(3_000)
            .window_ticks(500)
            .seed(0xBEEF)
            .tenant(TenantSpec {
                arrivals: Some("bursty".into()),
                rate: Some(10.0),
                queue_depth: Some(4),
                ..TenantSpec::new("noisy")
            })
            .tenant(TenantSpec { rate: Some(2.0), ..TenantSpec::new("quiet") })
            .build()
            .unwrap()
    }

    #[test]
    fn rebase_isolates_kv_but_shares_weights() {
        let kv = Access {
            time: 0,
            addr: region::KV + 0x400,
            pc: 0,
            kind: crate::trace::StreamKind::KvRead,
            session: 0,
            ctx_len: 0,
            layer: 0,
            is_write: false,
        };
        let w = Access { addr: region::WEIGHT + 0x400, ..kv };
        assert_ne!(rebase(kv, 0).addr, rebase(kv, 1).addr);
        assert_eq!(rebase(w, 0).addr, rebase(w, 1).addr, "weights are shared");
        for t in 0..MAX_TENANTS {
            assert_eq!(
                region::of(rebase(kv, t).addr),
                region::of(region::KV),
                "rebase must stay inside the region"
            );
        }
    }

    #[test]
    fn engine_runs_reconciles_and_reproduces() {
        let spec = small_spec();
        let a = run(&spec).unwrap();
        let b = run(&spec).unwrap();
        assert_eq!(a.tenants.len(), 2);
        let offered: u64 = a.tenants.iter().map(|t| t.offered).sum();
        assert!(offered > 0, "arrivals must flow");
        assert!(a.sessions_admitted > 0);
        assert!(a.accesses > 0);
        for (x, y) in a.tenants.iter().zip(b.tenants.iter()) {
            assert_eq!(x.offered, y.offered, "{}", x.name);
            assert_eq!(x.admitted, y.admitted, "{}", x.name);
            assert_eq!(x.shed, y.shed, "{}", x.name);
            assert_eq!(x.deferred, y.deferred, "{}", x.name);
            assert_eq!(x.accesses, y.accesses, "{}", x.name);
            assert_eq!(x.tokens, y.tokens, "{}", x.name);
            assert_eq!(x.offered, x.admitted + x.shed + x.deferred, "{}", x.name);
        }
        assert_eq!(a.accesses, b.accesses, "whole run is seed-deterministic");
        // The report embeds the resolved spec, which re-resolves.
        let j = a.to_json();
        let embedded = j.get("serve_spec").expect("resolved spec embedded");
        let back = ServeSpec::from_json(embedded).unwrap();
        assert!(back.resolve().is_ok());
        assert_eq!(back.workers, Some(2));
        assert_eq!(
            j.get("tenants").and_then(|t| t.as_arr()).map(|a| a.len()),
            Some(2)
        );
    }

    #[test]
    fn bucket_caps_admissions() {
        let base = ServeSpec::builder()
            .workers(1)
            .ticks(2_000)
            .seed(7)
            .tenant(TenantSpec { rate: Some(20.0), ..TenantSpec::new("t") })
            .build()
            .unwrap();
        let capped = ServeSpec::builder()
            .workers(1)
            .ticks(2_000)
            .seed(7)
            .tenant(TenantSpec {
                rate: Some(20.0),
                // ~1 admission per 500 ticks: far below the offered rate.
                bucket_rate: Some(0.002),
                bucket_burst: Some(1.0),
                ..TenantSpec::new("t")
            })
            .build()
            .unwrap();
        let a = run(&base).unwrap();
        let b = run(&capped).unwrap();
        assert_eq!(
            a.tenants[0].offered, b.tenants[0].offered,
            "same seed, same arrivals"
        );
        assert!(
            b.tenants[0].admitted < a.tenants[0].admitted,
            "bucket must bite: {} vs {}",
            b.tenants[0].admitted,
            a.tenants[0].admitted
        );
        assert!(b.tenants[0].shed > a.tenants[0].shed);
    }
}
