//! [`SessionRouter`] — session → tenant → worker placement by consistent
//! hashing, with explicit per-tenant pinning.
//!
//! The coordinator's classic [`crate::coordinator::Router`] balances purely
//! on load and knows nothing about tenants; every admission decision is a
//! fresh one. The session router instead makes placement a *pure function
//! of identity*: each worker owns `vnodes` pseudo-random points on a hashed
//! ring (seeded, so the ring is identical across reruns), and a session
//! hashes to the first point clockwise of `hash(tenant, session_key)`.
//! Tenants therefore concentrate on stable worker subsets (warm caches,
//! reproducible placement) instead of being sprayed wherever load happens
//! to be lowest, and a tenant can be *pinned* to one worker outright for
//! hard isolation.
//!
//! Capacity is the caller's business: [`SessionRouter::route`] takes an
//! `available` probe so per-(worker, tenant) session slots stay where they
//! live (the worker's workload), and the router walks the ring past full
//! workers. Pinned tenants never fail over — a full pinned worker defers
//! the admission instead, which is exactly the isolation the pin asked for.

use crate::util::rng::SplitMix64;

/// Maximum workers a router can place onto (ring-walk bookkeeping uses a
/// u64 bitmask).
pub const MAX_WORKERS: usize = 64;

/// Seeded consistent-hash placement of sessions onto workers.
#[derive(Debug, Clone)]
pub struct SessionRouter {
    /// `(point, worker)` sorted by point; each worker owns `vnodes` points.
    ring: Vec<(u64, u32)>,
    /// Per-tenant pin override (worker index), indexed by tenant id.
    pins: Vec<Option<u32>>,
    /// Live sessions per worker (admit/complete), for the imbalance metric.
    load: Vec<u64>,
    workers: usize,
}

fn mix(seed: u64, a: u64, b: u64) -> u64 {
    let mut sm = SplitMix64::new(seed ^ a.rotate_left(17) ^ b.rotate_left(41));
    sm.next_u64()
}

impl SessionRouter {
    /// Build the ring: `vnodes` points per worker drawn from a stream
    /// seeded by `(seed, worker, vnode)` — the same seed always yields the
    /// same ring, hence the same session → worker mapping.
    pub fn new(workers: usize, vnodes: usize, seed: u64, pins: Vec<Option<usize>>) -> Self {
        assert!(workers >= 1 && workers <= MAX_WORKERS, "workers must be in 1..={MAX_WORKERS}");
        assert!(vnodes >= 1, "vnodes must be >= 1");
        let mut ring = Vec::with_capacity(workers * vnodes);
        for w in 0..workers {
            for v in 0..vnodes {
                ring.push((mix(seed, w as u64, v as u64), w as u32));
            }
        }
        // Tie-break equal points by worker so the ring order is total.
        ring.sort_unstable();
        Self {
            ring,
            pins: pins.into_iter().map(|p| p.map(|w| w as u32)).collect(),
            load: vec![0; workers],
            workers,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Place `(tenant, session_key)` on a worker, or `None` when no worker
    /// can take it. Pinned tenants only ever get their pinned worker;
    /// unpinned sessions walk the ring clockwise past workers the
    /// `available` probe rejects (full session slots). Pure: no counters
    /// move until [`Self::admit`].
    pub fn route(
        &self,
        tenant: usize,
        session_key: u64,
        available: &dyn Fn(usize) -> bool,
    ) -> Option<usize> {
        if let Some(Some(pin)) = self.pins.get(tenant) {
            let w = *pin as usize;
            return available(w).then_some(w);
        }
        let h = mix(0x5E55_10_40, tenant as u64, session_key);
        let start = self.ring.partition_point(|&(p, _)| p < h) % self.ring.len();
        let mut tried: u64 = 0;
        for i in 0..self.ring.len() {
            let (_, w) = self.ring[(start + i) % self.ring.len()];
            if tried & (1 << w) != 0 {
                continue;
            }
            tried |= 1 << w;
            if available(w as usize) {
                return Some(w as usize);
            }
            if tried.count_ones() as usize == self.workers {
                break;
            }
        }
        None
    }

    pub fn admit(&mut self, worker: usize) {
        self.load[worker] += 1;
    }

    pub fn complete(&mut self, worker: usize) {
        self.load[worker] = self.load[worker].saturating_sub(1);
    }

    /// Spread between the most- and least-loaded worker right now.
    pub fn imbalance(&self) -> u64 {
        let max = self.load.iter().copied().max().unwrap_or(0);
        let min = self.load.iter().copied().min().unwrap_or(0);
        max - min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_mapping() {
        let a = SessionRouter::new(4, 32, 9, vec![None; 2]);
        let b = SessionRouter::new(4, 32, 9, vec![None; 2]);
        let all = |_: usize| true;
        for t in 0..2 {
            for k in 0..200u64 {
                assert_eq!(a.route(t, k, &all), b.route(t, k, &all));
            }
        }
        // A different seed rebuilds the ring differently somewhere.
        let c = SessionRouter::new(4, 32, 10, vec![None; 2]);
        let moved = (0..200u64).filter(|&k| a.route(0, k, &all) != c.route(0, k, &all)).count();
        assert!(moved > 0, "seed must shape the ring");
    }

    #[test]
    fn ring_spreads_sessions_across_workers() {
        let r = SessionRouter::new(4, 64, 7, vec![None]);
        let all = |_: usize| true;
        let mut seen = [0usize; 4];
        for k in 0..400u64 {
            seen[r.route(0, k, &all).unwrap()] += 1;
        }
        assert!(seen.iter().all(|&n| n > 0), "all workers reachable: {seen:?}");
    }

    #[test]
    fn full_workers_are_walked_past_but_pins_are_not() {
        let r = SessionRouter::new(3, 16, 3, vec![None, Some(2)]);
        let all = |_: usize| true;
        let home = r.route(0, 42, &all).unwrap();
        // Its hash-home worker full: session fails over to another worker.
        let w2 = r.route(0, 42, &|w| w != home).unwrap();
        assert_ne!(w2, home);
        // Everyone full: no placement.
        assert_eq!(r.route(0, 42, &|_| false), None);
        // Pinned tenant always lands on its pin, or nowhere.
        for k in 0..50u64 {
            assert_eq!(r.route(1, k, &all), Some(2));
        }
        assert_eq!(r.route(1, 0, &|w| w != 2), None, "pins never fail over");
    }

    #[test]
    fn load_accounting_tracks_imbalance() {
        let mut r = SessionRouter::new(2, 8, 1, vec![None]);
        assert_eq!(r.imbalance(), 0);
        r.admit(0);
        r.admit(0);
        r.admit(1);
        assert_eq!(r.imbalance(), 1);
        r.complete(0);
        assert_eq!(r.imbalance(), 0);
    }
}
