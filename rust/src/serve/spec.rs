//! [`ServeSpec`] — the serializable description of one tenant-aware serve
//! run (schema [`SERVE_SPEC_SCHEMA`]), with the same JSON round-trip,
//! builder-validation, and resolution discipline as `acpc-run-v1`.
//!
//! A serve spec captures everything the QoS engine needs: worker count and
//! L2 policy, the workload template (scenario or model profile), hierarchy
//! overrides (shared with the run spec via
//! [`crate::api::HierarchySpec`]), the session-router geometry, the
//! arbiter thresholds, and one block per tenant — its open-loop arrival
//! process, optional token-bucket rate contract, and optional worker pin.
//! [`ServeSpec::resolve`] validates everything at the boundary and derives
//! a *fully-explicit* copy of the spec which [`super::engine::run`] embeds
//! in the [`crate::coordinator::ServeReport`], so a report reproduces its
//! run bit-for-bit — `acpc serve --spec <(jq .serve_spec report.json)`.

use super::admission::ArbiterConfig;
use super::router::MAX_WORKERS;
use crate::api::spec::{f64_field, f64_json, str_field, u64_field, HierarchySpec};
use crate::config::PredictorKind;
use crate::mem::HierarchyConfig;
use crate::trace::{GeneratorConfig, ModelProfile, Scenario};
use crate::traffic::{ArrivalKind, OpenLoopConfig};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::time::Duration;

/// Schema identifier stamped into serve-spec JSON.
pub const SERVE_SPEC_SCHEMA: &str = "acpc-serve-spec-v1";

/// Most tenants one serve engine arbitrates between.
pub const MAX_TENANTS: usize = 8;

/// One tenant: identity, offered-traffic shape, rate contract, placement.
/// Arrival fields mirror [`crate::api::TrafficSpec`] (`None` = the
/// [`OpenLoopConfig`] default); the RNG stream seeds from the run seed
/// plus the tenant index, so tenants draw independent arrival histories.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Arrival process: `poisson` (default), `diurnal`, or `bursty`.
    pub arrivals: Option<String>,
    /// Mean offered rate, sessions per 1000 engine ticks.
    pub rate: Option<f64>,
    pub period: Option<u64>,
    pub amplitude: Option<f64>,
    pub burst_factor: Option<f64>,
    pub burst_switch_p: Option<f64>,
    /// Admission-queue capacity; arrivals beyond it are shed.
    pub queue_depth: Option<usize>,
    /// Token-bucket refill, tokens per tick (`None` = uncapped).
    pub bucket_rate: Option<f64>,
    /// Token-bucket capacity (requires `bucket_rate`; default 4).
    pub bucket_burst: Option<f64>,
    /// Pin every session of this tenant to one worker (hard isolation —
    /// pinned admissions never fail over).
    pub pin_worker: Option<usize>,
}

impl TenantSpec {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Self::default() }
    }

    /// Concrete arrival process + bucket for tenant `index`; unset fields
    /// take the open-loop defaults.
    fn resolve(&self, run_seed: u64, index: usize, workers: usize) -> Result<ResolvedTenant> {
        if self.name.is_empty() {
            bail!("tenant {index}: 'name' must be non-empty");
        }
        let kind = ArrivalKind::parse(self.arrivals.as_deref().unwrap_or("poisson"))
            .map_err(|e| anyhow!("tenant '{}': {e}", self.name))?;
        // Independent per-tenant stream from the run seed (SplitMix-style
        // odd-constant spacing, same idiom as worker seeds).
        let seed = run_seed.wrapping_add((index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut ol = OpenLoopConfig::new(kind, seed);
        if let Some(v) = self.rate {
            ol.rate = v;
        }
        if let Some(v) = self.period {
            ol.period = v;
        }
        if let Some(v) = self.amplitude {
            ol.amplitude = v;
        }
        if let Some(v) = self.burst_factor {
            ol.burst_factor = v;
        }
        if let Some(v) = self.burst_switch_p {
            ol.burst_switch_p = v;
        }
        if let Some(v) = self.queue_depth {
            ol.queue_depth = v;
        }
        ol.validate().map_err(|e| anyhow!("tenant '{}': {e}", self.name))?;
        let bucket = match (self.bucket_rate, self.bucket_burst) {
            (None, None) => None,
            (None, Some(_)) => {
                bail!("tenant '{}': 'bucket_burst' requires 'bucket_rate'", self.name)
            }
            (Some(rate), burst) => {
                let burst = burst.unwrap_or(4.0);
                if !(rate.is_finite() && rate > 0.0) {
                    bail!("tenant '{}': bucket_rate must be finite and > 0", self.name);
                }
                if !(burst.is_finite() && burst >= 1.0) {
                    bail!("tenant '{}': bucket_burst must be finite and >= 1", self.name);
                }
                Some((rate, burst))
            }
        };
        if let Some(pin) = self.pin_worker {
            if pin >= workers {
                bail!(
                    "tenant '{}': pin_worker {pin} out of range (workers = {workers})",
                    self.name
                );
            }
        }
        Ok(ResolvedTenant {
            name: self.name.clone(),
            arrivals: ol,
            bucket,
            pin: self.pin_worker,
        })
    }

    /// Spec view of a resolved tenant, every arrival field explicit.
    fn from_resolved(r: &ResolvedTenant) -> Self {
        Self {
            name: r.name.clone(),
            arrivals: Some(r.arrivals.kind.label().to_string()),
            rate: Some(r.arrivals.rate),
            period: Some(r.arrivals.period),
            amplitude: Some(r.arrivals.amplitude),
            burst_factor: Some(r.arrivals.burst_factor),
            burst_switch_p: Some(r.arrivals.burst_switch_p),
            queue_depth: Some(r.arrivals.queue_depth),
            bucket_rate: r.bucket.map(|(rate, _)| rate),
            bucket_burst: r.bucket.map(|(_, burst)| burst),
            pin_worker: r.pin,
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        if let Some(v) = &self.arrivals {
            j.set("arrivals", Json::Str(v.clone()));
        }
        if let Some(v) = self.rate {
            j.set("rate", f64_json(v));
        }
        if let Some(v) = self.period {
            j.set("period", Json::Num(v as f64));
        }
        if let Some(v) = self.amplitude {
            j.set("amplitude", f64_json(v));
        }
        if let Some(v) = self.burst_factor {
            j.set("burst_factor", f64_json(v));
        }
        if let Some(v) = self.burst_switch_p {
            j.set("burst_switch_p", f64_json(v));
        }
        if let Some(v) = self.queue_depth {
            j.set("queue_depth", Json::Num(v as f64));
        }
        if let Some(v) = self.bucket_rate {
            j.set("bucket_rate", f64_json(v));
        }
        if let Some(v) = self.bucket_burst {
            j.set("bucket_burst", f64_json(v));
        }
        if let Some(v) = self.pin_worker {
            j.set("pin_worker", Json::Num(v as f64));
        }
        j
    }

    fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("each tenant must be an object"))?;
        let mut t = Self::default();
        for (k, v) in obj {
            match k.as_str() {
                "name" => t.name = str_field(v, k)?,
                "arrivals" => t.arrivals = Some(str_field(v, k)?),
                "rate" => t.rate = Some(f64_field(v, k)?),
                "period" => t.period = Some(u64_field(v, k)?),
                "amplitude" => t.amplitude = Some(f64_field(v, k)?),
                "burst_factor" => t.burst_factor = Some(f64_field(v, k)?),
                "burst_switch_p" => t.burst_switch_p = Some(f64_field(v, k)?),
                "queue_depth" => t.queue_depth = Some(u64_field(v, k)? as usize),
                "bucket_rate" => t.bucket_rate = Some(f64_field(v, k)?),
                "bucket_burst" => t.bucket_burst = Some(f64_field(v, k)?),
                "pin_worker" => t.pin_worker = Some(u64_field(v, k)? as usize),
                other => bail!("unknown tenant key '{other}'"),
            }
        }
        if t.name.is_empty() {
            bail!("each tenant needs a non-empty 'name'");
        }
        Ok(t)
    }
}

/// Session-router geometry. `None` = default.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RouterSpec {
    /// Consistent-hash ring points per worker (default 16).
    pub vnodes: Option<usize>,
}

/// Arbiter knobs as spec fields; `None` = the [`ArbiterConfig`] default.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArbiterSpec {
    /// Arbitrate at all (default true; `false` = observe-only scoring).
    pub enabled: Option<bool>,
    /// Engine ticks per arbitration window (default 2000).
    pub window_ticks: Option<u64>,
    pub score_threshold: Option<f64>,
    pub min_share: Option<f64>,
    pub min_accesses: Option<u64>,
    pub warmup_windows: Option<u64>,
}

impl ArbiterSpec {
    fn resolve(&self) -> Result<(ArbiterConfig, bool, u64)> {
        let d = ArbiterConfig::default();
        let cfg = ArbiterConfig {
            score_threshold: self.score_threshold.unwrap_or(d.score_threshold),
            min_share: self.min_share.unwrap_or(d.min_share),
            min_accesses: self.min_accesses.unwrap_or(d.min_accesses),
            warmup_windows: self.warmup_windows.unwrap_or(d.warmup_windows),
        };
        if !(cfg.score_threshold.is_finite() && cfg.score_threshold >= 0.0) {
            bail!("arbiter.score_threshold must be finite and >= 0");
        }
        if !(0.0..=1.0).contains(&cfg.min_share) {
            bail!("arbiter.min_share must be in [0, 1]");
        }
        let window_ticks = self.window_ticks.unwrap_or(2000);
        if window_ticks == 0 {
            bail!("arbiter.window_ticks must be >= 1");
        }
        Ok((cfg, self.enabled.unwrap_or(true), window_ticks))
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        if let Some(v) = self.enabled {
            j.set("enabled", Json::Bool(v));
        }
        if let Some(v) = self.window_ticks {
            j.set("window_ticks", Json::Num(v as f64));
        }
        if let Some(v) = self.score_threshold {
            j.set("score_threshold", f64_json(v));
        }
        if let Some(v) = self.min_share {
            j.set("min_share", f64_json(v));
        }
        if let Some(v) = self.min_accesses {
            j.set("min_accesses", Json::Num(v as f64));
        }
        if let Some(v) = self.warmup_windows {
            j.set("warmup_windows", Json::Num(v as f64));
        }
        j
    }

    fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("'arbiter' must be an object"))?;
        let mut s = Self::default();
        for (k, v) in obj {
            match k.as_str() {
                "enabled" => {
                    s.enabled =
                        Some(v.as_bool().ok_or_else(|| anyhow!("'enabled' must be a bool"))?)
                }
                "window_ticks" => s.window_ticks = Some(u64_field(v, k)?),
                "score_threshold" => s.score_threshold = Some(f64_field(v, k)?),
                "min_share" => s.min_share = Some(f64_field(v, k)?),
                "min_accesses" => s.min_accesses = Some(u64_field(v, k)?),
                "warmup_windows" => s.warmup_windows = Some(u64_field(v, k)?),
                other => bail!("unknown arbiter key '{other}'"),
            }
        }
        Ok(s)
    }
}

/// Everything needed to reproduce one tenant-aware serve run. Build with
/// [`ServeSpec::builder`], load with [`ServeSpec::from_file`] /
/// [`ServeSpec::from_json`], execute with [`super::run`].
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    /// Run name; `None` derives `serve-{policy}-{tenants}t`.
    pub name: Option<String>,
    /// L2 replacement policy under test.
    pub policy: String,
    /// `none` or `heuristic` — the deterministic QoS engine never loads a
    /// learned artifact (use classic `acpc serve` for dnn/tcn).
    pub predictor: PredictorKind,
    /// Scenario-registry workload template (mutually exclusive with
    /// `profile`); tenants share the template, each over its own seeded
    /// generator and rebased address space.
    pub scenario: Option<String>,
    /// Model-profile workload template (mutually exclusive with
    /// `scenario`). Both unset = the tiny smoke generator.
    pub profile: Option<String>,
    pub workers: Option<usize>,
    /// Engine ticks to run arrivals for (service then drains).
    pub ticks: Option<u64>,
    /// Accesses each worker serves per tick.
    pub quantum: Option<u64>,
    pub predict_batch: Option<usize>,
    pub seed: Option<u64>,
    pub hierarchy: HierarchySpec,
    pub router: RouterSpec,
    pub arbiter: ArbiterSpec,
    /// The tenant population, 1..=[`MAX_TENANTS`], unique names.
    pub tenants: Vec<TenantSpec>,
    /// Record every served access into a v2 `.acpctrace` (tenant = routed
    /// tenant id, arrival = per-tenant access ordinal).
    pub capture: Option<String>,
    /// HTTP dashboard port (0 = any free port).
    pub dashboard: Option<u16>,
    pub dashboard_linger_ms: Option<u64>,
}

impl Default for ServeSpec {
    fn default() -> Self {
        Self {
            name: None,
            policy: "acpc".into(),
            predictor: PredictorKind::Heuristic,
            scenario: None,
            profile: None,
            workers: None,
            ticks: None,
            quantum: None,
            predict_batch: None,
            seed: None,
            hierarchy: HierarchySpec::default(),
            router: RouterSpec::default(),
            arbiter: ArbiterSpec::default(),
            tenants: Vec::new(),
            capture: None,
            dashboard: None,
            dashboard_linger_ms: None,
        }
    }
}

/// One tenant resolved: concrete arrival process, bucket contract, pin.
#[derive(Debug, Clone)]
pub struct ResolvedTenant {
    pub name: String,
    pub arrivals: OpenLoopConfig,
    /// `(rate, burst)` token-bucket contract, `None` = uncapped.
    pub bucket: Option<(f64, f64)>,
    pub pin: Option<usize>,
}

/// A serve spec resolved against the registries: what
/// [`super::engine::run`] executes.
#[derive(Debug, Clone)]
pub struct ResolvedServe {
    pub name: String,
    pub workers: usize,
    pub policy: String,
    pub predictor: PredictorKind,
    pub hierarchy: HierarchyConfig,
    /// Per-tenant generator template (arrivals zeroed — all admission is
    /// engine-driven); each (worker, tenant) generator derives its seed
    /// from this one.
    pub generator: GeneratorConfig,
    pub ticks: u64,
    pub quantum: u64,
    pub predict_batch: usize,
    pub seed: u64,
    pub vnodes: usize,
    pub arbiter: ArbiterConfig,
    pub arbiter_enabled: bool,
    pub window_ticks: u64,
    pub tenants: Vec<ResolvedTenant>,
    pub capture: Option<std::path::PathBuf>,
    pub dashboard_port: Option<u16>,
    pub dashboard_linger: Duration,
    /// The input spec with every defaulted scalar made explicit — embedded
    /// in the report so it re-runs bit-for-bit.
    pub spec: ServeSpec,
}

impl ResolvedServe {
    /// Per-tenant pin vector in router shape.
    pub fn pins(&self) -> Vec<Option<usize>> {
        self.tenants.iter().map(|t| t.pin).collect()
    }
}

impl ServeSpec {
    pub fn builder() -> ServeSpecBuilder {
        ServeSpecBuilder { spec: ServeSpec::default() }
    }

    /// Validate without running (resolution side effects discarded).
    pub fn validate(&self) -> Result<()> {
        self.resolve().map(|_| ())
    }

    /// Resolve against the registries into the concrete engine
    /// configuration, validating at the boundary.
    pub fn resolve(&self) -> Result<ResolvedServe> {
        if crate::policy::make_policy(&self.policy, 2, 2, 0).is_none() {
            bail!("unknown policy '{}' (see `acpc policies`)", self.policy);
        }
        match self.predictor {
            PredictorKind::None | PredictorKind::Heuristic => {}
            other => bail!(
                "serve spec predictor must be none|heuristic (got '{}'): the QoS engine \
                 is deterministic and loads no artifacts — use classic `acpc serve` for \
                 learned predictors",
                other.label()
            ),
        }
        if self.scenario.is_some() && self.profile.is_some() {
            bail!("'scenario' and 'profile' are mutually exclusive");
        }
        let workers = self.workers.unwrap_or(2);
        if workers == 0 || workers > MAX_WORKERS {
            bail!("workers must be in 1..={MAX_WORKERS} (got {workers})");
        }
        if self.tenants.is_empty() {
            bail!("a serve spec needs at least one tenant");
        }
        if self.tenants.len() > MAX_TENANTS {
            bail!("at most {MAX_TENANTS} tenants (got {})", self.tenants.len());
        }
        for (i, a) in self.tenants.iter().enumerate() {
            for b in &self.tenants[i + 1..] {
                if a.name == b.name {
                    bail!("duplicate tenant name '{}'", a.name);
                }
            }
        }
        let seed = self.seed.unwrap_or(0x5EED);
        let ticks = self.ticks.unwrap_or(20_000);
        if ticks == 0 {
            bail!("ticks must be >= 1");
        }
        let quantum = self.quantum.unwrap_or(64);
        if quantum == 0 {
            bail!("quantum must be >= 1");
        }
        let predict_batch = self.predict_batch.unwrap_or(32);
        if predict_batch == 0 {
            bail!("predict_batch must be >= 1");
        }
        let vnodes = self.router.vnodes.unwrap_or(16);
        if vnodes == 0 {
            bail!("router.vnodes must be >= 1");
        }
        let (arbiter, arbiter_enabled, window_ticks) = self.arbiter.resolve()?;

        let mut generator = match (&self.scenario, &self.profile) {
            (Some(name), None) => {
                let sc = Scenario::by_name(name)
                    .ok_or_else(|| anyhow!("unknown scenario '{name}' (see `acpc policies`)"))?;
                if sc.is_traffic() {
                    bail!(
                        "scenario '{name}' already models traffic shape; in a serve spec \
                         the tenants define arrivals — pick a generator scenario"
                    );
                }
                sc.config(seed)
            }
            (None, Some(p)) => {
                let profile = ModelProfile::by_name(p)
                    .ok_or_else(|| anyhow!("unknown model profile '{p}'"))?;
                GeneratorConfig::new(profile, seed)
            }
            (None, None) => GeneratorConfig::tiny(seed),
            (Some(_), Some(_)) => unreachable!("checked above"),
        };
        // All admission is engine-driven; autonomous arrivals off.
        generator.arrival_p_hot = 0.0;
        generator.arrival_p_cold = 0.0;

        let mut hierarchy = HierarchyConfig::scaled();
        hierarchy.prefetcher = "composite".into();
        self.hierarchy.apply(&mut hierarchy)?;

        let tenants: Vec<ResolvedTenant> = self
            .tenants
            .iter()
            .enumerate()
            .map(|(i, t)| t.resolve(seed, i, workers))
            .collect::<Result<_>>()?;

        let name = self
            .name
            .clone()
            .unwrap_or_else(|| format!("serve-{}-{}t", self.policy, tenants.len()));

        // The fully-explicit copy the report embeds.
        let mut spec = self.clone();
        spec.name = Some(name.clone());
        spec.workers = Some(workers);
        spec.ticks = Some(ticks);
        spec.quantum = Some(quantum);
        spec.predict_batch = Some(predict_batch);
        spec.seed = Some(seed);
        spec.router = RouterSpec { vnodes: Some(vnodes) };
        spec.arbiter = ArbiterSpec {
            enabled: Some(arbiter_enabled),
            window_ticks: Some(window_ticks),
            score_threshold: Some(arbiter.score_threshold),
            min_share: Some(arbiter.min_share),
            min_accesses: Some(arbiter.min_accesses),
            warmup_windows: Some(arbiter.warmup_windows),
        };
        spec.tenants = tenants.iter().map(TenantSpec::from_resolved).collect();
        spec.dashboard_linger_ms = Some(self.dashboard_linger_ms.unwrap_or(0));

        Ok(ResolvedServe {
            name,
            workers,
            policy: self.policy.clone(),
            predictor: self.predictor,
            hierarchy,
            generator,
            ticks,
            quantum,
            predict_batch,
            seed,
            vnodes,
            arbiter,
            arbiter_enabled,
            window_ticks,
            tenants,
            capture: self.capture.as_ref().map(std::path::PathBuf::from),
            dashboard_port: self.dashboard,
            dashboard_linger: Duration::from_millis(self.dashboard_linger_ms.unwrap_or(0)),
            spec,
        })
    }

    // ---- JSON ----------------------------------------------------------

    /// Serialize (schema-stamped). Unset optional fields are omitted; a
    /// resolved spec (as embedded in reports) has its scalars explicit.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Str(SERVE_SPEC_SCHEMA.into()));
        if let Some(n) = &self.name {
            j.set("name", Json::Str(n.clone()));
        }
        j.set("policy", Json::Str(self.policy.clone()));
        j.set("predictor", Json::Str(self.predictor.label().into()));
        if let Some(sc) = &self.scenario {
            j.set("scenario", Json::Str(sc.clone()));
        }
        if let Some(p) = &self.profile {
            j.set("profile", Json::Str(p.clone()));
        }
        if let Some(v) = self.workers {
            j.set("workers", Json::Num(v as f64));
        }
        if let Some(v) = self.ticks {
            j.set("ticks", Json::Num(v as f64));
        }
        if let Some(v) = self.quantum {
            j.set("quantum", Json::Num(v as f64));
        }
        if let Some(v) = self.predict_batch {
            j.set("predict_batch", Json::Num(v as f64));
        }
        // String, not Num: u64 seeds exceed f64's exact-integer range.
        if let Some(s) = self.seed {
            j.set("seed", Json::Str(s.to_string()));
        }
        if self.hierarchy != HierarchySpec::default() {
            j.set("hierarchy", self.hierarchy.to_json());
        }
        if let Some(v) = self.router.vnodes {
            j.set("router", Json::from_pairs(vec![("vnodes", Json::Num(v as f64))]));
        }
        if self.arbiter != ArbiterSpec::default() {
            j.set("arbiter", self.arbiter.to_json());
        }
        j.set("tenants", Json::Arr(self.tenants.iter().map(|t| t.to_json()).collect()));
        if let Some(c) = &self.capture {
            j.set("capture", Json::Str(c.clone()));
        }
        if let Some(p) = self.dashboard {
            j.set("dashboard", Json::Num(p as f64));
        }
        if let Some(v) = self.dashboard_linger_ms {
            j.set("dashboard_linger_ms", Json::Num(v as f64));
        }
        j
    }

    /// Parse a spec. Unknown keys are errors (typo protection).
    pub fn from_json(j: &Json) -> Result<ServeSpec> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("serve spec root must be an object"))?;
        let mut spec = ServeSpec::default();
        for (k, v) in obj {
            match k.as_str() {
                "schema" => {
                    let s = v.as_str().ok_or_else(|| anyhow!("schema must be a string"))?;
                    if s != SERVE_SPEC_SCHEMA {
                        bail!("unsupported spec schema '{s}' (expected '{SERVE_SPEC_SCHEMA}')");
                    }
                }
                "name" => spec.name = Some(str_field(v, k)?),
                "policy" => spec.policy = str_field(v, k)?,
                "predictor" => {
                    spec.predictor =
                        PredictorKind::parse(v.as_str().ok_or_else(|| anyhow!("predictor"))?)?
                }
                "scenario" => spec.scenario = Some(str_field(v, k)?),
                "profile" => spec.profile = Some(str_field(v, k)?),
                "workers" => spec.workers = Some(u64_field(v, k)? as usize),
                "ticks" => spec.ticks = Some(u64_field(v, k)?),
                "quantum" => spec.quantum = Some(u64_field(v, k)?),
                "predict_batch" => spec.predict_batch = Some(u64_field(v, k)? as usize),
                "seed" => spec.seed = Some(u64_field(v, k)?),
                "hierarchy" => spec.hierarchy = HierarchySpec::from_json(v)?,
                "router" => {
                    let obj =
                        v.as_obj().ok_or_else(|| anyhow!("'router' must be an object"))?;
                    for (rk, rv) in obj {
                        match rk.as_str() {
                            "vnodes" => {
                                spec.router.vnodes = Some(u64_field(rv, rk)? as usize)
                            }
                            other => bail!("unknown router key '{other}'"),
                        }
                    }
                }
                "arbiter" => spec.arbiter = ArbiterSpec::from_json(v)?,
                "tenants" => {
                    let arr =
                        v.as_arr().ok_or_else(|| anyhow!("'tenants' must be an array"))?;
                    spec.tenants =
                        arr.iter().map(TenantSpec::from_json).collect::<Result<_>>()?;
                }
                "capture" => spec.capture = Some(str_field(v, k)?),
                "dashboard" => spec.dashboard = Some(u64_field(v, k)? as u16),
                "dashboard_linger_ms" => spec.dashboard_linger_ms = Some(u64_field(v, k)?),
                other => bail!("unknown serve-spec key '{other}'"),
            }
        }
        Ok(spec)
    }

    /// Load a spec from a JSON file.
    pub fn from_file(path: &std::path::Path) -> Result<ServeSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&j).map_err(|e| anyhow!("{}: {e}", path.display()))
    }
}

// ---- builder -----------------------------------------------------------

/// Fluent construction of a [`ServeSpec`]; [`build`](Self::build)
/// validates by resolving against the registries.
#[derive(Debug, Clone)]
pub struct ServeSpecBuilder {
    spec: ServeSpec,
}

impl ServeSpecBuilder {
    pub fn name(mut self, name: &str) -> Self {
        self.spec.name = Some(name.to_string());
        self
    }

    pub fn policy(mut self, policy: &str) -> Self {
        self.spec.policy = policy.to_string();
        self
    }

    pub fn predictor(mut self, kind: PredictorKind) -> Self {
        self.spec.predictor = kind;
        self
    }

    pub fn scenario(mut self, scenario: &str) -> Self {
        self.spec.scenario = Some(scenario.to_string());
        self
    }

    pub fn profile(mut self, profile: &str) -> Self {
        self.spec.profile = Some(profile.to_string());
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.spec.workers = Some(n);
        self
    }

    pub fn ticks(mut self, n: u64) -> Self {
        self.spec.ticks = Some(n);
        self
    }

    pub fn quantum(mut self, n: u64) -> Self {
        self.spec.quantum = Some(n);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seed = Some(seed);
        self
    }

    /// Append one tenant block.
    pub fn tenant(mut self, t: TenantSpec) -> Self {
        self.spec.tenants.push(t);
        self
    }

    pub fn vnodes(mut self, n: usize) -> Self {
        self.spec.router.vnodes = Some(n);
        self
    }

    pub fn arbiter(mut self, a: ArbiterSpec) -> Self {
        self.spec.arbiter = a;
        self
    }

    /// Toggle arbitration (scores are computed either way).
    pub fn arbiter_enabled(mut self, on: bool) -> Self {
        self.spec.arbiter.enabled = Some(on);
        self
    }

    pub fn window_ticks(mut self, n: u64) -> Self {
        self.spec.arbiter.window_ticks = Some(n);
        self
    }

    pub fn hierarchy_preset(mut self, preset: &str) -> Self {
        self.spec.hierarchy.preset = Some(preset.to_string());
        self
    }

    pub fn prefetcher(mut self, prefetcher: &str) -> Self {
        self.spec.hierarchy.prefetcher = Some(prefetcher.to_string());
        self
    }

    pub fn l2_kb(mut self, kb: u64) -> Self {
        self.spec.hierarchy.l2_kb = Some(kb);
        self
    }

    pub fn capture(mut self, path: &str) -> Self {
        self.spec.capture = Some(path.to_string());
        self
    }

    pub fn dashboard(mut self, port: u16) -> Self {
        self.spec.dashboard = Some(port);
        self
    }

    /// Validate (full resolution against the registries) and return the
    /// spec.
    pub fn build(self) -> Result<ServeSpec> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tenants() -> ServeSpecBuilder {
        ServeSpec::builder()
            .tenant(TenantSpec {
                arrivals: Some("bursty".into()),
                rate: Some(8.0),
                queue_depth: Some(4),
                ..TenantSpec::new("noisy")
            })
            .tenant(TenantSpec {
                rate: Some(1.0),
                bucket_rate: Some(0.01),
                ..TenantSpec::new("quiet")
            })
    }

    #[test]
    fn builder_validates_and_roundtrips() {
        let spec = two_tenants()
            .policy("acpc")
            .workers(2)
            .ticks(5_000)
            .seed(0xFFFF_FFFF_FFFF_FFF1) // > 2^53: must survive JSON
            .prefetcher("stride")
            .build()
            .unwrap();
        let back = ServeSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        assert_eq!(back.seed, Some(0xFFFF_FFFF_FFFF_FFF1));
        assert_eq!(back.tenants.len(), 2);
    }

    #[test]
    fn resolution_makes_every_scalar_explicit_and_reresolves() {
        let spec = two_tenants().build().unwrap();
        let r = spec.resolve().unwrap();
        assert_eq!(r.workers, 2);
        assert_eq!(r.ticks, 20_000);
        assert_eq!(r.window_ticks, 2000);
        assert!(r.arbiter_enabled);
        assert_eq!(r.name, "serve-acpc-2t");
        assert_eq!(r.tenants[1].bucket, Some((0.01, 4.0)), "burst defaults to 4");
        // Tenants draw distinct arrival streams off the run seed.
        assert_ne!(r.tenants[0].arrivals.seed, r.tenants[1].arrivals.seed);
        // The resolved copy re-resolves to the same configuration.
        let back = ServeSpec::from_json(&r.spec.to_json()).unwrap();
        let r2 = back.resolve().unwrap();
        assert_eq!(format!("{:?}", r.hierarchy), format!("{:?}", r2.hierarchy));
        assert_eq!(format!("{:?}", r.tenants), format!("{:?}", r2.tenants));
        assert_eq!(format!("{:?}", r.arbiter), format!("{:?}", r2.arbiter));
        assert_eq!((r.ticks, r.quantum, r.seed), (r2.ticks, r2.quantum, r2.seed));
    }

    #[test]
    fn builder_rejects_invalid_specs() {
        let one = |t: TenantSpec| ServeSpec::builder().tenant(t);
        assert!(ServeSpec::builder().build().is_err(), "no tenants");
        assert!(one(TenantSpec::new("")).build().is_err(), "empty name");
        assert!(two_tenants().policy("nope").build().is_err());
        assert!(two_tenants().scenario("no-such-scenario").build().is_err());
        assert!(
            two_tenants().scenario("bursty-batch").build().is_err(),
            "traffic scenarios cannot stack under tenant arrivals"
        );
        assert!(two_tenants().profile("no-such-profile").build().is_err());
        assert!(
            two_tenants().scenario("decode-heavy").profile("gpt3ish").build().is_err(),
            "scenario+profile is ambiguous"
        );
        assert!(
            two_tenants().predictor(crate::config::PredictorKind::Tcn).build().is_err(),
            "learned predictors are the classic serve path"
        );
        assert!(two_tenants().workers(0).build().is_err());
        assert!(two_tenants().workers(65).build().is_err());
        assert!(two_tenants().ticks(0).build().is_err());
        assert!(two_tenants().quantum(0).build().is_err());
        assert!(two_tenants().vnodes(0).build().is_err());
        assert!(two_tenants().window_ticks(0).build().is_err());
        assert!(two_tenants().l2_kb(96).build().is_err(), "non-power-of-two sets");
        assert!(
            two_tenants().tenant(TenantSpec::new("noisy")).build().is_err(),
            "duplicate tenant name"
        );
        assert!(
            one(TenantSpec { arrivals: Some("tsunami".into()), ..TenantSpec::new("t") })
                .build()
                .is_err(),
            "unknown arrival kind"
        );
        assert!(
            one(TenantSpec { rate: Some(-1.0), ..TenantSpec::new("t") }).build().is_err(),
            "negative rate"
        );
        assert!(
            one(TenantSpec { bucket_burst: Some(4.0), ..TenantSpec::new("t") })
                .build()
                .is_err(),
            "bucket_burst without bucket_rate"
        );
        assert!(
            one(TenantSpec { bucket_rate: Some(0.0), ..TenantSpec::new("t") })
                .build()
                .is_err(),
            "zero bucket rate"
        );
        assert!(
            one(TenantSpec { pin_worker: Some(2), ..TenantSpec::new("t") })
                .workers(2)
                .build()
                .is_err(),
            "pin out of range"
        );
        let nine = (0..9).fold(ServeSpec::builder(), |b, i| {
            b.tenant(TenantSpec::new(&format!("t{i}")))
        });
        assert!(nine.build().is_err(), "too many tenants");
    }

    #[test]
    fn unknown_keys_rejected() {
        for text in [
            r#"{"polcy": "lru", "tenants": [{"name": "a"}]}"#,
            r#"{"tenants": [{"nmae": "a"}]}"#,
            r#"{"tenants": [{"name": "a", "rat": 4}]}"#,
            r#"{"arbiter": {"window": 1}, "tenants": [{"name": "a"}]}"#,
            r#"{"router": {"vnode": 8}, "tenants": [{"name": "a"}]}"#,
            r#"{"schema": "acpc-serve-spec-v0", "tenants": [{"name": "a"}]}"#,
            r#"{"tenants": [{}]}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(ServeSpec::from_json(&j).is_err(), "{text}");
        }
    }

    #[test]
    fn imprecise_numbers_rejected_not_truncated() {
        for text in [
            r#"{"ticks": 2.5, "tenants": [{"name": "a"}]}"#,
            r#"{"seed": 18446744073709551615, "tenants": [{"name": "a"}]}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(ServeSpec::from_json(&j).is_err(), "{text}");
        }
        let j =
            Json::parse(r#"{"seed": "18446744073709551615", "tenants": [{"name": "a"}]}"#)
                .unwrap();
        assert_eq!(ServeSpec::from_json(&j).unwrap().seed, Some(u64::MAX));
    }
}
