//! Admission control and QoS arbitration for the tenant-aware serve engine.
//!
//! Two mechanisms stack in front of the workers:
//!
//! * **Token buckets** — a static per-tenant rate contract. A tenant with a
//!   bucket can only admit sessions while it has tokens; the bucket refills
//!   at `rate` tokens per tick up to `burst`. Tenants without a bucket are
//!   uncapped (subject only to arbitration).
//! * **The arbiter** — an LLaMCAT-style dynamic throttle. Every window it
//!   scores each tenant from windowed cache telemetry (miss share ×
//!   a blend of miss rate, inflicted pollution, and reuse distance) and
//!   throttles the worst offender for the next window iff that tenant also
//!   holds a meaningful share of traffic. Throttled tenants defer
//!   admissions; their in-flight sessions keep running.
//!
//! Every admission attempt lands in exactly one counter bucket, so
//! `offered == admitted + shed + deferred` holds per tenant by
//! construction — [`TenantCounters::reconcile`] asserts it and the report
//! path calls it before serialization.

use crate::adapt::telemetry::ReuseSketch;

/// Classic token bucket in tick time, fractional refill.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    tokens: f64,
    rate: f64,
    burst: f64,
}

impl TokenBucket {
    /// `rate` tokens per tick, capacity `burst`. Starts full.
    pub fn new(rate: f64, burst: f64) -> Self {
        Self { tokens: burst, rate, burst }
    }

    /// Advance one tick of refill.
    pub fn tick(&mut self) {
        self.tokens = (self.tokens + self.rate).min(self.burst);
    }

    /// Spend one token if available.
    pub fn try_take(&mut self) -> bool {
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Per-tenant admission accounting. One increment per offered session:
/// admitted (placed on a worker), shed (token bucket dry — dropped), or
/// deferred (throttled by the arbiter or no worker slot — stays queued and
/// is re-offered, but the *terminal* disposition of a never-admitted
/// session is `deferred`).
#[derive(Debug, Clone, Default)]
pub struct TenantCounters {
    pub offered: u64,
    pub admitted: u64,
    pub shed: u64,
    pub deferred: u64,
}

impl TenantCounters {
    /// The audit the report path runs before serializing: every offered
    /// session must have exactly one disposition.
    pub fn reconcile(&self) -> Result<(), String> {
        let accounted = self.admitted + self.shed + self.deferred;
        if self.offered == accounted {
            Ok(())
        } else {
            Err(format!(
                "tenant counters drifted: offered={} != admitted={} + shed={} + deferred={}",
                self.offered, self.admitted, self.shed, self.deferred
            ))
        }
    }
}

/// Arbiter tuning; defaults mirror `ArbiterSpec` resolution.
#[derive(Debug, Clone)]
pub struct ArbiterConfig {
    /// Score a tenant must exceed to be throttled.
    pub score_threshold: f64,
    /// Minimum share of window accesses the top scorer must hold — a tiny
    /// tenant is never the noisy neighbor no matter how poorly it reuses.
    pub min_share: f64,
    /// Minimum absolute accesses the top scorer must have made this window.
    /// In drain windows a lone quiet tenant holds 100% share on a handful
    /// of accesses; the floor keeps such statistical noise unthrottled.
    pub min_accesses: u64,
    /// Windows to observe before the first throttle decision.
    pub warmup_windows: u64,
}

impl Default for ArbiterConfig {
    fn default() -> Self {
        Self { score_threshold: 0.25, min_share: 0.2, min_accesses: 64, warmup_windows: 1 }
    }
}

/// One tenant's telemetry for a closed window, harvested by the engine
/// from per-access counter deltas plus the merged reuse sketches.
#[derive(Debug, Clone, Copy, Default)]
pub struct TenantWindow {
    pub accesses: u64,
    pub hits: u64,
    pub misses: u64,
    /// Dead prefetch evictions attributed to this tenant's fills.
    pub dead_fills: u64,
    /// Prefetch fills issued while serving this tenant.
    pub fills: u64,
    /// Median reuse-distance bucket (log2), `None` when nothing reused.
    pub reuse_p50: Option<u8>,
}

impl TenantWindow {
    pub fn from_sketch(&mut self, sketch: &ReuseSketch) {
        self.reuse_p50 = sketch.p50_bucket();
    }

    fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            return 0.0;
        }
        self.misses as f64 / self.accesses as f64
    }

    fn pollution(&self) -> f64 {
        if self.fills == 0 {
            return 0.0;
        }
        (self.dead_fills as f64 / self.fills as f64).min(1.0)
    }
}

/// Outcome of one arbitration window, kept for the report/telemetry.
#[derive(Debug, Clone)]
pub struct ArbiterDecision {
    pub window: u64,
    /// Tenant throttled for the *next* window, if any.
    pub throttled: Option<usize>,
    /// Per-tenant scores this window (same order as tenants).
    pub scores: Vec<f64>,
}

/// Windowed noisy-neighbor arbiter. Call [`Arbiter::close_window`] at each
/// window boundary with per-tenant telemetry; query [`Arbiter::throttled`]
/// on every admission attempt.
#[derive(Debug, Clone)]
pub struct Arbiter {
    cfg: ArbiterConfig,
    enabled: bool,
    windows_seen: u64,
    throttled: Option<usize>,
    pub decisions: Vec<ArbiterDecision>,
}

impl Arbiter {
    pub fn new(cfg: ArbiterConfig, enabled: bool) -> Self {
        Self { cfg, enabled, windows_seen: 0, throttled: None, decisions: Vec::new() }
    }

    /// Is this tenant's admission gate closed right now?
    pub fn throttled(&self, tenant: usize) -> bool {
        self.throttled == Some(tenant)
    }

    /// Score the closed window and pick at most one tenant to throttle for
    /// the next. Score = miss_share × (0.5·miss_rate + 0.25·pollution +
    /// 0.25·reuse_norm): a tenant is only dangerous when it both misses a
    /// lot *and* carries enough traffic for those misses to evict others.
    pub fn close_window(&mut self, windows: &[TenantWindow]) -> &ArbiterDecision {
        self.windows_seen += 1;
        let total: u64 = windows.iter().map(|w| w.accesses).sum();
        let scores: Vec<f64> = windows
            .iter()
            .map(|w| {
                if total == 0 {
                    return 0.0;
                }
                let share = w.accesses as f64 / total as f64;
                let reuse_norm = match w.reuse_p50 {
                    Some(b) => (b as f64 / 16.0).min(1.0),
                    None => 1.0, // no reuse observed at all: worst case
                };
                share * (0.5 * w.miss_rate() + 0.25 * w.pollution() + 0.25 * reuse_norm)
            })
            .collect();

        self.throttled = None;
        if self.enabled && windows.len() >= 2 && self.windows_seen > self.cfg.warmup_windows {
            if let Some((t, &score)) = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
            {
                let share = if total == 0 {
                    0.0
                } else {
                    windows[t].accesses as f64 / total as f64
                };
                if score > self.cfg.score_threshold
                    && share >= self.cfg.min_share
                    && windows[t].accesses >= self.cfg.min_accesses
                {
                    self.throttled = Some(t);
                }
            }
        }
        self.decisions.push(ArbiterDecision {
            window: self.windows_seen,
            throttled: self.throttled,
            scores,
        });
        self.decisions.last().unwrap()
    }

    pub fn throttled_windows(&self) -> u64 {
        self.decisions.iter().filter(|d| d.throttled.is_some()).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_refills_and_caps() {
        let mut b = TokenBucket::new(0.5, 2.0);
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(!b.try_take(), "bucket starts at burst, not infinite");
        b.tick();
        assert!(!b.try_take(), "0.5 tokens is not a whole token");
        b.tick();
        assert!(b.try_take());
        for _ in 0..10 {
            b.tick();
        }
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(!b.try_take(), "refill caps at burst");
    }

    #[test]
    fn counters_reconcile() {
        let mut c = TenantCounters::default();
        c.offered = 10;
        c.admitted = 6;
        c.shed = 3;
        c.deferred = 1;
        assert!(c.reconcile().is_ok());
        c.deferred = 2;
        let err = c.reconcile().unwrap_err();
        assert!(err.contains("drifted"), "{err}");
    }

    fn noisy(accesses: u64) -> TenantWindow {
        TenantWindow {
            accesses,
            hits: accesses / 10,
            misses: accesses - accesses / 10,
            dead_fills: 40,
            fills: 50,
            reuse_p50: Some(20),
        }
    }

    fn quiet(accesses: u64) -> TenantWindow {
        TenantWindow {
            accesses,
            hits: accesses * 9 / 10,
            misses: accesses / 10,
            dead_fills: 0,
            fills: 10,
            reuse_p50: Some(3),
        }
    }

    #[test]
    fn arbiter_throttles_the_noisy_majority_tenant_after_warmup() {
        let mut a = Arbiter::new(ArbiterConfig::default(), true);
        let w = vec![noisy(800), quiet(200)];
        assert_eq!(a.close_window(&w).throttled, None, "warmup window");
        assert_eq!(a.close_window(&w).throttled, Some(0));
        assert!(a.throttled(0));
        assert!(!a.throttled(1));
        // Once the noisy tenant calms down, the throttle lifts.
        let calm = vec![quiet(500), quiet(500)];
        assert_eq!(a.close_window(&calm).throttled, None);
        assert_eq!(a.throttled_windows(), 1);
    }

    #[test]
    fn arbiter_spares_small_tenants_and_disabled_never_throttles() {
        let mut a = Arbiter::new(ArbiterConfig::default(), true);
        // Noisy but tiny (under min_share): spared.
        let w = vec![noisy(50), quiet(950)];
        a.close_window(&w);
        assert_eq!(a.close_window(&w).throttled, None);

        let mut off = Arbiter::new(ArbiterConfig::default(), false);
        let w = vec![noisy(900), quiet(100)];
        off.close_window(&w);
        assert_eq!(off.close_window(&w).throttled, None);
        assert_eq!(off.throttled_windows(), 0);
    }

    #[test]
    fn access_floor_spares_drain_window_noise() {
        // 100% share but only a handful of accesses (a drain window):
        // under the floor, never throttled no matter how bad the telemetry.
        let mut a = Arbiter::new(ArbiterConfig::default(), true);
        let w = vec![noisy(20), TenantWindow::default()];
        a.close_window(&w);
        assert_eq!(a.close_window(&w).throttled, None);
        // The same shape above the floor IS throttled.
        let mut a = Arbiter::new(ArbiterConfig::default(), true);
        let w = vec![noisy(200), TenantWindow::default()];
        a.close_window(&w);
        assert_eq!(a.close_window(&w).throttled, Some(0));
    }

    #[test]
    fn single_tenant_is_never_throttled() {
        let mut a = Arbiter::new(ArbiterConfig::default(), true);
        let w = vec![noisy(1000)];
        a.close_window(&w);
        assert_eq!(a.close_window(&w).throttled, None);
    }
}
