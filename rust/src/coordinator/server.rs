//! The serving node: N worker threads (each owning a cache hierarchy and
//! its admitted sessions) + the main thread driving arrivals through the
//! [`Router`]. Predictions run in one of two modes:
//!
//! - **Shared** ([`serve_shared`], the default for learned predictors):
//!   every worker holds a [`NativeModel`] clone over one shared
//!   [`NativeWeights`] snapshot and predicts its own batches inline — no
//!   service thread, no channel round-trip, no cross-worker version races.
//! - **Service** ([`serve`] / [`serve_with_bus`]): one predictor service
//!   thread owns the predictor (required for PJRT executables, which are
//!   thread-affine) and workers ship it batches over channels:
//!
//! ```text
//!   main ──admit──▶ worker_i ──PredictReq──▶ predictor service
//!                      ▲                         │ (DynamicBatcher:
//!                      └──────PredictResp────────┘  size/deadline)
//! ```
//!
//! Workers never block on predictions: fills use the latest completed
//! utility for the line (the async model of §3.1), and service-mode
//! responses are drained opportunistically each loop iteration.
//!
//! Each worker drives its admitted sessions through the shared
//! [`crate::sim::Engine`] — the same access loop the batch simulator and
//! the benches use — shipping the engine's feature rows to the predictor
//! service instead of flushing them inline.
//!
//! Workloads come from the scenario registry when `scenario` is set
//! (`acpc serve --scenario <name>`), otherwise from the configured
//! generator. With `adaptive` on, each worker runs its own
//! [`AdaptiveController`] over its engine's telemetry: the model lives in
//! the (remote) predictor service thread, so workers adapt by *throttling*
//! — on detected drift or confidence collapse they stop applying incoming
//! utilities (policy-default inserts) until telemetry recovers, and the
//! adaptation events are aggregated into the [`ServeReport`].

use super::batcher::DynamicBatcher;
use super::router::{Router, RouterPolicy};
use crate::adapt::{
    AdaptationEvent, AdaptiveController, ControlDecision, ControllerConfig, PredictorAccess,
};
use crate::mem::HierarchyConfig;
use crate::obs::{start_dashboard, Payload, SourceId, TelemetryBus, SAMPLE_PERIOD};
use crate::util::json::Json;
use crate::predictor::{GeometryHints, PredictorBox, ReusePredictor, FEATURE_DIM};
use crate::runtime::{NativeModel, NativeWeights};
use crate::sim::{Engine, PredictionBatch};
use crate::trace::{GeneratorConfig, Scenario, TraceGenerator, Workload};
use crate::util::stats::percentile;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub policy: String,
    pub hierarchy: HierarchyConfig,
    pub generator: GeneratorConfig,
    /// Total sessions to admit before draining.
    pub total_sessions: u64,
    /// Pacing between admissions (0 = open loop).
    pub arrival_interval: Duration,
    pub router: RouterPolicy,
    /// Cross-worker prediction batch + deadline.
    pub predict_batch: usize,
    pub predict_deadline: Duration,
    /// Scenario-registry workload for the workers (arrivals stay
    /// router-driven); `None` uses `generator` as-is.
    pub scenario: Option<String>,
    /// Run a per-worker [`AdaptiveController`] (throttle-mode back-off).
    pub adaptive: bool,
    /// Controller thresholds when `adaptive` is on.
    pub adapt: ControllerConfig,
    /// Serve an HTTP dashboard (`/health`, `/metrics.json`, `/events`) on
    /// `127.0.0.1:<port>` for the run's duration (port 0 picks a free one).
    pub dashboard_port: Option<u16>,
    /// Keep the dashboard answering for this long after the run drains —
    /// lets external probes (CI smoke, `acpc monitor --attach`) scrape the
    /// final state before shutdown.
    pub dashboard_linger: Duration,
    /// Capture every access the workers serve into a v2 `.acpctrace`
    /// (tenant = worker index, arrival = per-worker access ordinal) for
    /// later `traffic.replay` runs.
    pub capture: Option<std::path::PathBuf>,
}

impl ServeConfig {
    pub fn quick(policy: &str) -> Self {
        let mut generator = GeneratorConfig::tiny(77);
        // Serving mode: arrivals are router-driven only.
        generator.arrival_p_hot = 0.0;
        generator.arrival_p_cold = 0.0;
        Self {
            workers: 2,
            policy: policy.into(),
            hierarchy: {
                let mut h = HierarchyConfig::scaled();
                h.prefetcher = "composite".into();
                h
            },
            generator,
            total_sessions: 24,
            arrival_interval: Duration::from_micros(200),
            router: RouterPolicy::LeastLoaded,
            predict_batch: 128,
            predict_deadline: Duration::from_millis(2),
            scenario: None,
            adaptive: false,
            adapt: ControllerConfig::default(),
            dashboard_port: None,
            dashboard_linger: Duration::ZERO,
            capture: None,
        }
    }

    /// Resolve the per-worker generator template: the scenario registry
    /// entry (arrivals zeroed — serving admission is router-driven) or the
    /// configured generator. Panics on unknown scenario names (the CLI
    /// validates before calling [`serve`]).
    fn worker_generator(&self) -> GeneratorConfig {
        match &self.scenario {
            Some(name) => {
                let sc = Scenario::by_name(name)
                    .unwrap_or_else(|| panic!("unknown scenario '{name}'"));
                let mut g = sc.config(self.generator.seed);
                g.arrival_p_hot = 0.0;
                g.arrival_p_cold = 0.0;
                g
            }
            None => self.generator.clone(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ServeReport {
    pub sessions_admitted: u64,
    pub sessions_completed: u64,
    pub sessions_rejected: u64,
    pub tokens: u64,
    pub accesses: u64,
    pub wall_secs: f64,
    pub tokens_per_sec_wall: f64,
    pub l2_hit_rate: f64,
    pub l2_pollution_ratio: f64,
    pub session_latency_ms_p50: f64,
    pub session_latency_ms_p95: f64,
    pub prediction_batches: u64,
    pub mean_batch_fill: f64,
    pub router_imbalance_max: usize,
    /// Telemetry windows observed across all workers (adaptive mode).
    ///
    /// Unlike sim/sweep/`acpc adapt` (strictly access-counted and seed-
    /// deterministic), serving mode is wall-clock driven — prediction
    /// responses race arrivals — so these three counters can vary between
    /// runs of the same seed. They are load telemetry, not reproducible
    /// metrics.
    pub adapt_windows: u64,
    /// Drift-detector firings across all workers (timing-dependent; see
    /// [`Self::adapt_windows`]).
    pub drift_events: u64,
    /// Worker-windows spent with predictions throttled (timing-dependent;
    /// see [`Self::adapt_windows`]).
    pub throttled_windows: u64,
    /// Every adaptation event each worker's controller emitted, tagged with
    /// the worker index and sorted by `(worker, access, window)`. The full
    /// list behind the three counters above (same timing caveat).
    pub adaptation_events: Vec<WorkerAdaptationEvent>,
    /// Per-tenant QoS accounting — populated only by the tenant-aware
    /// engine ([`crate::serve::run`]); classic `serve()` leaves it empty
    /// and the JSON shape unchanged.
    pub tenants: Vec<crate::serve::TenantReport>,
    /// The resolved serve spec of a spec-driven run (`acpc serve --spec`),
    /// embedded so the report reproduces its run.
    pub serve_spec: Option<Json>,
}

/// One controller [`AdaptationEvent`] attributed to its serving worker.
#[derive(Debug, Clone, Copy)]
pub struct WorkerAdaptationEvent {
    pub worker: usize,
    pub event: AdaptationEvent,
}

impl WorkerAdaptationEvent {
    pub fn to_json(&self) -> Json {
        let mut j = self.event.to_json();
        j.set("worker", Json::Num(self.worker as f64));
        j
    }
}

/// Schema tag for [`ServeReport::to_json`].
pub const SERVE_SCHEMA: &str = "acpc-serve-v1";

impl ServeReport {
    /// Machine-readable report (`acpc serve --json`), schema
    /// [`SERVE_SCHEMA`]. Adaptation events are the full per-worker list,
    /// not just the summed counters.
    pub fn to_json(&self) -> Json {
        let mut j = Json::from_pairs(vec![
            ("schema", Json::Str(SERVE_SCHEMA.into())),
            ("sessions_admitted", Json::Num(self.sessions_admitted as f64)),
            ("sessions_completed", Json::Num(self.sessions_completed as f64)),
            ("sessions_rejected", Json::Num(self.sessions_rejected as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("accesses", Json::Num(self.accesses as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("tokens_per_sec_wall", Json::Num(self.tokens_per_sec_wall)),
            ("l2_hit_rate", Json::Num(self.l2_hit_rate)),
            ("l2_pollution_ratio", Json::Num(self.l2_pollution_ratio)),
            ("session_latency_ms_p50", Json::Num(self.session_latency_ms_p50)),
            ("session_latency_ms_p95", Json::Num(self.session_latency_ms_p95)),
            ("prediction_batches", Json::Num(self.prediction_batches as f64)),
            ("mean_batch_fill", Json::Num(self.mean_batch_fill)),
            ("router_imbalance_max", Json::Num(self.router_imbalance_max as f64)),
            ("adapt_windows", Json::Num(self.adapt_windows as f64)),
            ("drift_events", Json::Num(self.drift_events as f64)),
            ("throttled_windows", Json::Num(self.throttled_windows as f64)),
            (
                "adaptation_events",
                Json::Arr(self.adaptation_events.iter().map(|e| e.to_json()).collect()),
            ),
        ]);
        // Tenant-aware extensions only when present, so classic serve
        // reports keep their exact legacy shape.
        if !self.tenants.is_empty() {
            j.set("tenants", Json::Arr(self.tenants.iter().map(|t| t.to_json()).collect()));
        }
        if let Some(spec) = &self.serve_spec {
            j.set("serve_spec", spec.clone());
        }
        j
    }
}

enum Event {
    SessionDone { worker: usize },
    Finished { stats: WorkerStats },
}

#[derive(Debug, Clone)]
struct WorkerStats {
    worker: usize,
    accesses: u64,
    tokens: u64,
    l2_hits: u64,
    l2_accesses: u64,
    l2_fills: u64,
    l2_dead_prefetch: u64,
    adapt_windows: u64,
    drift_events: u64,
    throttled_windows: u64,
    events: Vec<AdaptationEvent>,
    /// Prediction batches executed locally (shared mode; 0 in service mode,
    /// where the service thread counts instead).
    pred_batches: u64,
    /// Rows predicted locally (shared mode).
    pred_filled: u64,
    /// Served accesses in order, when [`ServeConfig::capture`] is set
    /// (paired with the per-worker arrival ordinal).
    captured: Vec<(crate::trace::Access, u64)>,
}

struct PredictReq {
    worker: usize,
    /// Controller version at send time (0 without a controller). Responses
    /// are dropped by the worker when their version no longer matches —
    /// predictions requested before a throttle must not be applied after a
    /// resume re-enables application.
    version: u64,
    lines: Vec<u64>,
    x: Vec<f32>,
}

/// (line, probability, request version) triples for one worker.
type PredictResp = Vec<(u64, f32, u64)>;

/// How serving workers obtain predictions (see the module docs).
enum PredictorMode<F: FnOnce() -> PredictorBox + Send> {
    /// One predictor service thread; the factory runs *inside* it (PJRT
    /// executables are thread-affine, `!Send`).
    Service(F),
    /// No service thread: each worker predicts locally over a
    /// [`NativeModel`] clone of this shared snapshot.
    Shared(Arc<NativeWeights>),
}

/// Run the serving node to completion with a central predictor service.
///
/// `predictor_factory` is invoked *inside* the predictor-service thread
/// (PJRT executables are thread-affine, `!Send`); `predictor_window`
/// must match what the factory will produce: 0 = no predictor
/// (`PredictorBox::None`), 1 for heuristic/DNN, the TCN window otherwise.
/// Learned predictors on the default native backend should use
/// [`serve_shared`] instead — no service thread required.
pub fn serve(
    cfg: &ServeConfig,
    predictor_window: usize,
    predictor_factory: impl FnOnce() -> PredictorBox + Send,
) -> ServeReport {
    serve_with_bus(cfg, predictor_window, predictor_factory, None)
}

/// [`serve`], streaming each worker's telemetry (source `serve/w`) onto
/// `bus`. When [`ServeConfig::dashboard_port`] is set, an HTTP dashboard is
/// served for the run's duration (plus `dashboard_linger`) — fed from the
/// caller's bus, or from an internally created one when `bus` is `None`.
pub fn serve_with_bus(
    cfg: &ServeConfig,
    predictor_window: usize,
    predictor_factory: impl FnOnce() -> PredictorBox + Send,
    bus: Option<&TelemetryBus>,
) -> ServeReport {
    run_serve(cfg, predictor_window, PredictorMode::Service(predictor_factory), bus)
}

/// Run the serving node with every worker predicting locally over one
/// shared native weight snapshot — the default path for learned predictors.
/// The predictor window comes from the snapshot itself; there is no
/// predictor service thread and no cross-thread prediction round-trip
/// (worker batches apply their utilities immediately, so a throttle can
/// never race an in-flight response).
pub fn serve_shared(
    cfg: &ServeConfig,
    weights: Arc<NativeWeights>,
    bus: Option<&TelemetryBus>,
) -> ServeReport {
    let window = weights.window();
    run_serve::<fn() -> PredictorBox>(cfg, window, PredictorMode::Shared(weights), bus)
}

fn run_serve<F: FnOnce() -> PredictorBox + Send>(
    cfg: &ServeConfig,
    predictor_window: usize,
    mode: PredictorMode<F>,
    bus: Option<&TelemetryBus>,
) -> ServeReport {
    let t0 = Instant::now();
    // The dashboard needs a bus to subscribe to; synthesize one when the
    // caller wants the endpoint but didn't attach their own.
    let internal_bus =
        (bus.is_none() && cfg.dashboard_port.is_some()).then(TelemetryBus::new);
    let bus = bus.or(internal_bus.as_ref());
    let dashboard = cfg.dashboard_port.and_then(|port| {
        let sub = bus.expect("dashboard_port implies a bus").subscribe();
        match start_dashboard(port, sub) {
            Ok(h) => {
                crate::log_info!("dashboard: listening on http://{}/", h.addr());
                Some(h)
            }
            Err(e) => {
                crate::log_warn!("dashboard: disabled: {e:#}");
                None
            }
        }
    });
    let report = serve_inner(cfg, predictor_window, mode, bus, t0);
    if let Some(dash) = dashboard {
        if !cfg.dashboard_linger.is_zero() {
            crate::log_info!(
                "dashboard: run drained; lingering {:?} at http://{}/",
                cfg.dashboard_linger,
                dash.addr()
            );
            std::thread::sleep(cfg.dashboard_linger);
        }
        dash.shutdown();
    }
    report
}

fn serve_inner<F: FnOnce() -> PredictorBox + Send>(
    cfg: &ServeConfig,
    predictor_window: usize,
    mode: PredictorMode<F>,
    bus: Option<&TelemetryBus>,
    t0: Instant,
) -> ServeReport {
    let (service_factory, shared) = match mode {
        PredictorMode::Service(f) => (Some(f), None),
        PredictorMode::Shared(w) => (None, Some(w)),
    };
    let done = Arc::new(AtomicBool::new(false));
    let use_pred = predictor_window > 0;
    let window = predictor_window.max(1);
    let row = if predictor_window <= 1 { FEATURE_DIM } else { window * FEATURE_DIM };
    // Resolve the worker workload template up front: an unknown scenario
    // name panics here on the caller's thread with a clear message, not
    // inside a spawned worker (the CLI validates the name before calling).
    let worker_template = cfg.worker_generator();

    let (ev_tx, ev_rx) = mpsc::channel::<Event>();
    let (pr_tx, pr_rx) = mpsc::channel::<PredictReq>();

    std::thread::scope(|s| {
        // ---- predictor service ------------------------------------------
        let mut resp_txs: Vec<mpsc::Sender<PredictResp>> = Vec::new();
        let mut resp_rxs: Vec<mpsc::Receiver<PredictResp>> = Vec::new();
        for _ in 0..cfg.workers {
            let (tx, rx) = mpsc::channel::<PredictResp>();
            resp_txs.push(tx);
            resp_rxs.push(rx);
        }
        let pred_deadline = cfg.predict_deadline;
        let pred_batch = cfg.predict_batch;
        // Shared mode runs no service thread — workers predict locally, and
        // pr_rx is simply dropped (workers never send in that mode).
        let pred_stats = service_factory.map(|predictor_factory| {
            s.spawn(move || {
                // Construct inside the thread: PJRT handles are !Send.
                let mut predictor = predictor_factory();
                let mut batcher: DynamicBatcher<(usize, u64, u64)> =
                    DynamicBatcher::new(row, pred_batch, pred_deadline);
                let mut batches = 0u64;
                let mut filled = 0u64;
                let flush = |batcher: &mut DynamicBatcher<(usize, u64, u64)>,
                             predictor: &mut PredictorBox,
                             by_deadline: bool,
                             batches: &mut u64,
                             filled: &mut u64| {
                    if batcher.is_empty() {
                        return;
                    }
                    let (tags, x, n) = batcher.flush(by_deadline);
                    let probs = predictor.predict(&x, n);
                    *batches += 1;
                    *filled += n as u64;
                    let mut grouped: HashMap<usize, PredictResp> = HashMap::new();
                    for ((w, line, ver), p) in tags.into_iter().zip(probs) {
                        grouped.entry(w).or_default().push((line, p, ver));
                    }
                    for (w, resp) in grouped {
                        let _ = resp_txs[w].send(resp);
                    }
                };
                loop {
                    match pr_rx.recv_timeout(pred_deadline) {
                        Ok(req) => {
                            for (i, &line) in req.lines.iter().enumerate() {
                                let full = batcher.push(
                                    (req.worker, line, req.version),
                                    &req.x[i * row..(i + 1) * row],
                                );
                                if full {
                                    flush(
                                        &mut batcher,
                                        &mut predictor,
                                        false,
                                        &mut batches,
                                        &mut filled,
                                    );
                                }
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if batcher.deadline_expired() {
                                flush(&mut batcher, &mut predictor, true, &mut batches, &mut filled);
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            flush(&mut batcher, &mut predictor, true, &mut batches, &mut filled);
                            break;
                        }
                    }
                }
                (batches, filled)
            })
        });

        // ---- workers ------------------------------------------------------
        let mut admit_txs: Vec<mpsc::Sender<()>> = Vec::new();
        for w in 0..cfg.workers {
            let (admit_tx, admit_rx) = mpsc::channel::<()>();
            admit_txs.push(admit_tx);
            let ev_tx = ev_tx.clone();
            let pr_tx = pr_tx.clone();
            let resp_rx = std::mem::replace(&mut resp_rxs[w], mpsc::channel().1);
            let done = done.clone();
            let mut gcfg = worker_template.clone();
            gcfg.seed = cfg.generator.seed.wrapping_add(w as u64 * 7919);
            let hcfg = cfg.hierarchy.clone();
            let policy = cfg.policy.clone();
            let adaptive = cfg.adaptive;
            let acfg = cfg.adapt.clone();
            // Created dispatcher-side so the per-source (serve/w) sequence
            // counter has exactly one owner.
            let mut publisher = bus.map(|b| b.publisher(SourceId::serve(w)));
            let shared_w = shared.clone();
            let capture_on = cfg.capture.is_some();
            s.spawn(move || {
                // The shared engine drives this worker's accesses; its
                // feature rows are shipped to the predictor service rather
                // than flushed inline.
                let geom = GeometryHints::from_generator(&gcfg);
                let mut workload: Box<dyn Workload> = Box::new(TraceGenerator::new(gcfg));
                let mut engine =
                    Engine::new(hcfg, &policy, geom, if use_pred { window } else { 0 });
                const LOCAL_BATCH: usize = 32;
                let mut batch = PredictionBatch::new(engine.row(), LOCAL_BATCH);
                let mut completed_seen = 0u64;
                // Worker-local adaptive back-off: the model is owned by the
                // predictor service thread (`PredictorAccess::Remote`), so
                // on drift this controller throttles (stops applying
                // utilities) rather than retrains.
                let mut controller =
                    if adaptive && use_pred { Some(AdaptiveController::new(acfg)) } else { None };
                // Shared mode: this worker's own predictor over the shared
                // snapshot — batches predict here, never cross a channel.
                let mut local_model = shared_w.map(NativeModel::from_weights);
                let mut local_probs: Vec<f32> = Vec::new();
                let (mut local_batches, mut local_filled) = (0u64, 0u64);
                let mut captured: Vec<(crate::trace::Access, u64)> = Vec::new();

                loop {
                    // One throttle gate per iteration: it governs both the
                    // response drain (in-flight predictions that raced the
                    // throttle) and the request path below, so the two can
                    // never diverge. Throttled workers neither buffer rows
                    // nor ship work to the predictor service, and the
                    // version match discards late responses to requests
                    // from a previous throttle regime — those utilities
                    // were explicitly flushed and must not return.
                    let (apply, cur_version) = controller
                        .as_ref()
                        .map(|c| (c.apply_predictions(), c.version()))
                        .unwrap_or((true, 0));
                    while admit_rx.try_recv().is_ok() {
                        workload.force_arrival();
                    }
                    while let Ok(resp) = resp_rx.try_recv() {
                        if apply {
                            for (line, p, ver) in resp {
                                if ver == cur_version {
                                    engine.update_utility(line, p);
                                }
                            }
                        }
                    }
                    if workload.has_work() {
                        let a = workload.next_access();
                        if capture_on {
                            captured.push((a, captured.len() as u64));
                        }
                        let full = match engine.step(&a, None) {
                            Some(feats) => apply && batch.push(a.line(), feats),
                            None => false,
                        };
                        if let Some(c) = controller.as_mut() {
                            c.observe_access(engine.steps(), a.line());
                            let (windows_before, drifts_before, events_before) =
                                (c.windows(), c.drift_count(), c.events().len());
                            let decision = c.maybe_window(
                                engine.steps(),
                                &engine.hier,
                                PredictorAccess::Remote,
                            );
                            if let Some(p) = publisher.as_mut() {
                                let steps = engine.steps();
                                if c.windows() > windows_before {
                                    if let Some(stats) = c.last_window() {
                                        p.publish(
                                            steps,
                                            Payload::Window { stats, throttled: c.throttled() },
                                        );
                                        if c.drift_count() > drifts_before {
                                            let drift = Payload::Drift { window: stats.index };
                                            p.publish(steps, drift);
                                        }
                                    }
                                }
                                for e in &c.events()[events_before..] {
                                    p.publish(steps, Payload::Adaptation(*e));
                                }
                            }
                            match decision {
                                Some(ControlDecision::Throttled) => {
                                    engine.hier.clear_utilities();
                                    // Prefetching also turns conservative
                                    // for the throttle's duration.
                                    engine.hier.set_prefetch_throttled(true);
                                    // Drop rows captured pre-throttle: they
                                    // would otherwise flush after resume and
                                    // re-stamp old-regime predictions.
                                    let _ = batch.take();
                                }
                                Some(ControlDecision::Resumed)
                                | Some(ControlDecision::Retrained) => {
                                    engine.hier.set_prefetch_throttled(false);
                                }
                                None => {}
                            }
                        }
                        if publisher.is_some() && engine.steps() % SAMPLE_PERIOD == 0 {
                            let throttled =
                                controller.as_ref().map(|c| c.throttled()).unwrap_or(false);
                            let l2 = &engine.hier.l2;
                            let sample = Payload::Sample {
                                occupancy: l2.occupancy(),
                                hit_rate: l2.stats.hit_rate(),
                                pollution: l2.stats.pollution_ratio(),
                                throttled,
                            };
                            if let Some(p) = publisher.as_mut() {
                                p.publish(engine.steps(), sample);
                            }
                        }
                        if full {
                            let (lines, x) = batch.take();
                            // A throttle decision on this very access may
                            // have just drained the batch; don't ship an
                            // empty request.
                            if !lines.is_empty() {
                                if let Some(m) = local_model.as_mut() {
                                    // Shared mode: predict in place and
                                    // apply immediately — same throttle
                                    // regime that admitted the rows, so no
                                    // version check is needed.
                                    let n = lines.len();
                                    m.predict_into(&x, n, &mut local_probs);
                                    local_batches += 1;
                                    local_filled += n as u64;
                                    for (&line, &p) in lines.iter().zip(local_probs.iter()) {
                                        engine.update_utility(line, p);
                                    }
                                } else {
                                    let _ = pr_tx.send(PredictReq {
                                        worker: w,
                                        version: cur_version,
                                        lines,
                                        x,
                                    });
                                }
                            }
                        }
                        let c = workload.sessions_completed();
                        while completed_seen < c {
                            completed_seen += 1;
                            let _ = ev_tx.send(Event::SessionDone { worker: w });
                        }
                    } else if done.load(Ordering::Relaxed) {
                        break;
                    } else {
                        std::thread::sleep(Duration::from_micros(50));
                    }
                }
                let (adapt_windows, drift_events, throttled_windows, events) = controller
                    .map(|c| {
                        (c.windows(), c.drift_count(), c.throttled_windows(), c.events().to_vec())
                    })
                    .unwrap_or((0, 0, 0, Vec::new()));
                let l2 = &engine.hier.l2.stats;
                let stats = WorkerStats {
                    worker: w,
                    accesses: engine.hier.accesses,
                    tokens: workload.tokens_done(),
                    l2_hits: l2.demand_hits,
                    l2_accesses: l2.demand_accesses,
                    l2_fills: l2.demand_misses + l2.prefetch_fills,
                    l2_dead_prefetch: l2.dead_prefetch_evictions,
                    adapt_windows,
                    drift_events,
                    throttled_windows,
                    events,
                    pred_batches: local_batches,
                    pred_filled: local_filled,
                    captured,
                };
                let _ = ev_tx.send(Event::Finished { stats });
            });
        }
        drop(ev_tx);
        drop(pr_tx);

        // ---- main: arrivals + bookkeeping ---------------------------------
        // Per-worker admission capacity must match the *resolved* workload
        // (scenario templates carry their own KV slot counts).
        let mut router =
            Router::new(cfg.router, cfg.workers, worker_template.max_live_sessions);
        let mut admit_times: Vec<std::collections::VecDeque<Instant>> =
            vec![Default::default(); cfg.workers];
        let mut latencies_ms: Vec<f64> = Vec::new();
        let mut completed = 0u64;
        let mut admitted = 0u64;
        let mut max_imbalance = 0usize;

        let handle_event = |ev: Event,
                                router: &mut Router,
                                admit_times: &mut Vec<std::collections::VecDeque<Instant>>,
                                latencies: &mut Vec<f64>,
                                completed: &mut u64|
         -> Option<WorkerStats> {
            match ev {
                Event::SessionDone { worker } => {
                    router.complete(worker);
                    if let Some(t) = admit_times[worker].pop_front() {
                        latencies.push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    *completed += 1;
                    None
                }
                Event::Finished { stats, .. } => Some(stats),
            }
        };

        while admitted < cfg.total_sessions {
            if let Some(wkr) = router.route() {
                let _ = admit_txs[wkr].send(());
                admit_times[wkr].push_back(Instant::now());
                admitted += 1;
                max_imbalance = max_imbalance.max(router.imbalance());
                if !cfg.arrival_interval.is_zero() {
                    std::thread::sleep(cfg.arrival_interval);
                }
            } else {
                // Full: wait for a completion.
                if let Ok(ev) = ev_rx.recv_timeout(Duration::from_millis(50)) {
                    handle_event(ev, &mut router, &mut admit_times, &mut latencies_ms, &mut completed);
                }
            }
            while let Ok(ev) = ev_rx.try_recv() {
                handle_event(ev, &mut router, &mut admit_times, &mut latencies_ms, &mut completed);
            }
        }
        done.store(true, Ordering::Relaxed);
        drop(admit_txs);

        // Drain until all workers report Finished.
        let mut stats: Vec<WorkerStats> = Vec::new();
        while stats.len() < cfg.workers {
            match ev_rx.recv_timeout(Duration::from_secs(30)) {
                Ok(ev) => {
                    if let Some(st) =
                        handle_event(ev, &mut router, &mut admit_times, &mut latencies_ms, &mut completed)
                    {
                        stats.push(st);
                    }
                }
                Err(_) => break,
            }
        }
        // Service-mode counters come from the service thread; shared-mode
        // counters are summed from the workers (exactly one side is nonzero).
        let (mut pred_batches, mut pred_filled) =
            pred_stats.map(|h| h.join().unwrap_or((0, 0))).unwrap_or((0, 0));
        pred_batches += stats.iter().map(|s| s.pred_batches).sum::<u64>();
        pred_filled += stats.iter().map(|s| s.pred_filled).sum::<u64>();

        let wall = t0.elapsed().as_secs_f64();
        let tokens: u64 = stats.iter().map(|s| s.tokens).sum();
        let accesses: u64 = stats.iter().map(|s| s.accesses).sum();
        let l2_hits: u64 = stats.iter().map(|s| s.l2_hits).sum();
        let l2_acc: u64 = stats.iter().map(|s| s.l2_accesses).sum();
        let l2_fills: u64 = stats.iter().map(|s| s.l2_fills).sum();
        let l2_dead: u64 = stats.iter().map(|s| s.l2_dead_prefetch).sum();
        let adapt_windows: u64 = stats.iter().map(|s| s.adapt_windows).sum();
        let drift_events: u64 = stats.iter().map(|s| s.drift_events).sum();
        let throttled_windows: u64 = stats.iter().map(|s| s.throttled_windows).sum();
        let mut adaptation_events: Vec<WorkerAdaptationEvent> = stats
            .iter()
            .flat_map(|s| {
                s.events.iter().map(|&event| WorkerAdaptationEvent { worker: s.worker, event })
            })
            .collect();
        adaptation_events.sort_by_key(|e| (e.worker, e.event.access, e.event.window));

        if let Some(path) = &cfg.capture {
            // Workers finish in nondeterministic order; sort by worker index
            // so the capture layout is a pure function of what was served.
            stats.sort_by_key(|s| s.worker);
            let mut sink = crate::traffic::CaptureSink::new();
            for s in &stats {
                for &(a, arrival) in &s.captured {
                    sink.record(a, s.worker as u32, arrival);
                }
            }
            sink.set_totals(tokens, completed);
            match sink.finish(path) {
                Ok(()) => crate::log_info!(
                    "serve: captured {} accesses to {}",
                    sink.len(),
                    path.display()
                ),
                Err(e) => crate::log_warn!("serve: capture to {} failed: {e}", path.display()),
            }
        }

        ServeReport {
            sessions_admitted: admitted,
            sessions_completed: completed,
            sessions_rejected: router.rejected,
            tokens,
            accesses,
            wall_secs: wall,
            tokens_per_sec_wall: tokens as f64 / wall,
            l2_hit_rate: l2_hits as f64 / l2_acc.max(1) as f64,
            l2_pollution_ratio: l2_dead as f64 / l2_fills.max(1) as f64,
            session_latency_ms_p50: percentile(&latencies_ms, 50.0),
            session_latency_ms_p95: percentile(&latencies_ms, 95.0),
            prediction_batches: pred_batches,
            mean_batch_fill: if pred_batches > 0 {
                pred_filled as f64 / pred_batches as f64
            } else {
                0.0
            },
            router_imbalance_max: max_imbalance,
            adapt_windows,
            drift_events,
            throttled_windows,
            adaptation_events,
            tenants: Vec::new(),
            serve_spec: None,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::HeuristicPredictor;

    #[test]
    fn serve_completes_sessions_classic_policy() {
        let mut cfg = ServeConfig::quick("srrip");
        cfg.total_sessions = 10;
        let rep = serve(&cfg, 0, || PredictorBox::None);
        assert_eq!(rep.sessions_admitted, 10);
        assert!(rep.sessions_completed >= 9, "completed {}", rep.sessions_completed);
        assert!(rep.tokens > 50);
        assert!(rep.l2_hit_rate > 0.0 && rep.l2_hit_rate < 1.0);
        assert!(rep.tokens_per_sec_wall > 0.0);
    }

    #[test]
    fn serve_with_heuristic_predictor_batches() {
        let mut cfg = ServeConfig::quick("acpc");
        cfg.total_sessions = 8;
        let rep = serve(&cfg, 1, || PredictorBox::Heuristic(HeuristicPredictor));
        assert!(rep.prediction_batches > 0, "predictor service must run");
        assert!(rep.mean_batch_fill > 1.0, "batching must amortize: {}", rep.mean_batch_fill);
        assert!(rep.sessions_completed >= 7);
        assert_eq!(rep.adapt_windows, 0, "adaptive off by default");
    }

    #[test]
    fn serve_pulls_scenario_registry_workloads() {
        let mut cfg = ServeConfig::quick("srrip");
        cfg.scenario = Some("rag-embedding".into());
        cfg.total_sessions = 8;
        // The resolved template must come from the registry with arrivals
        // disabled for router-driven admission.
        let g = cfg.worker_generator();
        assert_eq!(g.profile.name, "rag-embedding");
        assert_eq!(g.arrival_p_hot, 0.0);
        assert_eq!(g.arrival_p_cold, 0.0);
        let rep = serve(&cfg, 0, || PredictorBox::None);
        assert_eq!(rep.sessions_admitted, 8);
        assert!(rep.sessions_completed >= 7, "completed {}", rep.sessions_completed);
        assert!(rep.tokens > 0);
    }

    #[test]
    fn serve_adaptive_mode_ticks_worker_controllers() {
        let mut cfg = ServeConfig::quick("acpc");
        cfg.total_sessions = 12;
        cfg.adaptive = true;
        cfg.adapt = crate::adapt::ControllerConfig::quick();
        cfg.adapt.window_accesses = 1024;
        let rep = serve(&cfg, 1, || PredictorBox::Heuristic(HeuristicPredictor));
        assert!(rep.sessions_completed >= 10, "completed {}", rep.sessions_completed);
        assert!(rep.adapt_windows > 0, "workers must harvest telemetry windows");
    }

    #[test]
    fn serve_with_bus_streams_worker_windows_and_reports_events() {
        let mut cfg = ServeConfig::quick("acpc");
        cfg.total_sessions = 12;
        cfg.adaptive = true;
        cfg.adapt = crate::adapt::ControllerConfig::quick();
        cfg.adapt.window_accesses = 1024;
        let bus = TelemetryBus::new();
        let mut sub = bus.subscribe();
        let rep = serve_with_bus(
            &cfg,
            1,
            || PredictorBox::Heuristic(HeuristicPredictor),
            Some(&bus),
        );
        assert!(rep.adapt_windows > 0, "workers must harvest telemetry windows");

        let mut events = Vec::new();
        sub.drain(&mut events);
        let windows = events
            .iter()
            .filter(|e| matches!(e.payload, Payload::Window { .. }))
            .count() as u64;
        // Every controller window publishes exactly one Window event (the
        // ring only drops under a lagging subscriber, not at this scale).
        if sub.dropped() == 0 {
            assert_eq!(windows, rep.adapt_windows);
        }
        assert!(windows > 0, "window events must reach the bus");
        assert!(events.iter().all(|e| e.source.kind == crate::obs::SourceKind::Serve));

        // The report carries the full per-worker event list, sorted.
        assert!(rep
            .adaptation_events
            .windows(2)
            .all(|p| (p[0].worker, p[0].event.access) <= (p[1].worker, p[1].event.access)));
        let j = rep.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(SERVE_SCHEMA));
        assert_eq!(
            j.get("adaptation_events").unwrap().as_arr().unwrap().len(),
            rep.adaptation_events.len()
        );
    }

    /// Shared mode: every worker predicts over one native snapshot — no
    /// service thread — and the batch counters still land in the report.
    /// Runs on synthetic weights, so it needs no artifacts.
    #[test]
    fn serve_shared_predicts_locally_without_service_thread() {
        let (mm, store) =
            crate::runtime::synthetic_model("tcn", 8, FEATURE_DIM, 8, &[1, 2], 0xC0FFEE);
        let weights = Arc::new(NativeWeights::from_params(&mm, &store).unwrap());
        let mut cfg = ServeConfig::quick("acpc");
        cfg.total_sessions = 12;
        cfg.adaptive = true;
        cfg.adapt = crate::adapt::ControllerConfig::quick();
        cfg.adapt.window_accesses = 1024;
        let rep = serve_shared(&cfg, weights, None);
        assert!(rep.prediction_batches > 0, "workers must predict locally");
        assert!(
            rep.mean_batch_fill > 1.0,
            "local batching must amortize: {}",
            rep.mean_batch_fill
        );
        assert!(rep.sessions_completed >= 10, "completed {}", rep.sessions_completed);
        assert!(rep.adapt_windows > 0, "shared mode still ticks worker controllers");
    }

    #[test]
    fn serve_with_dashboard_port_completes_clean() {
        let mut cfg = ServeConfig::quick("srrip");
        cfg.total_sessions = 6;
        cfg.dashboard_port = Some(0); // free port; endpoint exercised via obs::http tests
        cfg.dashboard_linger = Duration::ZERO;
        let rep = serve(&cfg, 0, || PredictorBox::None);
        assert_eq!(rep.sessions_admitted, 6);
    }
}
