//! Session router: assigns incoming inference sessions to workers.

/// Routing discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastLoaded,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rr" | "round_robin" => Some(Self::RoundRobin),
            "least" | "least_loaded" => Some(Self::LeastLoaded),
            _ => None,
        }
    }
}

/// Tracks per-worker load (outstanding sessions / queue depth) and picks
/// targets. Loads are updated by the server as sessions start/finish.
#[derive(Debug)]
pub struct Router {
    policy: RouterPolicy,
    loads: Vec<usize>,
    /// Per-worker admission capacity (KV slots).
    capacity: Vec<usize>,
    rr_next: usize,
    pub admitted: u64,
    pub rejected: u64,
}

impl Router {
    pub fn new(policy: RouterPolicy, workers: usize, capacity_per_worker: usize) -> Self {
        Self {
            policy,
            loads: vec![0; workers],
            capacity: vec![capacity_per_worker; workers],
            rr_next: 0,
            admitted: 0,
            rejected: 0,
        }
    }

    pub fn workers(&self) -> usize {
        self.loads.len()
    }

    pub fn load(&self, w: usize) -> usize {
        self.loads[w]
    }

    /// Pick a worker for a new session; `None` when every worker is full
    /// (admission control — the request would be queued/rejected upstream).
    pub fn route(&mut self) -> Option<usize> {
        let n = self.loads.len();
        let pick = match self.policy {
            RouterPolicy::RoundRobin => {
                (0..n).map(|i| (self.rr_next + i) % n).find(|&w| self.loads[w] < self.capacity[w])
            }
            RouterPolicy::LeastLoaded => (0..n)
                .filter(|&w| self.loads[w] < self.capacity[w])
                .min_by_key(|&w| self.loads[w]),
        };
        match pick {
            Some(w) => {
                self.loads[w] += 1;
                self.admitted += 1;
                if self.policy == RouterPolicy::RoundRobin {
                    self.rr_next = (w + 1) % n;
                }
                Some(w)
            }
            None => {
                self.rejected += 1;
                None
            }
        }
    }

    /// Session finished on worker `w`.
    pub fn complete(&mut self, w: usize) {
        assert!(self.loads[w] > 0, "completion without admission on worker {w}");
        self.loads[w] -= 1;
    }

    /// Max/min load imbalance (diagnostics + tests).
    pub fn imbalance(&self) -> usize {
        let max = self.loads.iter().max().copied().unwrap_or(0);
        let min = self.loads.iter().min().copied().unwrap_or(0);
        max - min
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 4, 8);
        for _ in 0..16 {
            r.route().unwrap();
        }
        assert_eq!(r.imbalance(), 0);
        assert_eq!(r.admitted, 16);
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RouterPolicy::RoundRobin, 3, 10);
        let seq: Vec<usize> = (0..6).map(|_| r.route().unwrap()).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 2, 1);
        assert!(r.route().is_some());
        assert!(r.route().is_some());
        assert!(r.route().is_none());
        assert_eq!(r.rejected, 1);
        r.complete(0);
        assert_eq!(r.route(), Some(0));
    }

    #[test]
    fn least_loaded_prefers_freed_worker() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 3, 4);
        for _ in 0..9 {
            r.route();
        }
        r.complete(1);
        r.complete(1);
        assert_eq!(r.route(), Some(1));
    }

    #[test]
    #[should_panic]
    fn completion_underflow_panics() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 1, 1);
        r.complete(0);
    }
}
