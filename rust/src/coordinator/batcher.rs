//! Size-or-deadline dynamic batcher for predictor queries.
//!
//! Workers enqueue (tag, feature-row) requests; the batch flushes when it
//! reaches `max_batch` or when the oldest entry exceeds `max_wait`. The
//! same policy a serving engine applies to model invocations — here it
//! amortizes PJRT dispatch overhead across workers (measured by
//! `benches/coordinator_throughput.rs`).

use std::time::{Duration, Instant};

/// One pending request: an opaque tag (e.g. (worker, line)) + feature row.
#[derive(Debug, Clone)]
pub struct Pending<T> {
    pub tag: T,
    pub row_offset: usize,
}

pub struct DynamicBatcher<T> {
    row: usize,
    max_batch: usize,
    max_wait: Duration,
    x: Vec<f32>,
    pending: Vec<Pending<T>>,
    oldest: Option<Instant>,
    pub flushes_size: u64,
    pub flushes_deadline: u64,
}

impl<T> DynamicBatcher<T> {
    pub fn new(row: usize, max_batch: usize, max_wait: Duration) -> Self {
        Self {
            row,
            max_batch,
            max_wait,
            x: Vec::with_capacity(row * max_batch),
            pending: Vec::with_capacity(max_batch),
            oldest: None,
            flushes_size: 0,
            flushes_deadline: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Enqueue one request. Returns true if the batch is now full
    /// (caller should flush).
    pub fn push(&mut self, tag: T, features: &[f32]) -> bool {
        assert_eq!(features.len(), self.row);
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(Pending { tag, row_offset: self.x.len() });
        self.x.extend_from_slice(features);
        self.pending.len() >= self.max_batch
    }

    /// Deadline check (call on a timer / loop tick).
    pub fn deadline_expired(&self) -> bool {
        matches!(self.oldest, Some(t) if t.elapsed() >= self.max_wait) && !self.pending.is_empty()
    }

    /// Drain the batch: returns (tags, x, n). Caller runs the predictor and
    /// pairs `probs[i]` with `tags[i]`.
    pub fn flush(&mut self, by_deadline: bool) -> (Vec<T>, Vec<f32>, usize) {
        if by_deadline {
            self.flushes_deadline += 1;
        } else {
            self.flushes_size += 1;
        }
        let n = self.pending.len();
        let tags = self.pending.drain(..).map(|p| p.tag).collect();
        let x = std::mem::take(&mut self.x);
        self.oldest = None;
        (tags, x, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_on_size() {
        let mut b: DynamicBatcher<u32> = DynamicBatcher::new(2, 3, Duration::from_secs(10));
        assert!(!b.push(1, &[0.0, 0.1]));
        assert!(!b.push(2, &[0.2, 0.3]));
        assert!(b.push(3, &[0.4, 0.5]));
        let (tags, x, n) = b.flush(false);
        assert_eq!(tags, vec![1, 2, 3]);
        assert_eq!(n, 3);
        assert_eq!(x.len(), 6);
        assert!(b.is_empty());
        assert_eq!(b.flushes_size, 1);
    }

    #[test]
    fn deadline_fires_only_with_content() {
        let mut b: DynamicBatcher<u32> = DynamicBatcher::new(1, 100, Duration::from_millis(1));
        assert!(!b.deadline_expired());
        b.push(7, &[1.0]);
        std::thread::sleep(Duration::from_millis(5));
        assert!(b.deadline_expired());
        let (tags, _, _) = b.flush(true);
        assert_eq!(tags, vec![7]);
        assert!(!b.deadline_expired(), "empty batcher has no deadline");
        assert_eq!(b.flushes_deadline, 1);
    }

    #[test]
    fn rows_keep_alignment() {
        let mut b: DynamicBatcher<usize> = DynamicBatcher::new(3, 4, Duration::from_secs(1));
        for i in 0..4 {
            b.push(i, &[i as f32; 3]);
        }
        let (tags, x, n) = b.flush(false);
        for (i, &tag) in tags.iter().enumerate() {
            assert_eq!(x[i * 3], tag as f32);
        }
        assert_eq!(n, 4);
    }
}
