//! Serving-style coordinator — the L3 system wrapper that turns the
//! simulator into a multi-worker "LLM serving node" (DESIGN.md S16):
//!
//! - [`router`]: admits incoming sessions to workers (least-loaded /
//!   round-robin), the request-routing role of a vLLM-style frontend;
//! - [`batcher`]: size-or-deadline dynamic batching of predictor queries —
//!   the same discipline a serving engine uses for model invocations;
//! - [`server`]: worker threads (each owning a cache hierarchy + its
//!   sessions), connected by channels. Python never appears — learned
//!   predictors default to per-worker native-kernel inference over one
//!   shared weight snapshot ([`serve_shared`]); the `backend: pjrt` escape
//!   hatch instead runs a central predictor service thread executing the
//!   AOT artifacts via PJRT.

pub mod batcher;
pub mod router;
pub mod server;

pub use batcher::DynamicBatcher;
pub use router::{Router, RouterPolicy};
pub use server::{
    serve, serve_shared, serve_with_bus, ServeConfig, ServeReport, WorkerAdaptationEvent,
    SERVE_SCHEMA,
};
