//! Rust-driven training of the AOT-compiled predictors (§3.4 + §4.2): the
//! compiled `train_step` HLO (BCE + Adam, lr 1e-4, batch 512) is replayed
//! from rust over the labeled dataset — Python never runs. Reproduces the
//! paper's Figure 2 loss curve and the "final loss" column of Table 1.

mod implicit;
mod trainer;

pub use implicit::{bce, implicit_loss, ImplicitKind};
pub use trainer::{eval_split, train, TrainConfig, TrainResult};
