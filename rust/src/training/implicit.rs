//! "Final loss" for non-learned policies (Table 1's LRU 0.84 / RRIP 0.69
//! cells). A classic replacement policy has no training loss; the only
//! measurable interpretation (DESIGN.md §5) is the BCE of the *implicit
//! reuse predictor* the policy embodies, evaluated against ground-truth
//! labels on the test split:
//!
//! - **LRU** ranks by recency alone ⇒ p(reuse) = 1 − normalized recency
//!   (our feature f4). Monotone but poorly calibrated ⇒ high BCE.
//! - **RRIP** quantizes re-reference predictions to 2 bits ⇒ a 4-level
//!   staircase over the same signal, with levels set to the RRIP insert
//!   semantics ⇒ better calibrated ⇒ lower BCE.

use crate::predictor::dataset::Dataset;
use crate::predictor::feature::FEATURE_DIM;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImplicitKind {
    Lru,
    Rrip,
}

/// Numerically-safe binary cross-entropy of probabilities vs labels.
pub fn bce(probs: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    let eps = 1e-6f64;
    let mut s = 0.0;
    for (&p, &y) in probs.iter().zip(labels) {
        let p = (p as f64).clamp(eps, 1.0 - eps);
        s -= y as f64 * p.ln() + (1.0 - y as f64) * (1.0 - p).ln();
    }
    s / probs.len() as f64
}

fn implicit_prob(kind: ImplicitKind, recency_f4: f32) -> f32 {
    match kind {
        // LRU: linear in (inverse) recency, optimistic at the fresh end.
        ImplicitKind::Lru => (1.0 - recency_f4).clamp(0.02, 0.98),
        // RRIP: 2-bit staircase (RRPV 0..3 → high..distant re-reference).
        ImplicitKind::Rrip => {
            if recency_f4 < 0.25 {
                0.85
            } else if recency_f4 < 0.45 {
                0.65
            } else if recency_f4 < 0.65 {
                0.4
            } else {
                0.12
            }
        }
    }
}

/// BCE of the implicit predictor over the given sample indices.
pub fn implicit_loss(kind: ImplicitKind, ds: &Dataset, idx: &[usize]) -> f64 {
    let mut probs = Vec::with_capacity(idx.len());
    let mut labels = Vec::with_capacity(idx.len());
    for &i in idx {
        let f4 = ds.x_cur[i * FEATURE_DIM + 4];
        probs.push(implicit_prob(kind, f4));
        labels.push(ds.y[i]);
    }
    bce(&probs, &labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::GeometryHints;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    #[test]
    fn bce_basics() {
        assert!(bce(&[0.99, 0.01], &[1.0, 0.0]) < 0.02);
        assert!(bce(&[0.01, 0.99], &[1.0, 0.0]) > 4.0);
        let chance = bce(&[0.5, 0.5], &[1.0, 0.0]);
        assert!((chance - std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn rrip_implicit_beats_lru_implicit() {
        // On a real generated trace, the 2-bit staircase should be better
        // calibrated than raw LRU recency — matching the Table 1 ordering.
        let gcfg = GeneratorConfig::tiny(8);
        let geom = GeometryHints::from_generator(&gcfg);
        let trace = TraceGenerator::new(gcfg).generate(60_000);
        let ds = Dataset::build(&trace, 4, geom, 2048, 4);
        let idx: Vec<usize> = (0..ds.n).collect();
        let lru = implicit_loss(ImplicitKind::Lru, &ds, &idx);
        let rrip = implicit_loss(ImplicitKind::Rrip, &ds, &idx);
        assert!(lru.is_finite() && rrip.is_finite());
        assert!(rrip < lru, "rrip {rrip:.3} vs lru {lru:.3}");
        // Order of magnitude of the paper's cells (0.84 / 0.69).
        assert!(lru > 0.4 && lru < 1.6, "{lru}");
        assert!(rrip > 0.3 && rrip < 1.2, "{rrip}");
    }
}
