//! Epoch loop: minibatch the train split, drive the compiled Adam step,
//! track train/val curves, early-stop on validation loss (§4.2).

use crate::predictor::{Dataset, ModelRuntime, Split};
use crate::util::rng::Xoshiro256;
use crate::util::stats::Welford;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    /// Early stopping: stop after this many epochs without val improvement
    /// (0 disables).
    pub patience: usize,
    /// Cap on train minibatches per epoch (0 = full epoch) — keeps smoke
    /// tests and benches fast while the full run uses everything.
    pub max_batches_per_epoch: usize,
    pub seed: u64,
    /// Print progress every N epochs (0 = silent).
    pub verbose_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { epochs: 80, patience: 10, max_batches_per_epoch: 0, seed: 1, verbose_every: 10 }
    }
}

#[derive(Debug, Clone)]
pub struct TrainResult {
    pub model: String,
    pub train_curve: Vec<f64>,
    pub val_curve: Vec<f64>,
    pub final_train_loss: f64,
    pub final_val_loss: f64,
    pub best_val_loss: f64,
    pub epochs_run: usize,
    pub stopped_early: bool,
}

impl TrainResult {
    /// Convergence-stability descriptor for Table 1: the standard deviation
    /// of the last quarter of the training curve, bucketed.
    pub fn stability(&self) -> String {
        let tail = &self.train_curve[self.train_curve.len() * 3 / 4..];
        if tail.len() < 2 {
            return "n/a".into();
        }
        let mut w = Welford::new();
        for &x in tail {
            w.push(x);
        }
        let cv = w.stddev() / w.mean().abs().max(1e-9);
        if cv < 0.02 {
            "Highly Stable".into()
        } else if cv < 0.06 {
            "Stable".into()
        } else {
            "Moderate".into()
        }
    }
}

/// Evaluate mean loss over a split using the compiled eval entry point.
pub fn eval_split(rt: &ModelRuntime, ds: &Dataset, idx: &[usize]) -> f64 {
    if idx.is_empty() {
        return f64::NAN;
    }
    let b = rt.mm.eval.batch;
    let mut total = 0.0;
    let mut batches = 0usize;
    let mut i = 0;
    while i < idx.len() {
        let end = (i + b).min(idx.len());
        let chunk = &idx[i..end];
        let (x, y) = if rt.mm.kind == "tcn" {
            ds.gather_seq(chunk, b)
        } else {
            ds.gather_cur(chunk, b)
        };
        total += rt.eval_loss(x, y).expect("eval failed") as f64;
        batches += 1;
        i = end;
    }
    total / batches as f64
}

/// Full training run; mutates the runtime's parameters in place.
pub fn train(rt: &mut ModelRuntime, ds: &Dataset, split: &Split, cfg: &TrainConfig) -> TrainResult {
    let b = rt.mm.train.batch;
    let mut order: Vec<usize> = split.train.clone();
    let mut rng = Xoshiro256::new(cfg.seed ^ 0x7241_494E);
    let mut train_curve = Vec::with_capacity(cfg.epochs);
    let mut val_curve = Vec::with_capacity(cfg.epochs);
    let mut best_val = f64::INFINITY;
    let mut since_best = 0usize;
    let mut stopped_early = false;

    for epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        let mut nb = 0usize;
        let max_b = if cfg.max_batches_per_epoch == 0 {
            usize::MAX
        } else {
            cfg.max_batches_per_epoch
        };
        let mut i = 0;
        while i < order.len() && nb < max_b {
            let end = (i + b).min(order.len());
            let chunk = &order[i..end];
            let (x, y) = if rt.mm.kind == "tcn" {
                ds.gather_seq(chunk, b)
            } else {
                ds.gather_cur(chunk, b)
            };
            epoch_loss += rt.train_step(x, y).expect("train step failed") as f64;
            nb += 1;
            i = end;
        }
        let tl = epoch_loss / nb.max(1) as f64;
        let vl = eval_split(rt, ds, &split.val);
        train_curve.push(tl);
        val_curve.push(vl);
        if cfg.verbose_every > 0 && (epoch + 1) % cfg.verbose_every == 0 {
            crate::log_info!(
                "train[{}] epoch {:>3}/{}: train={:.4} val={:.4}",
                rt.mm.name,
                epoch + 1,
                cfg.epochs,
                tl,
                vl
            );
        }
        if vl < best_val - 1e-5 {
            best_val = vl;
            since_best = 0;
        } else {
            since_best += 1;
            if cfg.patience > 0 && since_best >= cfg.patience {
                stopped_early = true;
                break;
            }
        }
    }

    TrainResult {
        model: rt.mm.name.clone(),
        final_train_loss: *train_curve.last().unwrap_or(&f64::NAN),
        final_val_loss: *val_curve.last().unwrap_or(&f64::NAN),
        best_val_loss: best_val,
        epochs_run: train_curve.len(),
        stopped_early,
        train_curve,
        val_curve,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::{Dataset, GeometryHints, ModelRuntime};
    use crate::runtime::{Engine, Manifest};
    use crate::trace::{GeneratorConfig, TraceGenerator};

    #[test]
    fn short_training_reduces_loss_on_real_trace() {
        let Some(dir) = crate::runtime::artifacts_dir() else {
            eprintln!("SKIP: artifacts not built");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::cpu().unwrap();
        let mut rt = ModelRuntime::load(&engine, &manifest, "tcn").unwrap();

        let gcfg = GeneratorConfig::tiny(42);
        let geom = GeometryHints::from_generator(&gcfg);
        let trace = TraceGenerator::new(gcfg).generate(60_000);
        let ds = Dataset::build(&trace, rt.mm.window, geom, 2048, 4);
        let split = ds.split(3);

        let cfg = TrainConfig {
            epochs: 5,
            patience: 0,
            max_batches_per_epoch: 6,
            seed: 1,
            verbose_every: 0,
        };
        let res = train(&mut rt, &ds, &split, &cfg);
        assert_eq!(res.epochs_run, 5);
        assert!(res.train_curve[4] < res.train_curve[0], "curve: {:?}", res.train_curve);
        assert!(res.final_val_loss.is_finite());
        assert!(!res.stability().is_empty());
    }
}
