//! ACPC's Priority-Aware Replacement Module (PARM) — the paper's §3.3.
//!
//! Every resident line carries a dynamic priority (eq. 3):
//!
//! ```text
//!     P_i = α·U_i + (1−α)·f_i
//! ```
//!
//! where `U_i` is the TCN-predicted utility (delivered at fill time via
//! `AccessMeta::predicted_utility` and refreshed asynchronously via
//! `update_utility` as prediction batches complete), and `f_i` is a
//! normalized access frequency (per-line saturating hit counter, normalized
//! by `FREQ_SAT`, with periodic decay so stale popularity fades).
//!
//! Pollution suppression (§3.1/§3.3): on a miss, PARM evicts the
//! lowest-priority line; new lines insert with priority proportional to
//! predicted reuse, and *prefetch* fills are additionally demoted by the
//! set's pollution pressure (fraction of resident lines that are
//! never-referenced prefetches — the "cache occupancy" signal of eq. 3's
//! surrounding text). A low-confidence prefetch therefore lands just above
//! eviction and dies quickly unless promptly referenced, which is exactly
//! the paper's mechanism for suppressing redundant prefetches.

use super::{AccessMeta, Policy};

/// Tunables for PARM (paper defaults: α = 0.7).
#[derive(Debug, Clone, Copy)]
pub struct ParmConfig {
    /// Balance coefficient α in eq. 3.
    pub alpha: f32,
    /// Hits at which the frequency term saturates to 1.0.
    pub freq_sat: u32,
    /// Decay period (fills per set) after which frequencies are halved.
    pub decay_period: u32,
    /// Strength of the occupancy-pressure demotion for prefetch inserts.
    pub occupancy_penalty: f32,
    /// Neutral utility before the predictor has scored a line.
    pub neutral_utility: f32,
}

impl Default for ParmConfig {
    fn default() -> Self {
        Self {
            alpha: 0.95,
            freq_sat: 8,
            decay_period: 32,
            occupancy_penalty: 0.3,
            neutral_utility: 0.5,
        }
    }
}

/// Re-reference countdown resolution (3 bits, like an extended RRIP).
const MAX_RRPV: u8 = 7;

pub struct AcpcParm {
    assoc: usize,
    cfg: ParmConfig,
    utility: Vec<f32>,
    hits: Vec<u32>,
    /// RRIP-style re-reference prediction value per line. PARM "refines
    /// LRU/RRIP" (§3.3): the backbone is RRPV aging (scan resistance +
    /// recency), and the priority score P_i decides both the *insertion*
    /// RRPV (quantized 1−P) and the tie-break among max-RRPV victims.
    rrpv: Vec<u8>,
    /// Unreferenced-prefetch flag per line (pollution pressure input).
    dead_prefetch: Vec<bool>,
    /// Fills since last decay, per set.
    fills: Vec<u32>,
    /// Externally-provided pollution pressure (EWMA from the cache wrapper);
    /// per set.
    pressure: Vec<f32>,
    clock: u64,
    stamp: Vec<u64>,
}

impl AcpcParm {
    pub fn new(sets: usize, assoc: usize, cfg: ParmConfig) -> Self {
        Self {
            assoc,
            cfg,
            utility: vec![cfg.neutral_utility; sets * assoc],
            hits: vec![0; sets * assoc],
            rrpv: vec![MAX_RRPV; sets * assoc],
            dead_prefetch: vec![false; sets * assoc],
            fills: vec![0; sets],
            pressure: vec![0.0; sets],
            clock: 0,
            stamp: vec![0; sets * assoc],
        }
    }

    #[inline]
    fn quantize(&self, set: usize, way: usize) -> u8 {
        let p = self.priority(set, way).clamp(0.0, 1.0);
        // High priority → near re-reference (low RRPV); insertions never get
        // RRPV 7 outright (that is reserved for aged-out lines) unless the
        // priority is rock-bottom.
        ((1.0 - p) * (MAX_RRPV as f32 - 1.0)).round() as u8
    }

    /// Priority of a way (eq. 3). Public for tests and for the implicit-
    /// predictor loss evaluation.
    pub fn priority(&self, set: usize, way: usize) -> f32 {
        let idx = set * self.assoc + way;
        let f = (self.hits[idx] as f32 / self.cfg.freq_sat as f32).min(1.0);
        self.cfg.alpha * self.utility[idx] + (1.0 - self.cfg.alpha) * f
    }

    fn decay_set(&mut self, set: usize) {
        let base = set * self.assoc;
        for w in 0..self.assoc {
            self.hits[base + w] /= 2;
        }
    }

    /// Measured fraction of this set's ways that hold never-referenced
    /// prefetches.
    fn dead_prefetch_frac(&self, set: usize) -> f32 {
        let base = set * self.assoc;
        let n = (0..self.assoc).filter(|&w| self.dead_prefetch[base + w]).count();
        n as f32 / self.assoc as f32
    }
}

impl Policy for AcpcParm {
    fn name(&self) -> &'static str {
        "acpc"
    }

    fn on_hit(&mut self, set: usize, way: usize, meta: &AccessMeta) {
        let idx = set * self.assoc + way;
        self.hits[idx] = self.hits[idx].saturating_add(1);
        self.dead_prefetch[idx] = false;
        self.clock += 1;
        self.stamp[idx] = self.clock;
        if let Some(u) = meta.predicted_utility {
            self.utility[idx] = u;
        }
        // Near-immediate re-reference expected after a hit.
        self.rrpv[idx] = 0;
    }

    fn on_fill(&mut self, set: usize, way: usize, meta: &AccessMeta) {
        let idx = set * self.assoc + way;
        // Periodic frequency decay keeps f_i a *recent* popularity signal.
        self.fills[set] += 1;
        if self.fills[set] >= self.cfg.decay_period {
            self.fills[set] = 0;
            self.decay_set(set);
        }

        let u = meta.predicted_utility.unwrap_or(self.cfg.neutral_utility);
        // Pollution pressure: blend the measured dead-prefetch occupancy of
        // this set with the cache-level EWMA hint.
        let pressure = 0.5 * self.dead_prefetch_frac(set) + 0.5 * self.pressure[set];
        let u = if meta.is_prefetch {
            (u * (1.0 - self.cfg.occupancy_penalty * pressure)).max(0.0)
        } else {
            u
        };
        self.utility[idx] = u;
        // Insertion grace for demand fills: without it, f_i = 0 makes every
        // new line the instant victim (the classic LFU pathology on
        // streaming workloads). Prefetch fills get no grace — they must
        // earn residency via a demand hit (pollution suppression).
        self.hits[idx] = if meta.is_prefetch { 0 } else { self.cfg.freq_sat / 2 };
        self.dead_prefetch[idx] = meta.is_prefetch;
        self.clock += 1;
        self.stamp[idx] = self.clock;
        // Insertion RRPV from the blended priority (eq. 3): confident-reuse
        // lines insert near, predicted-dead prefetches insert at the brink.
        self.rrpv[idx] = self.quantize(set, way);
    }

    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.assoc;
        loop {
            // All lines at max RRPV are candidates; the blended priority
            // breaks the tie (lowest P evicted), then older stamp.
            let mut best: Option<usize> = None;
            let mut best_key = (f32::INFINITY, u64::MAX);
            for w in 0..self.assoc {
                if self.rrpv[base + w] >= MAX_RRPV {
                    let key = (self.priority(set, w), self.stamp[base + w]);
                    if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
                        best_key = key;
                        best = Some(w);
                    }
                }
            }
            if let Some(w) = best {
                return w;
            }
            for w in 0..self.assoc {
                self.rrpv[base + w] += 1;
            }
        }
    }

    fn update_utility(&mut self, set: usize, way: usize, utility: f32) {
        self.utility[set * self.assoc + way] = utility.clamp(0.0, 1.0);
        // Re-quantize: a prediction downgrade (e.g. KV entry slid out of the
        // attention window) pushes the line toward eviction immediately; an
        // upgrade rescues it.
        self.rrpv[set * self.assoc + way] = self.quantize(set, way);
    }

    fn reset_utilities(&mut self) {
        // Adaptive back-off: stale predictions stop steering victim
        // selection immediately (priority falls back to the neutral prior +
        // live frequency); RRPV ages out naturally rather than being
        // rewritten, preserving recency state.
        for u in &mut self.utility {
            *u = self.cfg.neutral_utility;
        }
    }

    fn occupancy_hint(&mut self, set: usize, frac_dead_prefetch: f64) {
        // EWMA so a single noisy sample does not whipsaw insert priorities.
        let p = &mut self.pressure[set];
        *p = 0.75 * *p + 0.25 * frac_dead_prefetch as f32;
    }

    fn on_invalidate(&mut self, set: usize, way: usize) {
        let idx = set * self.assoc + way;
        self.utility[idx] = self.cfg.neutral_utility;
        self.hits[idx] = 0;
        self.rrpv[idx] = MAX_RRPV;
        self.dead_prefetch[idx] = false;
        self.stamp[idx] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::StreamKind;

    fn meta_p(p: Option<f32>) -> AccessMeta {
        let mut m = AccessMeta::demand(0, 0, StreamKind::KvRead);
        m.predicted_utility = p;
        m
    }

    fn pf_p(p: Option<f32>) -> AccessMeta {
        let mut m = AccessMeta::prefetch(0, 0, StreamKind::Weight);
        m.predicted_utility = p;
        m
    }

    #[test]
    fn priority_blends_utility_and_frequency() {
        let cfg = ParmConfig { alpha: 0.5, ..Default::default() };
        let mut p = AcpcParm::new(1, 2, cfg);
        p.on_fill(0, 0, &meta_p(Some(1.0))); // U=1, grace f=0.5 → P=0.75
        p.on_fill(0, 1, &meta_p(Some(0.0))); // U=0, grace f=0.5 → P=0.25
        assert!((p.priority(0, 0) - 0.75).abs() < 1e-6);
        assert!((p.priority(0, 1) - 0.25).abs() < 1e-6);
        for _ in 0..8 {
            p.on_hit(0, 1, &meta_p(None)); // f saturates → P=0.5
        }
        assert!((p.priority(0, 1) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn evicts_lowest_priority() {
        let mut p = AcpcParm::new(1, 4, ParmConfig::default());
        p.on_fill(0, 0, &meta_p(Some(0.9)));
        p.on_fill(0, 1, &meta_p(Some(0.2)));
        p.on_fill(0, 2, &meta_p(Some(0.7)));
        p.on_fill(0, 3, &meta_p(Some(0.5)));
        assert_eq!(p.victim(0), 1);
        p.update_utility(0, 1, 0.95);
        assert_ne!(p.victim(0), 1);
    }

    #[test]
    fn prefetch_demoted_under_pressure() {
        let mut p = AcpcParm::new(1, 4, ParmConfig::default());
        // Build pollution pressure: dead prefetches resident + hint.
        p.on_fill(0, 0, &pf_p(Some(0.4)));
        p.on_fill(0, 1, &pf_p(Some(0.4)));
        for _ in 0..8 {
            p.occupancy_hint(0, 0.8);
        }
        // Same predicted utility: prefetch insert lands lower than demand.
        p.on_fill(0, 2, &pf_p(Some(0.6)));
        p.on_fill(0, 3, &meta_p(Some(0.6)));
        assert!(
            p.priority(0, 2) < p.priority(0, 3),
            "prefetch {} vs demand {}",
            p.priority(0, 2),
            p.priority(0, 3)
        );
    }

    #[test]
    fn hit_clears_dead_prefetch_flag() {
        let mut p = AcpcParm::new(1, 2, ParmConfig::default());
        p.on_fill(0, 0, &pf_p(Some(0.5)));
        assert!(p.dead_prefetch[0]);
        p.on_hit(0, 0, &meta_p(None));
        assert!(!p.dead_prefetch[0]);
    }

    #[test]
    fn frequency_decays() {
        let cfg = ParmConfig { decay_period: 4, ..Default::default() };
        let mut p = AcpcParm::new(1, 2, cfg);
        p.on_fill(0, 0, &meta_p(Some(0.5)));
        for _ in 0..8 {
            p.on_hit(0, 0, &meta_p(None));
        }
        let before = p.priority(0, 0);
        // 4 fills into way 1 trigger a decay.
        for _ in 0..4 {
            p.on_fill(0, 1, &meta_p(Some(0.5)));
        }
        assert!(p.priority(0, 0) < before);
    }

    #[test]
    fn alpha_extremes() {
        // α=1: pure prediction — with equal recency, the low-utility line
        // inserts deeper and ages out first.
        let mut pred = AcpcParm::new(1, 2, ParmConfig { alpha: 1.0, ..Default::default() });
        pred.on_fill(0, 0, &meta_p(Some(0.9)));
        pred.on_fill(0, 1, &meta_p(Some(0.1)));
        assert_eq!(pred.victim(0), 1, "alpha=1 follows prediction");

        // α=0: pure frequency — predictions flipped, victim driven by f_i.
        let mut freq = AcpcParm::new(1, 2, ParmConfig { alpha: 0.0, ..Default::default() });
        freq.on_fill(0, 0, &meta_p(Some(0.9)));
        freq.on_fill(0, 1, &meta_p(Some(0.1)));
        for _ in 0..8 {
            freq.on_hit(0, 1, &meta_p(None)); // way1 becomes frequent
        }
        assert_eq!(freq.victim(0), 0, "alpha=0 ignores prediction");
    }
}
