//! RRIP family (Jaleel et al., ISCA'10 — related work [4]): SRRIP, BRRIP and
//! the set-dueling hybrid DRRIP. Table 1's "RRIP (Static)" row is SRRIP.
//!
//! Each line carries an M-bit re-reference prediction value (RRPV);
//! 0 = near-immediate re-reference, 2^M-1 = distant. Victims are lines with
//! maximal RRPV (aging the whole set until one appears). SRRIP inserts at
//! "long" (max-1); BRRIP inserts at "distant" (max) except with probability
//! 1/32 at long — which resists thrashing; DRRIP picks per-set via dueling.

use super::{AccessMeta, Policy};
use crate::util::rng::Xoshiro256;

const M: u8 = 2;
const MAX_RRPV: u8 = (1 << M) - 1; // 3
const LONG_RRPV: u8 = MAX_RRPV - 1; // 2
const BIP_EPSILON: f64 = 1.0 / 32.0;
const PSEL_BITS: u32 = 10;
const LEADER_PERIOD: usize = 32; // 1 leader set per policy per 32 sets

#[derive(Debug, Clone, Copy, PartialEq)]
enum Mode {
    Srrip,
    Brrip,
    Drrip,
}

pub struct Rrip {
    assoc: usize,
    mode: Mode,
    rrpv: Vec<u8>,
    rng: Xoshiro256,
    /// DRRIP policy-selector counter (saturating).
    psel: i32,
}

impl Rrip {
    pub fn srrip(sets: usize, assoc: usize) -> Self {
        Self::new(sets, assoc, Mode::Srrip, 0)
    }

    pub fn brrip(sets: usize, assoc: usize, seed: u64) -> Self {
        Self::new(sets, assoc, Mode::Brrip, seed)
    }

    pub fn drrip(sets: usize, assoc: usize, seed: u64) -> Self {
        Self::new(sets, assoc, Mode::Drrip, seed)
    }

    fn new(sets: usize, assoc: usize, mode: Mode, seed: u64) -> Self {
        Self {
            assoc,
            mode,
            rrpv: vec![MAX_RRPV; sets * assoc],
            rng: Xoshiro256::new(seed ^ 0x5251_4950),
            psel: 0,
        }
    }

    /// Leader-set classification for DRRIP set dueling.
    fn leader(&self, set: usize) -> Option<Mode> {
        match set % LEADER_PERIOD {
            0 => Some(Mode::Srrip),
            1 => Some(Mode::Brrip),
            _ => None,
        }
    }

    /// Which insertion policy applies in `set` right now.
    fn insertion_mode(&self, set: usize) -> Mode {
        match self.mode {
            Mode::Srrip => Mode::Srrip,
            Mode::Brrip => Mode::Brrip,
            Mode::Drrip => self.leader(set).unwrap_or(if self.psel >= 0 {
                Mode::Srrip
            } else {
                Mode::Brrip
            }),
        }
    }

    /// DRRIP learning: a *miss* in a leader set votes against its policy.
    fn duel_on_miss(&mut self, set: usize) {
        if self.mode != Mode::Drrip {
            return;
        }
        let cap = 1 << (PSEL_BITS - 1);
        match self.leader(set) {
            Some(Mode::Srrip) => self.psel = (self.psel - 1).max(-cap),
            Some(Mode::Brrip) => self.psel = (self.psel + 1).min(cap - 1),
            _ => {}
        }
    }

    /// RRPV of a way — exposed for the implicit-predictor loss evaluation
    /// (lower RRPV ⇒ higher implied reuse probability).
    pub fn rrpv_of(&self, set: usize, way: usize) -> u8 {
        self.rrpv[set * self.assoc + way]
    }
}

impl Policy for Rrip {
    fn name(&self) -> &'static str {
        match self.mode {
            Mode::Srrip => "srrip",
            Mode::Brrip => "brrip",
            Mode::Drrip => "drrip",
        }
    }

    fn on_hit(&mut self, set: usize, way: usize, _meta: &AccessMeta) {
        // Hit promotion: RRPV → 0 (near re-reference).
        self.rrpv[set * self.assoc + way] = 0;
    }

    fn on_fill(&mut self, set: usize, way: usize, _meta: &AccessMeta) {
        self.duel_on_miss(set);
        let mode = self.insertion_mode(set);
        let insert = match mode {
            Mode::Srrip => LONG_RRPV,
            Mode::Brrip | Mode::Drrip => {
                if self.rng.chance(BIP_EPSILON) {
                    LONG_RRPV
                } else {
                    MAX_RRPV
                }
            }
        };
        // Standard RRIP treats prefetch fills like demand fills: its scan
        // resistance (long insertion + aging) is what bounds pollution —
        // the paper's "RRIP (Static)" row has no prefetch-specific logic.
        self.rrpv[set * self.assoc + way] = insert;
    }

    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.assoc;
        loop {
            for w in 0..self.assoc {
                if self.rrpv[base + w] >= MAX_RRPV {
                    return w;
                }
            }
            for w in 0..self.assoc {
                self.rrpv[base + w] += 1;
            }
        }
    }

    fn on_invalidate(&mut self, set: usize, way: usize) {
        self.rrpv[set * self.assoc + way] = MAX_RRPV;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::StreamKind;

    fn meta() -> AccessMeta {
        AccessMeta::demand(0, 0, StreamKind::Weight)
    }

    #[test]
    fn srrip_scan_resistance() {
        // A hit-promoted line survives a scan of distant-inserted lines
        // longer than under LRU: fill 4 ways, hit way 0, then check the
        // victim is never way 0 while others are at higher RRPV.
        let mut p = Rrip::srrip(1, 4);
        for w in 0..4 {
            p.on_fill(0, w, &meta());
        }
        p.on_hit(0, 0, &meta());
        for _ in 0..3 {
            let v = p.victim(0);
            assert_ne!(v, 0, "promoted line evicted too early");
            p.on_fill(0, v, &meta());
        }
    }

    #[test]
    fn victim_always_terminates_and_ages() {
        let mut p = Rrip::srrip(1, 4);
        for w in 0..4 {
            p.on_fill(0, w, &meta());
            p.on_hit(0, w, &meta()); // all RRPV=0
        }
        let v = p.victim(0); // must age everyone up to MAX then pick
        assert!(v < 4);
    }

    #[test]
    fn brrip_mostly_distant_inserts() {
        let mut p = Rrip::brrip(1, 8, 11);
        let mut distant = 0;
        for i in 0..800 {
            p.on_fill(0, (i % 8) as usize, &meta());
            if p.rrpv_of(0, (i % 8) as usize) == MAX_RRPV {
                distant += 1;
            }
        }
        assert!(distant > 700, "BRRIP should insert distant ~31/32: {distant}/800");
        assert!(distant < 800, "but occasionally long");
    }

    #[test]
    fn drrip_psel_moves_on_leader_misses() {
        let mut p = Rrip::drrip(64, 4, 5);
        let before = p.psel;
        // Misses (fills) in SRRIP leader sets (set % 32 == 0) push psel down.
        for _ in 0..20 {
            p.on_fill(0, 0, &meta());
            p.on_fill(32, 0, &meta());
        }
        assert!(p.psel < before, "psel should move: {} -> {}", before, p.psel);
    }
}
