//! Random replacement (related work [3]) — the zero-state baseline.

use super::{AccessMeta, Policy};
use crate::util::rng::Xoshiro256;

pub struct RandomPolicy {
    assoc: usize,
    rng: Xoshiro256,
}

impl RandomPolicy {
    pub fn new(_sets: usize, assoc: usize, seed: u64) -> Self {
        Self { assoc, rng: Xoshiro256::new(seed) }
    }
}

impl Policy for RandomPolicy {
    fn name(&self) -> &'static str {
        "random"
    }

    fn on_hit(&mut self, _set: usize, _way: usize, _meta: &AccessMeta) {}

    fn on_fill(&mut self, _set: usize, _way: usize, _meta: &AccessMeta) {}

    fn victim(&mut self, _set: usize) -> usize {
        self.rng.range_usize(0, self.assoc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_victims() {
        let mut p = RandomPolicy::new(4, 8, 7);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[p.victim(0)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 1000.0).abs() < 250.0, "counts {counts:?}");
        }
    }
}
