//! Adaptive insertion policies (Qureshi et al., ISCA'07 — related work [5]):
//! LIP (insert at LRU position), BIP (LIP with 1/32 MRU inserts), and DIP
//! (set-dueling between traditional LRU-insert and BIP).
//!
//! Implemented over the same age-stamp machinery as `lru.rs`: inserting "at
//! LRU" = giving the line the *oldest* stamp in the set.

use super::{AccessMeta, Policy};
use crate::util::rng::Xoshiro256;

const BIP_EPSILON: f64 = 1.0 / 32.0;
const PSEL_BITS: u32 = 10;
const LEADER_PERIOD: usize = 32;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Mode {
    Lip,
    Bip,
    Dip,
}

pub struct Dip {
    assoc: usize,
    mode: Mode,
    stamp: Vec<u64>,
    clock: u64,
    rng: Xoshiro256,
    psel: i32,
}

impl Dip {
    pub fn lip(sets: usize, assoc: usize, seed: u64) -> Self {
        Self::new(sets, assoc, Mode::Lip, seed)
    }

    pub fn bip(sets: usize, assoc: usize, seed: u64) -> Self {
        Self::new(sets, assoc, Mode::Bip, seed)
    }

    pub fn dip(sets: usize, assoc: usize, seed: u64) -> Self {
        Self::new(sets, assoc, Mode::Dip, seed)
    }

    fn new(sets: usize, assoc: usize, mode: Mode, seed: u64) -> Self {
        Self {
            assoc,
            mode,
            stamp: vec![0; sets * assoc],
            clock: 1,
            rng: Xoshiro256::new(seed ^ 0x4449_5000),
            psel: 0,
        }
    }

    fn leader(&self, set: usize) -> Option<Mode> {
        match set % LEADER_PERIOD {
            0 => Some(Mode::Lip), // stands in for "LRU-insert" leader
            1 => Some(Mode::Bip),
            _ => None,
        }
    }

    fn oldest_stamp(&self, set: usize) -> u64 {
        let base = set * self.assoc;
        (0..self.assoc).map(|w| self.stamp[base + w]).min().unwrap_or(0)
    }
}

impl Policy for Dip {
    fn name(&self) -> &'static str {
        match self.mode {
            Mode::Lip => "lip",
            Mode::Bip => "bip",
            Mode::Dip => "dip",
        }
    }

    fn on_hit(&mut self, set: usize, way: usize, _meta: &AccessMeta) {
        self.clock += 1;
        self.stamp[set * self.assoc + way] = self.clock;
    }

    fn on_fill(&mut self, set: usize, way: usize, _meta: &AccessMeta) {
        // Dueling: miss in a leader set votes against its policy.
        if self.mode == Mode::Dip {
            let cap = 1 << (PSEL_BITS - 1);
            match self.leader(set) {
                Some(Mode::Lip) => self.psel = (self.psel - 1).max(-cap),
                Some(Mode::Bip) => self.psel = (self.psel + 1).min(cap - 1),
                _ => {}
            }
        }
        let mode = match self.mode {
            Mode::Dip => self.leader(set).unwrap_or(if self.psel >= 0 { Mode::Lip } else { Mode::Bip }),
            m => m,
        };
        let mru = match mode {
            Mode::Lip => false,
            Mode::Bip | Mode::Dip => self.rng.chance(BIP_EPSILON),
        };
        let idx = set * self.assoc + way;
        if mru {
            self.clock += 1;
            self.stamp[idx] = self.clock;
        } else {
            // Insert at LRU: strictly older than everything resident.
            self.stamp[idx] = self.oldest_stamp(set).saturating_sub(1);
        }
    }

    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.assoc;
        let mut best = 0;
        let mut best_stamp = u64::MAX;
        for w in 0..self.assoc {
            if self.stamp[base + w] < best_stamp {
                best_stamp = self.stamp[base + w];
                best = w;
            }
        }
        best
    }

    fn on_invalidate(&mut self, set: usize, way: usize) {
        self.stamp[set * self.assoc + way] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::StreamKind;

    fn meta() -> AccessMeta {
        AccessMeta::demand(0, 0, StreamKind::Weight)
    }

    #[test]
    fn lip_inserted_line_is_next_victim_without_reuse() {
        let mut p = Dip::lip(1, 4, 1);
        for w in 0..4 {
            p.on_fill(0, w, &meta());
            p.on_hit(0, w, &meta()); // establish recency
        }
        // New fill at LRU position: immediately the next victim.
        let v = p.victim(0);
        p.on_fill(0, v, &meta());
        assert_eq!(p.victim(0), v, "LIP insert must stay at LRU");
    }

    #[test]
    fn lip_reused_line_is_promoted() {
        let mut p = Dip::lip(1, 4, 1);
        for w in 0..4 {
            p.on_fill(0, w, &meta());
            p.on_hit(0, w, &meta());
        }
        let v = p.victim(0);
        p.on_fill(0, v, &meta());
        p.on_hit(0, v, &meta()); // reuse rescues it
        assert_ne!(p.victim(0), v);
    }

    #[test]
    fn bip_occasionally_promotes_inserts() {
        let mut p = Dip::bip(1, 4, 3);
        let mut promoted = 0;
        for i in 0..640 {
            let w = i % 4;
            p.on_fill(0, w, &meta());
            if p.victim(0) != w {
                promoted += 1;
            }
            // reset stamps to a clean state
            for w2 in 0..4 {
                p.on_hit(0, w2, &meta());
            }
        }
        assert!(promoted > 2 && promoted < 120, "BIP MRU-insert rate off: {promoted}/640");
    }

    #[test]
    fn dip_psel_moves() {
        let mut p = Dip::dip(64, 4, 9);
        let before = p.psel;
        for _ in 0..10 {
            p.on_fill(0, 0, &meta()); // LIP leader misses
        }
        assert!(p.psel < before);
    }
}
