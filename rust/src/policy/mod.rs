//! Cache replacement policies.
//!
//! The paper's contribution (ACPC/PARM, `acpc.rs`) plus every baseline it is
//! compared against (Table 1: LRU, static RRIP, ML-Predict) and the wider
//! family of classic policies its related-work section cites (PLRU, Random,
//! LIP/BIP/DIP, DRRIP, SHiP) — and a Belady oracle for upper-bound studies.
//!
//! A policy owns per-set/per-way metadata and answers three questions:
//! what to do on a hit, what to do on a fill, and which way to evict.
//! Learning-driven policies additionally receive asynchronous utility
//! updates from the predictor runtime (`update_utility`).

pub mod acpc;
pub mod belady;
pub mod dip;
pub mod lru;
pub mod mlpredict;
pub mod plru;
pub mod random;
pub mod rrip;
pub mod ship;

use crate::trace::StreamKind;

/// Per-access information a policy may condition on. This is the runtime
/// form of the paper's feature tuple: address (line), PC, stream kind,
/// whether the fill is a prefetch, the predictor's utility estimate, and —
/// only in oracle runs — the next-use time.
#[derive(Debug, Clone, Copy)]
pub struct AccessMeta {
    pub line: u64,
    pub pc: u64,
    pub kind: StreamKind,
    pub is_prefetch: bool,
    /// TCN/DNN-predicted reuse utility in [0,1]; `None` until the predictor
    /// has produced a score for this access (policies use a neutral prior).
    pub predicted_utility: Option<f32>,
    /// Absolute time of the next access to this line (Belady oracle only).
    pub next_use: Option<u64>,
}

impl AccessMeta {
    pub fn demand(line: u64, pc: u64, kind: StreamKind) -> Self {
        Self { line, pc, kind, is_prefetch: false, predicted_utility: None, next_use: None }
    }

    pub fn prefetch(line: u64, pc: u64, kind: StreamKind) -> Self {
        Self { line, pc, kind, is_prefetch: true, predicted_utility: None, next_use: None }
    }
}

/// Replacement policy interface. `set` is the set index; `way` a slot in
/// `[0, assoc)`. `victim` is only called when every way in the set is valid.
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    fn on_hit(&mut self, set: usize, way: usize, meta: &AccessMeta);

    fn on_fill(&mut self, set: usize, way: usize, meta: &AccessMeta);

    fn victim(&mut self, set: usize) -> usize;

    /// Asynchronous utility refresh from the predictor (ACPC/ML-Predict).
    fn update_utility(&mut self, _set: usize, _way: usize, _utility: f32) {}

    /// Forget every stored predicted utility (adaptive throttle / predictor
    /// hot swap): utility-consuming policies fall back to their neutral
    /// prior for all resident lines, so stale predictions stop steering
    /// victim selection. No-op for classic policies.
    fn reset_utilities(&mut self) {}

    /// Occupancy feedback: fraction of currently-resident lines that are
    /// unreferenced prefetches (PARM's pollution-pressure signal).
    fn occupancy_hint(&mut self, _set: usize, _frac_dead_prefetch: f64) {}

    /// Invalidation notice (slot recycled) so stale state does not leak
    /// into the next resident of the way.
    fn on_invalidate(&mut self, _set: usize, _way: usize) {}
}

/// Names of all selectable policies (CLI + bench sweeps).
pub const POLICY_NAMES: &[&str] = &[
    "lru", "plru", "random", "lip", "bip", "dip", "srrip", "brrip", "drrip", "ship", "belady",
    "mlpredict", "acpc",
];

/// Policy factory. `seed` feeds stochastic policies (random, BIP inserts).
///
/// The ACPC policy accepts an inline α override for ablation sweeps:
/// `"acpc@0.5"` builds PARM with `alpha = 0.5` (eq. 3).
pub fn make_policy(name: &str, sets: usize, assoc: usize, seed: u64) -> Option<Box<dyn Policy>> {
    if let Some(alpha_s) = name.strip_prefix("acpc@") {
        let alpha: f32 = alpha_s.parse().ok()?;
        if !(0.0..=1.0).contains(&alpha) {
            return None;
        }
        let cfg = acpc::ParmConfig { alpha, ..Default::default() };
        return Some(Box::new(acpc::AcpcParm::new(sets, assoc, cfg)));
    }
    let p: Box<dyn Policy> = match name {
        "lru" => Box::new(lru::Lru::new(sets, assoc)),
        "plru" => Box::new(plru::TreePlru::new(sets, assoc)),
        "random" => Box::new(random::RandomPolicy::new(sets, assoc, seed)),
        "lip" => Box::new(dip::Dip::lip(sets, assoc, seed)),
        "bip" => Box::new(dip::Dip::bip(sets, assoc, seed)),
        "dip" => Box::new(dip::Dip::dip(sets, assoc, seed)),
        "srrip" => Box::new(rrip::Rrip::srrip(sets, assoc)),
        "brrip" => Box::new(rrip::Rrip::brrip(sets, assoc, seed)),
        "drrip" => Box::new(rrip::Rrip::drrip(sets, assoc, seed)),
        "ship" => Box::new(ship::Ship::new(sets, assoc)),
        "belady" => Box::new(belady::Belady::new(sets, assoc)),
        "mlpredict" => Box::new(mlpredict::MlPredict::new(sets, assoc)),
        "acpc" => Box::new(acpc::AcpcParm::new(sets, assoc, acpc::ParmConfig::default())),
        _ => return None,
    };
    Some(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_covers_all_names() {
        for name in POLICY_NAMES {
            let p = make_policy(name, 16, 8, 1).unwrap_or_else(|| panic!("{name}"));
            assert!(!p.name().is_empty());
        }
        assert!(make_policy("bogus", 16, 8, 1).is_none());
    }

    /// Generic contract: victim() always returns a way in range, for every
    /// policy, from any reachable state.
    #[test]
    fn victims_in_range_after_random_workload() {
        use crate::util::rng::Xoshiro256;
        let (sets, assoc) = (8, 4);
        for name in POLICY_NAMES {
            let mut p = make_policy(name, sets, assoc, 3).unwrap();
            let mut rng = Xoshiro256::new(42);
            for i in 0..2000 {
                let set = rng.range_usize(0, sets);
                let mut meta = AccessMeta::demand(i, i % 7, StreamKind::Weight);
                meta.next_use = Some(i + rng.gen_range(100)); // keep belady fed
                match i % 3 {
                    0 => {
                        let w = p.victim(set);
                        assert!(w < assoc, "{name} victim {w}");
                        p.on_fill(set, w, &meta);
                    }
                    1 => p.on_hit(set, rng.range_usize(0, assoc), &meta),
                    _ => p.update_utility(set, rng.range_usize(0, assoc), rng.next_f32()),
                }
            }
        }
    }
}
