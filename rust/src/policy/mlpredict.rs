//! ML-Predict — the paper's "DNN" baseline row in Table 1: a learned reuse
//! probability drives replacement *directly* (victim = lowest predicted
//! reuse), with recency as tie-breaker. Unlike ACPC's PARM it has no
//! frequency blending, no occupancy feedback, and no prefetch-aware
//! insertion: exactly the "prediction is the policy" design the paper
//! contrasts against.
//!
//! The probability comes from the flattened-window MLP (see
//! `python/compile/model.py::dnn_*`) via `update_utility` /
//! `AccessMeta::predicted_utility`.

use super::{AccessMeta, Policy};

const NEUTRAL: f32 = 0.5;
const MAX_RRPV: u8 = 7;

pub struct MlPredict {
    assoc: usize,
    prob: Vec<f32>,
    /// RRPV aging backbone (same countdown machinery as RRIP — without it a
    /// prediction-only victim choice has the LFU new-line pathology); the
    /// *predicted probability alone* decides insertion depth and victim
    /// tie-breaks, which is what distinguishes this baseline from ACPC's
    /// blended, occupancy-aware PARM.
    rrpv: Vec<u8>,
    stamp: Vec<u64>,
    clock: u64,
}

impl MlPredict {
    pub fn new(sets: usize, assoc: usize) -> Self {
        Self {
            assoc,
            prob: vec![NEUTRAL; sets * assoc],
            rrpv: vec![MAX_RRPV; sets * assoc],
            stamp: vec![0; sets * assoc],
            clock: 0,
        }
    }

    #[inline]
    fn quantize(p: f32) -> u8 {
        ((1.0 - p.clamp(0.0, 1.0)) * (MAX_RRPV as f32 - 1.0)).round() as u8
    }
}

impl Policy for MlPredict {
    fn name(&self) -> &'static str {
        "mlpredict"
    }

    fn on_hit(&mut self, set: usize, way: usize, meta: &AccessMeta) {
        let idx = set * self.assoc + way;
        self.clock += 1;
        self.stamp[idx] = self.clock;
        if let Some(p) = meta.predicted_utility {
            self.prob[idx] = p;
        }
        self.rrpv[idx] = 0;
    }

    fn on_fill(&mut self, set: usize, way: usize, meta: &AccessMeta) {
        let idx = set * self.assoc + way;
        self.clock += 1;
        self.stamp[idx] = self.clock;
        self.prob[idx] = meta.predicted_utility.unwrap_or(NEUTRAL);
        self.rrpv[idx] = Self::quantize(self.prob[idx]);
    }

    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.assoc;
        loop {
            let mut best: Option<usize> = None;
            let mut best_key = (f32::INFINITY, u64::MAX);
            for w in 0..self.assoc {
                if self.rrpv[base + w] >= MAX_RRPV {
                    let key = (self.prob[base + w], self.stamp[base + w]);
                    if key.0 < best_key.0 || (key.0 == best_key.0 && key.1 < best_key.1) {
                        best_key = key;
                        best = Some(w);
                    }
                }
            }
            if let Some(w) = best {
                return w;
            }
            for w in 0..self.assoc {
                self.rrpv[base + w] += 1;
            }
        }
    }

    fn update_utility(&mut self, set: usize, way: usize, utility: f32) {
        let idx = set * self.assoc + way;
        self.prob[idx] = utility;
        self.rrpv[idx] = Self::quantize(utility);
    }

    fn reset_utilities(&mut self) {
        // Adaptive back-off: resident lines revert to the neutral prior so
        // stale predictions stop deciding victims; RRPV ages out naturally.
        for p in &mut self.prob {
            *p = NEUTRAL;
        }
    }

    fn on_invalidate(&mut self, set: usize, way: usize) {
        let idx = set * self.assoc + way;
        self.prob[idx] = NEUTRAL;
        self.rrpv[idx] = MAX_RRPV;
        self.stamp[idx] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::StreamKind;

    fn meta_p(p: Option<f32>) -> AccessMeta {
        let mut m = AccessMeta::demand(0, 0, StreamKind::Embedding);
        m.predicted_utility = p;
        m
    }

    #[test]
    fn evicts_lowest_probability() {
        let mut p = MlPredict::new(1, 4);
        p.on_fill(0, 0, &meta_p(Some(0.9)));
        p.on_fill(0, 1, &meta_p(Some(0.1)));
        p.on_fill(0, 2, &meta_p(Some(0.6)));
        p.on_fill(0, 3, &meta_p(Some(0.4)));
        // Low probability ⇒ deep insertion ⇒ ages out first.
        let v = p.victim(0);
        assert_eq!(v, 1);
        // Replace the victim with a confident line; a prediction downgrade
        // elsewhere must redirect the next eviction there.
        p.on_fill(0, v, &meta_p(Some(0.95)));
        p.update_utility(0, 2, 0.01);
        assert_eq!(p.victim(0), 2);
    }

    #[test]
    fn recency_breaks_ties() {
        let mut p = MlPredict::new(1, 2);
        p.on_fill(0, 0, &meta_p(Some(0.5)));
        p.on_fill(0, 1, &meta_p(Some(0.5)));
        assert_eq!(p.victim(0), 0, "older fill loses the tie");
    }

    #[test]
    fn missing_prediction_is_neutral() {
        let mut p = MlPredict::new(1, 2);
        p.on_fill(0, 0, &meta_p(None));
        p.on_fill(0, 1, &meta_p(Some(0.8)));
        assert_eq!(p.victim(0), 0);
    }
}
