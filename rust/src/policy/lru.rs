//! True LRU — the paper's baseline row in Table 1. Per-set recency stack
//! implemented as monotone counters (age-stamp scheme): O(1) touch, O(assoc)
//! victim scan; exact LRU order.

use super::{AccessMeta, Policy};

pub struct Lru {
    assoc: usize,
    /// stamp[set*assoc + way]: larger = more recently used.
    stamp: Vec<u64>,
    clock: u64,
}

impl Lru {
    pub fn new(sets: usize, assoc: usize) -> Self {
        Self { assoc, stamp: vec![0; sets * assoc], clock: 0 }
    }

    #[inline]
    fn touch(&mut self, set: usize, way: usize) {
        self.clock += 1;
        self.stamp[set * self.assoc + way] = self.clock;
    }

    /// Recency rank of `way` within its set: 0 = MRU .. assoc-1 = LRU.
    /// Exposed for the implicit-predictor loss evaluation (Table 1's
    /// "final loss" for non-learned policies; DESIGN.md §5).
    pub fn recency_rank(&self, set: usize, way: usize) -> usize {
        let base = set * self.assoc;
        let mine = self.stamp[base + way];
        (0..self.assoc).filter(|&w| self.stamp[base + w] > mine).count()
    }
}

impl Policy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_hit(&mut self, set: usize, way: usize, _meta: &AccessMeta) {
        self.touch(set, way);
    }

    fn on_fill(&mut self, set: usize, way: usize, _meta: &AccessMeta) {
        self.touch(set, way);
    }

    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.assoc;
        let mut best = 0;
        let mut best_stamp = u64::MAX;
        for w in 0..self.assoc {
            let s = self.stamp[base + w];
            if s < best_stamp {
                best_stamp = s;
                best = w;
            }
        }
        best
    }

    fn on_invalidate(&mut self, set: usize, way: usize) {
        self.stamp[set * self.assoc + way] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::AccessMeta;
    use crate::trace::StreamKind;

    fn meta() -> AccessMeta {
        AccessMeta::demand(0, 0, StreamKind::Weight)
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut p = Lru::new(1, 4);
        for w in 0..4 {
            p.on_fill(0, w, &meta());
        }
        // Touch 0,1,3 → LRU is 2.
        p.on_hit(0, 0, &meta());
        p.on_hit(0, 1, &meta());
        p.on_hit(0, 3, &meta());
        assert_eq!(p.victim(0), 2);
        // Touch 2 → LRU is 0 (oldest remaining).
        p.on_hit(0, 2, &meta());
        assert_eq!(p.victim(0), 0);
    }

    #[test]
    fn sets_are_independent() {
        let mut p = Lru::new(2, 2);
        p.on_fill(0, 0, &meta());
        p.on_fill(1, 1, &meta());
        p.on_fill(0, 1, &meta());
        p.on_fill(1, 0, &meta());
        assert_eq!(p.victim(0), 0);
        assert_eq!(p.victim(1), 1);
    }

    #[test]
    fn recency_rank_is_a_permutation() {
        let mut p = Lru::new(1, 8);
        for w in 0..8 {
            p.on_fill(0, w, &meta());
        }
        p.on_hit(0, 3, &meta());
        let mut ranks: Vec<usize> = (0..8).map(|w| p.recency_rank(0, w)).collect();
        ranks.sort_unstable();
        assert_eq!(ranks, (0..8).collect::<Vec<_>>());
        assert_eq!(p.recency_rank(0, 3), 0, "just-touched way is MRU");
    }

    #[test]
    fn lru_stack_property_inclusion() {
        // Classic LRU inclusion: hits under assoc k imply hits under k+1.
        // Simulate the same access stream on two associativities and check
        // hit set inclusion (single set).
        use crate::util::rng::Xoshiro256;
        let stream: Vec<u64> = {
            let mut r = Xoshiro256::new(9);
            (0..400).map(|_| r.gen_range(12)).collect()
        };
        let run = |assoc: usize| -> Vec<bool> {
            let mut p = Lru::new(1, assoc);
            let mut resident: Vec<Option<u64>> = vec![None; assoc];
            let mut hits = Vec::new();
            for &line in &stream {
                if let Some(w) = resident.iter().position(|&t| t == Some(line)) {
                    p.on_hit(0, w, &meta());
                    hits.push(true);
                } else {
                    hits.push(false);
                    let w = resident.iter().position(|t| t.is_none()).unwrap_or_else(|| p.victim(0));
                    resident[w] = Some(line);
                    p.on_fill(0, w, &meta());
                }
            }
            hits
        };
        let h4 = run(4);
        let h8 = run(8);
        for (i, (&a, &b)) in h4.iter().zip(&h8).enumerate() {
            assert!(!a || b, "stack property violated at {i}");
        }
    }
}
