//! Belady's MIN oracle: evict the line whose next use is farthest in the
//! future. Not realizable in hardware; used as the hit-rate upper bound in
//! ablation benches. Requires the simulator to annotate each access with the
//! line's next-use time (`AccessMeta::next_use`), computed by a backward
//! pass over the trace (`sim::oracle::annotate_next_use`).

use super::{AccessMeta, Policy};

const NEVER: u64 = u64::MAX;

pub struct Belady {
    assoc: usize,
    next_use: Vec<u64>,
}

impl Belady {
    pub fn new(sets: usize, assoc: usize) -> Self {
        Self { assoc, next_use: vec![NEVER; sets * assoc] }
    }
}

impl Policy for Belady {
    fn name(&self) -> &'static str {
        "belady"
    }

    fn on_hit(&mut self, set: usize, way: usize, meta: &AccessMeta) {
        self.next_use[set * self.assoc + way] = meta.next_use.unwrap_or(NEVER);
    }

    fn on_fill(&mut self, set: usize, way: usize, meta: &AccessMeta) {
        self.next_use[set * self.assoc + way] = meta.next_use.unwrap_or(NEVER);
    }

    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.assoc;
        let mut best = 0;
        let mut best_t = 0;
        for w in 0..self.assoc {
            let t = self.next_use[base + w];
            if t == NEVER {
                return w; // dead line: perfect victim
            }
            if t > best_t {
                best_t = t;
                best = w;
            }
        }
        best
    }

    fn on_invalidate(&mut self, set: usize, way: usize) {
        self.next_use[set * self.assoc + way] = NEVER;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::StreamKind;

    fn meta_next(next: Option<u64>) -> AccessMeta {
        let mut m = AccessMeta::demand(0, 0, StreamKind::Weight);
        m.next_use = next;
        m
    }

    #[test]
    fn picks_farthest_future_use() {
        let mut p = Belady::new(1, 4);
        p.on_fill(0, 0, &meta_next(Some(10)));
        p.on_fill(0, 1, &meta_next(Some(500)));
        p.on_fill(0, 2, &meta_next(Some(50)));
        p.on_fill(0, 3, &meta_next(Some(100)));
        assert_eq!(p.victim(0), 1);
    }

    #[test]
    fn dead_line_beats_everything() {
        let mut p = Belady::new(1, 3);
        p.on_fill(0, 0, &meta_next(Some(1_000_000)));
        p.on_fill(0, 1, &meta_next(None)); // never used again
        p.on_fill(0, 2, &meta_next(Some(5)));
        assert_eq!(p.victim(0), 1);
    }

    #[test]
    fn hit_refreshes_next_use() {
        let mut p = Belady::new(1, 2);
        p.on_fill(0, 0, &meta_next(Some(100)));
        p.on_fill(0, 1, &meta_next(Some(50)));
        // Line 1 gets re-touched; its *new* next use is very far → victim.
        p.on_hit(0, 1, &meta_next(Some(10_000)));
        assert_eq!(p.victim(0), 1);
    }
}
