//! Tree-PLRU — the hardware-cheap LRU approximation (related work [2]).
//! One bit per internal node of a binary tree over the ways; a touch flips
//! the path away from the touched way, the victim follows the bits.

use super::{AccessMeta, Policy};

pub struct TreePlru {
    assoc: usize,
    /// Per-set tree bits; tree has `assoc - 1` internal nodes (assoc = 2^k).
    bits: Vec<bool>,
    nodes: usize,
}

impl TreePlru {
    pub fn new(sets: usize, assoc: usize) -> Self {
        assert!(assoc.is_power_of_two(), "tree-PLRU requires power-of-two associativity");
        let nodes = assoc - 1;
        Self { assoc, bits: vec![false; sets * nodes.max(1)], nodes }
    }

    fn touch(&mut self, set: usize, way: usize) {
        if self.nodes == 0 {
            return;
        }
        let base = set * self.nodes;
        let mut node = 0usize;
        let mut lo = 0usize;
        let mut hi = self.assoc;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            let right = way >= mid;
            // Point the bit AWAY from the touched half.
            self.bits[base + node] = !right;
            node = 2 * node + if right { 2 } else { 1 };
            if right {
                lo = mid;
            } else {
                hi = mid;
            }
        }
    }
}

impl Policy for TreePlru {
    fn name(&self) -> &'static str {
        "plru"
    }

    fn on_hit(&mut self, set: usize, way: usize, _meta: &AccessMeta) {
        self.touch(set, way);
    }

    fn on_fill(&mut self, set: usize, way: usize, _meta: &AccessMeta) {
        self.touch(set, way);
    }

    fn victim(&mut self, set: usize) -> usize {
        if self.nodes == 0 {
            return 0;
        }
        let base = set * self.nodes;
        let mut node = 0usize;
        let mut lo = 0usize;
        let mut hi = self.assoc;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            let right = self.bits[base + node];
            node = 2 * node + if right { 2 } else { 1 };
            if right {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::StreamKind;

    fn meta() -> AccessMeta {
        AccessMeta::demand(0, 0, StreamKind::Weight)
    }

    #[test]
    fn victim_avoids_recent_touch() {
        let mut p = TreePlru::new(1, 8);
        for w in 0..8 {
            p.on_fill(0, w, &meta());
        }
        let last = 5;
        p.on_hit(0, last, &meta());
        assert_ne!(p.victim(0), last, "PLRU must not evict the MRU way");
    }

    #[test]
    fn repeated_touch_cycles_all_other_ways() {
        // Touch way 0 forever: victims must come from the other ways and
        // eventually cover several of them (approximation of LRU).
        let mut p = TreePlru::new(1, 4);
        for w in 0..4 {
            p.on_fill(0, w, &meta());
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..16 {
            p.on_hit(0, 0, &meta());
            let v = p.victim(0);
            assert_ne!(v, 0);
            p.on_fill(0, v, &meta());
            seen.insert(v);
        }
        assert!(seen.len() >= 2, "victims should rotate: {seen:?}");
    }

    #[test]
    fn assoc_two_behaves_as_lru() {
        let mut p = TreePlru::new(1, 2);
        p.on_fill(0, 0, &meta());
        p.on_fill(0, 1, &meta());
        p.on_hit(0, 0, &meta());
        assert_eq!(p.victim(0), 1);
        p.on_hit(0, 1, &meta());
        assert_eq!(p.victim(0), 0);
    }
}
