//! SHiP (Wu et al., MICRO'11 — related work [6]): signature-based hit
//! prediction layered on SRRIP. Each fill is tagged with a PC signature;
//! a table of saturating counters (SHCT) learns whether fills from that
//! signature tend to be re-referenced. Zero-counter signatures insert at
//! distant RRPV (likely dead), others at long.

use super::{AccessMeta, Policy};

const M: u8 = 2;
const MAX_RRPV: u8 = (1 << M) - 1;
const LONG_RRPV: u8 = MAX_RRPV - 1;
const SHCT_SIZE: usize = 16 * 1024;
const SHCT_MAX: u8 = 7; // 3-bit counters

pub struct Ship {
    assoc: usize,
    rrpv: Vec<u8>,
    /// Per-line fill signature and outcome (re-referenced since fill?).
    sig: Vec<u16>,
    outcome: Vec<bool>,
    shct: Vec<u8>,
}

fn signature(pc: u64) -> u16 {
    // Fibonacci hash of the PC into the SHCT index space.
    ((pc.wrapping_mul(0x9E3779B97F4A7C15) >> 49) as usize % SHCT_SIZE) as u16
}

impl Ship {
    pub fn new(sets: usize, assoc: usize) -> Self {
        Self {
            assoc,
            rrpv: vec![MAX_RRPV; sets * assoc],
            sig: vec![0; sets * assoc],
            outcome: vec![false; sets * assoc],
            // Start mildly optimistic so cold signatures are not all-dead.
            shct: vec![1; SHCT_SIZE],
        }
    }

    pub fn shct_value(&self, pc: u64) -> u8 {
        self.shct[signature(pc) as usize]
    }
}

impl Policy for Ship {
    fn name(&self) -> &'static str {
        "ship"
    }

    fn on_hit(&mut self, set: usize, way: usize, _meta: &AccessMeta) {
        let idx = set * self.assoc + way;
        self.rrpv[idx] = 0;
        if !self.outcome[idx] {
            self.outcome[idx] = true;
            let s = self.sig[idx] as usize;
            self.shct[s] = (self.shct[s] + 1).min(SHCT_MAX);
        }
    }

    fn on_fill(&mut self, set: usize, way: usize, meta: &AccessMeta) {
        let idx = set * self.assoc + way;
        // Close out the previous resident's training sample.
        if !self.outcome[idx] && self.sig[idx] != 0 {
            let s = self.sig[idx] as usize;
            self.shct[s] = self.shct[s].saturating_sub(1);
        }
        let s = signature(meta.pc);
        self.sig[idx] = s;
        self.outcome[idx] = false;
        let dead_likely = self.shct[s as usize] == 0;
        self.rrpv[idx] = if dead_likely || meta.is_prefetch { MAX_RRPV } else { LONG_RRPV };
    }

    fn victim(&mut self, set: usize) -> usize {
        let base = set * self.assoc;
        loop {
            for w in 0..self.assoc {
                if self.rrpv[base + w] >= MAX_RRPV {
                    return w;
                }
            }
            for w in 0..self.assoc {
                self.rrpv[base + w] += 1;
            }
        }
    }

    fn on_invalidate(&mut self, set: usize, way: usize) {
        let idx = set * self.assoc + way;
        self.rrpv[idx] = MAX_RRPV;
        self.sig[idx] = 0;
        self.outcome[idx] = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::StreamKind;

    fn meta_pc(pc: u64) -> AccessMeta {
        AccessMeta::demand(0, pc, StreamKind::Weight)
    }

    #[test]
    fn learns_dead_signature() {
        let mut p = Ship::new(1, 4);
        let dead_pc = 0xDEAD;
        // Repeatedly fill from dead_pc and evict without reuse.
        for i in 0..16 {
            let w = (i % 4) as usize;
            p.on_fill(0, w, &meta_pc(dead_pc));
        }
        assert_eq!(p.shct_value(dead_pc), 0, "unreused signature should saturate low");
        // New fill from the dead signature inserts distant → immediate victim.
        p.on_fill(0, 0, &meta_pc(dead_pc));
        p.on_fill(0, 1, &meta_pc(0xBEEF));
        p.on_hit(0, 1, &meta_pc(0xBEEF));
        assert_eq!(p.victim(0), 0);
    }

    #[test]
    fn learns_live_signature() {
        let mut p = Ship::new(1, 4);
        let live_pc = 0xA11CE;
        for i in 0..8 {
            let w = (i % 4) as usize;
            p.on_fill(0, w, &meta_pc(live_pc));
            p.on_hit(0, w, &meta_pc(live_pc));
        }
        assert!(p.shct_value(live_pc) > 1);
    }
}
