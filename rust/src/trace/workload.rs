//! The [`Workload`] abstraction: anything that can stream [`Access`]es into
//! the experiment engine.
//!
//! Historically the access-driving loop was welded to [`TraceGenerator`] in
//! four places (simulator, table1, coordinator workers, benches). The trait
//! decouples *what* produces accesses from *how* they are driven through a
//! cache hierarchy: the [`crate::sim::Engine`] runs any `Box<dyn Workload>`,
//! and the scenario registry ([`super::scenario`]) names concrete
//! instantiations.
//!
//! Besides the access stream itself, a workload exposes the ground-truth
//! hooks the engine and the serving coordinator need:
//!
//! - **progress accounting** (`tokens_done`, `sessions_completed`) for
//!   throughput metrics;
//! - **admission control** (`force_arrival`, `has_work`, `live_sessions`)
//!   for router-driven serving mode, where autonomous arrivals are disabled
//!   and the coordinator admits sessions explicitly;
//! - **materialization** (`generate`) for oracle (Belady) runs that need
//!   the whole trace up front to annotate next-use times.

use super::generator::TraceGenerator;
use super::Access;

/// A deterministic, seedable source of LLM-inference memory accesses.
///
/// `Send` is required so workloads can be moved into sweep / coordinator
/// worker threads.
pub trait Workload: Send {
    /// Human-readable label (scenario or profile name) for reports.
    fn name(&self) -> String;

    /// Produce the next access. Workloads are infinite streams: this must
    /// always return (generators synthesize arrivals when idle).
    fn next_access(&mut self) -> Access;

    /// Tokens decoded so far (ground truth for TGT / tokens-per-second).
    fn tokens_done(&self) -> u64;

    /// Sessions fully completed so far.
    fn sessions_completed(&self) -> u64;

    /// Currently live sessions.
    fn live_sessions(&self) -> usize;

    /// True when a `next_access` call can make progress without an
    /// autonomous arrival (the coordinator drains workers on this).
    fn has_work(&self) -> bool;

    /// Externally-driven session admission (the serving router calls this).
    /// Returns false when the workload cannot accept another session.
    fn force_arrival(&mut self) -> bool;

    /// Open-loop traffic counters, when this workload models offered load
    /// decoupled from service rate (see [`crate::traffic`]). Closed-loop
    /// workloads report `None` and their runs carry no traffic block.
    fn traffic(&self) -> Option<crate::traffic::TrafficSummary> {
        None
    }

    /// Materialize `n` accesses (consumes stream state). Oracle runs use
    /// this to annotate next-use times before simulation.
    fn generate(&mut self, n: usize) -> Vec<Access> {
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.next_access());
        }
        v
    }
}

impl Workload for TraceGenerator {
    fn name(&self) -> String {
        self.profile_name().to_string()
    }

    fn next_access(&mut self) -> Access {
        TraceGenerator::next_access(self)
    }

    fn tokens_done(&self) -> u64 {
        TraceGenerator::tokens_done(self)
    }

    fn sessions_completed(&self) -> u64 {
        TraceGenerator::sessions_completed(self)
    }

    fn live_sessions(&self) -> usize {
        TraceGenerator::live_sessions(self)
    }

    fn has_work(&self) -> bool {
        TraceGenerator::has_work(self)
    }

    fn force_arrival(&mut self) -> bool {
        TraceGenerator::force_arrival(self)
    }

    fn generate(&mut self, n: usize) -> Vec<Access> {
        TraceGenerator::generate(self, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::GeneratorConfig;

    #[test]
    fn generator_satisfies_workload_contract() {
        let mut w: Box<dyn Workload> = Box::new(TraceGenerator::new(GeneratorConfig::tiny(3)));
        let first = w.next_access();
        let direct = TraceGenerator::new(GeneratorConfig::tiny(3)).next_access();
        assert_eq!(first, direct, "trait dispatch must not change the stream");
        let _ = w.generate(1_000);
        assert!(w.tokens_done() > 0);
        assert!(!w.name().is_empty());
    }

    #[test]
    fn workload_is_boxable_and_send() {
        fn assert_send<T: Send>(_: &T) {}
        let w: Box<dyn Workload> = Box::new(TraceGenerator::new(GeneratorConfig::tiny(1)));
        assert_send(&w);
    }
}
