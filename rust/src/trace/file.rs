//! Binary trace file format (`.acpctrace`): persist generated traces so the
//! same workload can be replayed across policies, benches, and the Python
//! side if ever needed. Little-endian, fixed 40-byte records, versioned
//! header with a record-count for integrity checking.
//!
//! Layout:
//! ```text
//! magic  u64  = 0x4143_5043_5452_4331  ("ACPCTRC1")
//! count  u64
//! record × count:
//!   time u64 | addr u64 | pc u64 | session u32 | ctx_len u32 |
//!   layer u16 | kind u8 | is_write u8 | pad u32
//! ```

use super::{Access, StreamKind};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: u64 = 0x4143_5043_5452_4331;
pub const RECORD_BYTES: usize = 40;

pub fn write_trace(path: &Path, trace: &[Access]) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&(trace.len() as u64).to_le_bytes())?;
    let mut rec = [0u8; RECORD_BYTES];
    for a in trace {
        rec[0..8].copy_from_slice(&a.time.to_le_bytes());
        rec[8..16].copy_from_slice(&a.addr.to_le_bytes());
        rec[16..24].copy_from_slice(&a.pc.to_le_bytes());
        rec[24..28].copy_from_slice(&a.session.to_le_bytes());
        rec[28..32].copy_from_slice(&a.ctx_len.to_le_bytes());
        rec[32..34].copy_from_slice(&a.layer.to_le_bytes());
        rec[34] = a.kind as u8;
        rec[35] = a.is_write as u8;
        rec[36..40].fill(0);
        w.write_all(&rec)?;
    }
    w.flush()?;
    Ok(())
}

pub fn read_trace(path: &Path) -> Result<Vec<Access>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut hdr = [0u8; 16];
    r.read_exact(&mut hdr).context("trace header")?;
    let magic = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
    if magic != MAGIC {
        bail!("not an acpc trace file (bad magic {magic:#x})");
    }
    let count = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(count);
    let mut rec = [0u8; RECORD_BYTES];
    for i in 0..count {
        r.read_exact(&mut rec).with_context(|| format!("record {i}/{count}"))?;
        out.push(Access {
            time: u64::from_le_bytes(rec[0..8].try_into().unwrap()),
            addr: u64::from_le_bytes(rec[8..16].try_into().unwrap()),
            pc: u64::from_le_bytes(rec[16..24].try_into().unwrap()),
            session: u32::from_le_bytes(rec[24..28].try_into().unwrap()),
            ctx_len: u32::from_le_bytes(rec[28..32].try_into().unwrap()),
            layer: u16::from_le_bytes(rec[32..34].try_into().unwrap()),
            kind: StreamKind::from_u8(rec[34]),
            is_write: rec[35] != 0,
        });
    }
    // Must be exactly at EOF.
    let mut extra = [0u8; 1];
    if r.read(&mut extra)? != 0 {
        bail!("trailing bytes after {count} records");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    #[test]
    fn roundtrip() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(21)).generate(10_000);
        let dir = std::env::temp_dir().join("acpc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.acpctrace");
        write_trace(&path, &trace).unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(trace, back);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("acpc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.acpctrace");
        std::fs::write(&path, b"definitely not a trace file....").unwrap();
        assert!(read_trace(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncation() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(2)).generate(100);
        let dir = std::env::temp_dir().join("acpc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.acpctrace");
        write_trace(&path, &trace).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 13]).unwrap();
        assert!(read_trace(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
