//! Binary trace file format (`.acpctrace`): persist generated traces so the
//! same workload can be replayed across policies, benches, and the Python
//! side if ever needed. Little-endian, fixed-size records, versioned
//! header with a record-count for integrity checking.
//!
//! v1 layout (synthetic traces, 40-byte records):
//! ```text
//! magic  u64  = 0x4143_5043_5452_4331  ("ACPCTRC1")
//! count  u64
//! record × count:
//!   time u64 | addr u64 | pc u64 | session u32 | ctx_len u32 |
//!   layer u16 | kind u8 | is_write u8 | pad u32
//! ```
//!
//! v2 layout (serve captures, 56-byte records — see [`crate::traffic`]):
//! ```text
//! magic    u64  = 0x4143_5043_5452_4332  ("ACPCTRC2")
//! count    u64
//! tokens   u64   (decoded tokens behind the capture, for replay progress)
//! sessions u64   (completed sessions behind the capture)
//! record × count:
//!   <v1 record, 40 bytes> | tenant u32 | pad u32 | arrival u64
//! ```
//!
//! Reading goes through the streaming [`TraceReader`] — header-validated,
//! chunked through a [`BufReader`] — so consumers like
//! [`crate::traffic::ReplayWorkload`] never materialize the whole trace;
//! [`read_trace`] is a thin collecting wrapper over it. Both versions are
//! readable; v1 records surface with `tenant = 0`, `arrival = 0`.

use super::{Access, StreamKind};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC_V1: u64 = 0x4143_5043_5452_4331;
const MAGIC_V2: u64 = 0x4143_5043_5452_4332;
pub const RECORD_BYTES: usize = 40;
pub const RECORD_BYTES_V2: usize = 56;

/// One v2 record: the access plus its traffic provenance. v1 files read
/// back with zeroed `tenant`/`arrival`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub access: Access,
    /// Originating tenant (serve captures: the worker index).
    pub tenant: u32,
    /// Arrival timestamp in the producer's tick clock.
    pub arrival: u64,
}

fn encode_access(a: &Access, rec: &mut [u8]) {
    rec[0..8].copy_from_slice(&a.time.to_le_bytes());
    rec[8..16].copy_from_slice(&a.addr.to_le_bytes());
    rec[16..24].copy_from_slice(&a.pc.to_le_bytes());
    rec[24..28].copy_from_slice(&a.session.to_le_bytes());
    rec[28..32].copy_from_slice(&a.ctx_len.to_le_bytes());
    rec[32..34].copy_from_slice(&a.layer.to_le_bytes());
    rec[34] = a.kind as u8;
    rec[35] = a.is_write as u8;
    rec[36..40].fill(0);
}

fn decode_access(rec: &[u8]) -> Access {
    Access {
        time: u64::from_le_bytes(rec[0..8].try_into().unwrap()),
        addr: u64::from_le_bytes(rec[8..16].try_into().unwrap()),
        pc: u64::from_le_bytes(rec[16..24].try_into().unwrap()),
        session: u32::from_le_bytes(rec[24..28].try_into().unwrap()),
        ctx_len: u32::from_le_bytes(rec[28..32].try_into().unwrap()),
        layer: u16::from_le_bytes(rec[32..34].try_into().unwrap()),
        kind: StreamKind::from_u8(rec[34]),
        is_write: rec[35] != 0,
    }
}

/// Write a v1 (access-only) trace.
pub fn write_trace(path: &Path, trace: &[Access]) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC_V1.to_le_bytes())?;
    w.write_all(&(trace.len() as u64).to_le_bytes())?;
    let mut rec = [0u8; RECORD_BYTES];
    for a in trace {
        encode_access(a, &mut rec);
        w.write_all(&rec)?;
    }
    w.flush()?;
    Ok(())
}

/// Write a v2 (capture) trace: tenant + arrival per record, decoded-token
/// and completed-session totals in the header so replay can report
/// progress.
pub fn write_trace_v2(
    path: &Path,
    records: &[TraceRecord],
    tokens: u64,
    sessions: u64,
) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC_V2.to_le_bytes())?;
    w.write_all(&(records.len() as u64).to_le_bytes())?;
    w.write_all(&tokens.to_le_bytes())?;
    w.write_all(&sessions.to_le_bytes())?;
    let mut rec = [0u8; RECORD_BYTES_V2];
    for r in records {
        encode_access(&r.access, &mut rec);
        rec[40..44].copy_from_slice(&r.tenant.to_le_bytes());
        rec[44..48].fill(0);
        rec[48..56].copy_from_slice(&r.arrival.to_le_bytes());
        w.write_all(&rec)?;
    }
    w.flush()?;
    Ok(())
}

/// Streaming `.acpctrace` reader: validates the header up front, then
/// yields records one at a time (buffered in [`BufReader`]-sized chunks)
/// without materializing the file. The iterator yields exactly
/// `count` `Ok` records for an intact file; truncation surfaces as an
/// `Err` item at the failing record, and trailing garbage as an `Err`
/// after the last one.
pub struct TraceReader {
    r: BufReader<std::fs::File>,
    version: u8,
    count: u64,
    tokens: u64,
    sessions: u64,
    read: u64,
    done: bool,
}

impl TraceReader {
    pub fn open(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = BufReader::new(f);
        let mut hdr = [0u8; 16];
        r.read_exact(&mut hdr).context("trace header")?;
        let magic = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        let count = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        let (version, tokens, sessions) = match magic {
            MAGIC_V1 => (1, 0, 0),
            MAGIC_V2 => {
                let mut ext = [0u8; 16];
                r.read_exact(&mut ext).context("v2 trace header")?;
                (
                    2,
                    u64::from_le_bytes(ext[0..8].try_into().unwrap()),
                    u64::from_le_bytes(ext[8..16].try_into().unwrap()),
                )
            }
            _ => bail!("not an acpc trace file (bad magic {magic:#x})"),
        };
        Ok(Self { r, version, count, tokens, sessions, read: 0, done: false })
    }

    /// Format version: 1 (access-only) or 2 (capture).
    pub fn version(&self) -> u8 {
        self.version
    }

    /// Records the header promises.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Decoded tokens behind the capture (0 for v1 files).
    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    /// Completed sessions behind the capture (0 for v1 files).
    pub fn sessions(&self) -> u64 {
        self.sessions
    }

    fn read_record(&mut self) -> Result<TraceRecord> {
        let i = self.read;
        let count = self.count;
        if self.version == 1 {
            let mut rec = [0u8; RECORD_BYTES];
            self.r.read_exact(&mut rec).with_context(|| format!("record {i}/{count}"))?;
            Ok(TraceRecord { access: decode_access(&rec), tenant: 0, arrival: 0 })
        } else {
            let mut rec = [0u8; RECORD_BYTES_V2];
            self.r.read_exact(&mut rec).with_context(|| format!("record {i}/{count}"))?;
            Ok(TraceRecord {
                access: decode_access(&rec[..RECORD_BYTES]),
                tenant: u32::from_le_bytes(rec[40..44].try_into().unwrap()),
                arrival: u64::from_le_bytes(rec[48..56].try_into().unwrap()),
            })
        }
    }
}

impl Iterator for TraceReader {
    type Item = Result<TraceRecord>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        if self.read == self.count {
            // Must be exactly at EOF.
            self.done = true;
            let mut extra = [0u8; 1];
            return match self.r.read(&mut extra) {
                Ok(0) => None,
                Ok(_) => Some(Err(anyhow::anyhow!(
                    "trailing bytes after {} records",
                    self.count
                ))),
                Err(e) => Some(Err(e.into())),
            };
        }
        match self.read_record() {
            Ok(rec) => {
                self.read += 1;
                Some(Ok(rec))
            }
            Err(e) => {
                self.done = true;
                Some(Err(e))
            }
        }
    }
}

/// Read a whole trace (either version) into memory — a thin collecting
/// wrapper over [`TraceReader`].
pub fn read_trace(path: &Path) -> Result<Vec<Access>> {
    let reader = TraceReader::open(path)?;
    let mut out = Vec::with_capacity(reader.count() as usize);
    for rec in reader {
        out.push(rec?.access);
    }
    Ok(out)
}

/// [`read_trace`] keeping the v2 provenance fields.
pub fn read_records(path: &Path) -> Result<Vec<TraceRecord>> {
    let reader = TraceReader::open(path)?;
    let mut out = Vec::with_capacity(reader.count() as usize);
    for rec in reader {
        out.push(rec?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    #[test]
    fn roundtrip() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(21)).generate(10_000);
        let dir = std::env::temp_dir().join("acpc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.acpctrace");
        write_trace(&path, &trace).unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(trace, back);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn roundtrip_v2_preserves_provenance() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(8)).generate(2_000);
        let records: Vec<TraceRecord> = trace
            .iter()
            .enumerate()
            .map(|(i, &access)| TraceRecord {
                access,
                tenant: (i % 5) as u32,
                arrival: i as u64 * 3,
            })
            .collect();
        let dir = std::env::temp_dir().join("acpc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t2.acpctrace");
        write_trace_v2(&path, &records, 777, 42).unwrap();

        let rd = TraceReader::open(&path).unwrap();
        assert_eq!(rd.version(), 2);
        assert_eq!(rd.count(), records.len() as u64);
        assert_eq!((rd.tokens(), rd.sessions()), (777, 42));
        let back = read_records(&path).unwrap();
        assert_eq!(records, back);
        // The access-only view still works on v2 files.
        assert_eq!(read_trace(&path).unwrap(), trace);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn streaming_reader_matches_bulk_read_on_v1() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(4)).generate(1_000);
        let dir = std::env::temp_dir().join("acpc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.acpctrace");
        write_trace(&path, &trace).unwrap();
        let rd = TraceReader::open(&path).unwrap();
        assert_eq!(rd.version(), 1);
        let streamed: Vec<Access> =
            rd.map(|r| r.unwrap()).map(|r| {
                assert_eq!((r.tenant, r.arrival), (0, 0), "v1 records carry no provenance");
                r.access
            })
            .collect();
        assert_eq!(streamed, trace);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("acpc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.acpctrace");
        std::fs::write(&path, b"definitely not a trace file....").unwrap();
        assert!(read_trace(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncation() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(2)).generate(100);
        let dir = std::env::temp_dir().join("acpc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.acpctrace");
        write_trace(&path, &trace).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 13]).unwrap();
        assert!(read_trace(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_trailing_garbage() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(2)).generate(50);
        let dir = std::env::temp_dir().join("acpc_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trail.acpctrace");
        write_trace(&path, &trace).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0xAB);
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_trace(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
