//! Model profiles: the knobs that shape a synthetic inference trace for a
//! given transformer family. Values are *scaled-down* analogues (DESIGN.md
//! §3): the cache hierarchy in the simulator is also scaled, so what matters
//! is the ratio of working-set sizes to cache sizes, not absolute bytes.

/// Shape of the simulated transformer + serving stack.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: String,
    /// Vocabulary size (embedding table rows).
    pub vocab: u64,
    /// Bytes per embedding row (row → contiguous cache lines).
    pub embed_row_bytes: u64,
    /// Lines touched per embedding lookup (head of the row).
    pub embed_lines_per_lookup: u64,
    /// Zipf exponent for token popularity.
    pub zipf_theta: f64,
    /// Number of transformer layers.
    pub layers: u16,
    /// KV bytes appended per token per layer.
    pub kv_bytes_per_token: u64,
    /// Sliding attention window (tokens) that dominates KV reads.
    pub attn_window: u32,
    /// KV read fan-in per generated token per layer (how many window
    /// positions are touched — a sparse sample of the window).
    pub kv_reads_per_token: u32,
    /// Probability that a KV read goes *outside* the window (long-range
    /// attention head) — these accesses look random and mislead prefetchers.
    pub kv_longrange_p: f64,
    /// Weight tiles per layer and bytes per tile; each token scans
    /// `weight_tiles_hot` of them cyclically.
    pub weight_tiles_per_layer: u64,
    pub weight_tile_bytes: u64,
    pub weight_tiles_hot: u64,
    /// Scratch (activation) lines per token per layer — near-zero reuse.
    pub scratch_lines_per_token: u64,
    /// Mean prompt length / generation length (tokens).
    pub prompt_len_mean: f64,
    pub gen_len_mean: f64,
}

impl ModelProfile {
    /// GPT-style decoder-only profile (the paper's primary workload):
    /// large vocabulary, deep, long generations.
    pub fn gpt3ish() -> Self {
        Self {
            name: "gpt3ish".into(),
            vocab: 50_000,
            embed_row_bytes: 512,
            embed_lines_per_lookup: 2,
            zipf_theta: 0.9,
            layers: 8,
            kv_bytes_per_token: 128,
            attn_window: 48,
            kv_reads_per_token: 10,
            kv_longrange_p: 0.08,
            weight_tiles_per_layer: 96,
            weight_tile_bytes: 4096,
            weight_tiles_hot: 16,
            scratch_lines_per_token: 2,
            prompt_len_mean: 64.0,
            gen_len_mean: 96.0,
        }
    }

    /// LLaMA-style profile: grouped-query attention → smaller KV per token,
    /// slightly flatter token distribution, shorter generations.
    pub fn llama2ish() -> Self {
        Self {
            name: "llama2ish".into(),
            vocab: 32_000,
            embed_row_bytes: 512,
            embed_lines_per_lookup: 2,
            zipf_theta: 0.8,
            layers: 16,
            kv_bytes_per_token: 128,
            attn_window: 96,
            kv_reads_per_token: 10,
            kv_longrange_p: 0.05,
            weight_tiles_per_layer: 128,
            weight_tile_bytes: 4096,
            weight_tiles_hot: 20,
            scratch_lines_per_token: 3,
            prompt_len_mean: 96.0,
            gen_len_mean: 64.0,
        }
    }

    /// T5-style encoder-decoder profile: shorter decode, heavier embedding
    /// traffic (shared input/output embeddings), smaller depth.
    pub fn t5ish() -> Self {
        Self {
            name: "t5ish".into(),
            vocab: 32_128,
            embed_row_bytes: 768,
            embed_lines_per_lookup: 3,
            zipf_theta: 0.9,
            layers: 8,
            kv_bytes_per_token: 192,
            attn_window: 48,
            kv_reads_per_token: 8,
            kv_longrange_p: 0.10,
            weight_tiles_per_layer: 64,
            weight_tile_bytes: 4096,
            weight_tiles_hot: 16,
            scratch_lines_per_token: 5,
            prompt_len_mean: 48.0,
            gen_len_mean: 32.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "gpt3ish" | "gpt3" | "gpt" => Some(Self::gpt3ish()),
            "llama2ish" | "llama2" | "llama" => Some(Self::llama2ish()),
            "t5ish" | "t5" => Some(Self::t5ish()),
            _ => None,
        }
    }

    /// Total embedding table bytes (for working-set sanity checks).
    pub fn embed_table_bytes(&self) -> u64 {
        self.vocab * self.embed_row_bytes
    }

    /// Hot weight working set per token (bytes, all layers).
    pub fn weight_hot_bytes(&self) -> u64 {
        self.layers as u64 * self.weight_tiles_hot * self.weight_tile_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolvable() {
        for n in ["gpt3ish", "llama2ish", "t5ish", "gpt", "llama", "t5"] {
            assert!(ModelProfile::by_name(n).is_some(), "{n}");
        }
        assert!(ModelProfile::by_name("nope").is_none());
    }

    #[test]
    fn working_sets_exceed_l2_scale() {
        // The profiles must stress a few-hundred-KB L2: hot weights alone
        // should exceed 256 KiB so replacement policy quality matters.
        for p in [ModelProfile::gpt3ish(), ModelProfile::llama2ish(), ModelProfile::t5ish()] {
            assert!(p.weight_hot_bytes() > 256 * 1024, "{}: {}", p.name, p.weight_hot_bytes());
            assert!(p.embed_table_bytes() > 4 * 1024 * 1024, "{}", p.name);
        }
    }
}
