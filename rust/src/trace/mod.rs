//! LLM-inference memory access trace substrate.
//!
//! The paper evaluates ACPC on cache access traces profiled from GPT-3 /
//! LLaMA-2 / T5 serving (2.3B records — not released). This module is the
//! documented substitution (DESIGN.md §3): a synthetic generator that
//! reproduces the *mechanisms* behind those traces:
//!
//! - **embedding lookups** — Zipf-distributed token ids over a large
//!   embedding table: a hot head with heavy reuse, a long cold tail that
//!   pollutes when prefetched;
//! - **KV-cache traffic** — per (session, layer) append-on-write streams
//!   whose reads concentrate in a sliding attention window plus sparse
//!   long-range re-reads: a line is hot while in-window and *provably dead*
//!   afterwards (the signal the TCN predictor can exploit);
//! - **weight streaming** — cyclic per-layer tile scans each token: a
//!   scanning pattern that thrashes LRU and motivates RRIP-style policies;
//! - **bursty session arrivals** — a two-state MMPP (hot/cold arrival
//!   rates) producing the bursty, non-uniform interleaving the paper
//!   describes;
//! - **phase drift** — the Zipf head rotates periodically, so a predictor
//!   trained once goes stale (exercises the online-learning loop, §3.4).
//!
//! # Workloads and the scenario registry
//!
//! The [`Workload`] trait ([`workload`]) abstracts *any* access source the
//! experiment [`crate::sim::Engine`] can drive — [`TraceGenerator`] is the
//! canonical implementation. On top of it, [`scenario`] provides a registry
//! of named access regimes ([`SCENARIO_NAMES`]), each a preconfigured
//! generator capturing one of the LLM serving patterns the paper (and the
//! related work it cites) evaluates:
//!
//! - [`decode-heavy`](scenario) — the stock autoregressive decode mix
//!   (weight-scan dominant; the Table 1 workload);
//! - [`prefill-burst`](scenario) — hot-state MMPP arrivals with long
//!   prompts: batched prefill KV writes dominate;
//! - [`rag-embedding`](scenario) — retrieval-style lookups over a large
//!   flat-tailed embedding table (majority embedding traffic);
//! - [`long-context`](scenario) — contexts far beyond the attention
//!   window: KV re-reads dominate and mislead recency policies;
//! - [`multi-tenant-mix`](scenario) — many interleaved sessions with fast
//!   phase drift;
//! - [`speculative-decode`](scenario) — draft/verify interleave whose
//!   verify passes re-read the drafted KV window in bulk;
//! - [`prefix-share`](scenario) — churning tenant population with
//!   per-tenant Zipf footprints and a shared system-prompt prefix block
//!   ([`crate::traffic::population`]);
//! - [`bursty-batch`](scenario) — the decode mix behind an open-loop
//!   on/off arrival process and bounded admission queue
//!   ([`crate::traffic::arrivals`]).
//!
//! Resolve by name with [`Scenario::by_name`], enumerate with
//! [`Scenario::all`], and instantiate with `Scenario::workload(seed)`.
//! The `acpc sweep` command runs the full policy×scenario grid in parallel.

pub mod file;
pub mod generator;
pub mod profile;
pub mod scenario;
pub mod stats;
pub mod workload;

pub use generator::{GeneratorConfig, TraceGenerator};
pub use profile::ModelProfile;
pub use scenario::{Scenario, SCENARIO_NAMES};
pub use workload::Workload;

/// Memory stream kind — the coarse "instruction type" feature of the paper's
/// record tuple (eq. 5). Encoded into addresses (region) and features.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum StreamKind {
    /// Input/output embedding row read (token id → row).
    Embedding = 0,
    /// Attention KV-cache read within the context window.
    KvRead = 1,
    /// KV-cache append for the newly generated token.
    KvWrite = 2,
    /// Model weight tile read (cyclic per-layer scan).
    Weight = 3,
    /// Activation scratch traffic (low reuse filler).
    Scratch = 4,
}

impl StreamKind {
    pub fn from_u8(v: u8) -> StreamKind {
        match v {
            0 => StreamKind::Embedding,
            1 => StreamKind::KvRead,
            2 => StreamKind::KvWrite,
            3 => StreamKind::Weight,
            _ => StreamKind::Scratch,
        }
    }

    pub const ALL: [StreamKind; 5] = [
        StreamKind::Embedding,
        StreamKind::KvRead,
        StreamKind::KvWrite,
        StreamKind::Weight,
        StreamKind::Scratch,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            StreamKind::Embedding => "embed",
            StreamKind::KvRead => "kv_rd",
            StreamKind::KvWrite => "kv_wr",
            StreamKind::Weight => "weight",
            StreamKind::Scratch => "scratch",
        }
    }
}

/// One memory access event — the in-memory form of the paper's record tuple
/// `D_i = {T_i, A_i, F_i, S_i, H_i, L_i}` (timestamp, address, feature hash,
/// context length, history reuse distance, reuse label). The reuse label is
/// *not* stored here; it is derived by `predictor::labeler` with a forward
/// pass over the trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Access {
    /// Logical timestamp (cycle-ish; monotonically increasing).
    pub time: u64,
    /// Byte address; cache line = `addr >> 6`.
    pub addr: u64,
    /// Synthetic program counter (stream kind × layer site) for PC-indexed
    /// policies (SHiP) and the stride prefetcher.
    pub pc: u64,
    /// Stream kind (the paper's "instruction type" feature).
    pub kind: StreamKind,
    /// Serving session id.
    pub session: u32,
    /// Context length (token position) at the time of access — the paper's
    /// `S_i` feature.
    pub ctx_len: u32,
    /// Transformer layer index.
    pub layer: u16,
    /// Write (KV append) vs read.
    pub is_write: bool,
}

impl Access {
    #[inline]
    pub fn line(&self) -> u64 {
        self.addr >> 6
    }
}

/// Address-space regions. Region tag lives in bits 40..44 so realistic
/// offsets never collide across regions.
pub mod region {
    pub const SHIFT: u64 = 40;
    pub const EMBED: u64 = 1 << SHIFT;
    pub const KV: u64 = 2 << SHIFT;
    pub const WEIGHT: u64 = 3 << SHIFT;
    pub const SCRATCH: u64 = 4 << SHIFT;

    pub fn of(addr: u64) -> u64 {
        addr >> SHIFT
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_kind_roundtrip() {
        for k in StreamKind::ALL {
            assert_eq!(StreamKind::from_u8(k as u8), k);
        }
    }

    #[test]
    fn regions_disjoint() {
        let e = region::EMBED + 0xFFFF_FFFF;
        let k = region::KV;
        assert_ne!(region::of(e), region::of(k));
    }

    #[test]
    fn line_granularity() {
        let a = Access {
            time: 0,
            addr: 0x1234,
            pc: 0,
            kind: StreamKind::Embedding,
            session: 0,
            ctx_len: 0,
            layer: 0,
            is_write: false,
        };
        assert_eq!(a.line(), 0x1234 >> 6);
    }
}
