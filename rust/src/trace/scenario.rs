//! Named workload scenarios — the distinct LLM access regimes the paper's
//! evaluation spans, each a preconfigured [`GeneratorConfig`] built from
//! [`ModelProfile`] + generator knobs.
//!
//! A scenario is a *recipe*: `Scenario::config(seed)` yields a fully
//! deterministic generator configuration, and `Scenario::workload(seed)`
//! a ready-to-drive [`Workload`]. Each scenario declares the [`StreamKind`]
//! expected to dominate its access mix; tests assert the declaration holds,
//! so the registry doubles as executable documentation of the regimes:
//!
//! | scenario             | regime                                     | dominant |
//! |----------------------|--------------------------------------------|----------|
//! | `decode-heavy`       | autoregressive decode (paper's default)    | weight   |
//! | `prefill-burst`      | hot-state MMPP, long prompts, short gens   | kv_wr    |
//! | `rag-embedding`      | Zipf-tail embedding retrieval              | embed    |
//! | `long-context`       | max_ctx ≫ attention window, KV re-reads    | kv_rd    |
//! | `multi-tenant-mix`   | many interleaved sessions, fast drift      | weight   |
//! | `speculative-decode` | draft/verify interleave, KV verify re-reads| kv_rd    |
//! | `prefix-share`       | tenant population, churn, shared prefix    | kv_rd    |
//! | `bursty-batch`       | open-loop on/off arrivals, bounded queue   | weight   |
//!
//! The last two are *traffic* scenarios ([`crate::traffic`]): `prefix-share`
//! runs the tenant-population workload and `bursty-batch` drives the stock
//! decode generator through an open-loop bursty arrival process, so its run
//! reports carry a `traffic` block (offered/admitted/shed, queue delay).

use super::generator::{GeneratorConfig, TraceGenerator};
use super::profile::ModelProfile;
use super::workload::Workload;
use super::StreamKind;
use crate::traffic::{OpenLoopConfig, OpenLoopWorkload, PopulationConfig, PopulationWorkload};

/// How a scenario turns its [`GeneratorConfig`] into a workload.
#[derive(Clone, Copy)]
enum Kind {
    /// Plain closed-loop [`TraceGenerator`].
    Generator,
    /// Generator wrapped in an open-loop bursty arrival process.
    OpenLoop,
    /// Tenant-population workload (the generator config only contributes
    /// its seed and profile name).
    Population,
}

/// One named workload regime.
#[derive(Clone, Copy)]
pub struct Scenario {
    pub name: &'static str,
    /// One-line description for `acpc policies` / docs.
    pub summary: &'static str,
    /// Stream kind expected to dominate the generated access mix
    /// (asserted by the scenario smoke tests).
    pub dominant: StreamKind,
    build: fn(u64) -> GeneratorConfig,
    kind: Kind,
}

impl Scenario {
    /// Deterministic generator config for this scenario and seed.
    pub fn config(&self, seed: u64) -> GeneratorConfig {
        (self.build)(seed)
    }

    /// Ready-to-run workload for this scenario and seed.
    pub fn workload(&self, seed: u64) -> Box<dyn Workload> {
        self.workload_from(self.config(seed))
    }

    /// Build the workload from an already-resolved generator config (the
    /// experiment config path, where profile/seed overrides have been
    /// applied).
    pub(crate) fn workload_from(&self, g: GeneratorConfig) -> Box<dyn Workload> {
        match self.kind {
            Kind::Generator => Box::new(TraceGenerator::new(g)),
            Kind::OpenLoop => {
                let ol = OpenLoopConfig::bursty_batch(g.seed);
                Box::new(OpenLoopWorkload::new(
                    Box::new(TraceGenerator::new(g)),
                    ol,
                    Some(self.name),
                ))
            }
            Kind::Population => Box::new(PopulationWorkload::with_name(
                PopulationConfig::prefix_share(g.seed),
                self.name,
            )),
        }
    }

    /// True for scenarios whose workload already models traffic shape
    /// (open-loop arrivals or a tenant population) — a spec-level `traffic`
    /// block cannot stack on top of these.
    pub(crate) fn is_traffic(&self) -> bool {
        !matches!(self.kind, Kind::Generator)
    }

    /// Registry lookup.
    pub fn by_name(name: &str) -> Option<&'static Scenario> {
        SCENARIOS.iter().find(|s| s.name == name)
    }

    /// All registered scenarios, in registry order.
    pub fn all() -> &'static [Scenario] {
        SCENARIOS
    }
}

impl std::fmt::Debug for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Scenario")
            .field("name", &self.name)
            .field("dominant", &self.dominant)
            .finish()
    }
}

/// Names of all registered scenarios (CLI help / sweep default grid).
pub const SCENARIO_NAMES: &[&str] = &[
    "decode-heavy",
    "prefill-burst",
    "rag-embedding",
    "long-context",
    "multi-tenant-mix",
    "speculative-decode",
    "prefix-share",
    "bursty-batch",
];

static SCENARIOS: &[Scenario] = &[
    Scenario {
        name: "decode-heavy",
        summary: "autoregressive decode over a GPT-style profile (paper's Table 1 workload)",
        dominant: StreamKind::Weight,
        build: decode_heavy,
        kind: Kind::Generator,
    },
    Scenario {
        name: "prefill-burst",
        summary: "bursty arrivals in the MMPP hot state; long prompts make prefill KV writes dominate",
        dominant: StreamKind::KvWrite,
        build: prefill_burst,
        kind: Kind::Generator,
    },
    Scenario {
        name: "rag-embedding",
        summary: "retrieval-style lookups over a huge flat-tailed embedding table",
        dominant: StreamKind::Embedding,
        build: rag_embedding,
        kind: Kind::Generator,
    },
    Scenario {
        name: "long-context",
        summary: "contexts far beyond the attention window; KV re-reads dominate",
        dominant: StreamKind::KvRead,
        build: long_context,
        kind: Kind::Generator,
    },
    Scenario {
        name: "multi-tenant-mix",
        summary: "many interleaved tenant sessions with fast phase drift",
        dominant: StreamKind::Weight,
        build: multi_tenant_mix,
        kind: Kind::Generator,
    },
    Scenario {
        name: "speculative-decode",
        summary: "draft/verify interleave: verify passes re-read the drafted KV window in bulk",
        dominant: StreamKind::KvRead,
        build: speculative_decode,
        kind: Kind::Generator,
    },
    Scenario {
        name: "prefix-share",
        summary: "tenant population with churn, Zipf footprints, and a shared system-prompt prefix block",
        dominant: StreamKind::KvRead,
        build: prefix_share,
        kind: Kind::Population,
    },
    Scenario {
        name: "bursty-batch",
        summary: "open-loop on/off (MMPP) arrivals over the decode mix; bounded admission queue, shed on overload",
        dominant: StreamKind::Weight,
        build: bursty_batch,
        kind: Kind::OpenLoop,
    },
];

/// The paper's primary regime: the stock GPT-style decode mix. Per decoded
/// token the per-layer weight-tile scans dominate (the scanning pattern
/// that thrashes LRU and motivates RRIP-style policies).
fn decode_heavy(seed: u64) -> GeneratorConfig {
    let mut p = ModelProfile::gpt3ish();
    p.name = "decode-heavy".into();
    GeneratorConfig::new(p, seed)
}

/// Prefill-dominated arbitration stress (cf. LLaMCAT's mixed prefill/decode
/// traffic): the MMPP sits mostly in its hot state, prompts are long and
/// generations short, so batched prefill KV-append bursts are the majority
/// stream and weight scans are long but infrequent.
fn prefill_burst(seed: u64) -> GeneratorConfig {
    let mut p = ModelProfile::gpt3ish();
    p.name = "prefill-burst".into();
    p.layers = 16;
    p.kv_reads_per_token = 4;
    p.weight_tiles_hot = 4;
    p.scratch_lines_per_token = 2;
    p.prompt_len_mean = 240.0;
    p.gen_len_mean = 6.0;
    let mut c = GeneratorConfig::new(p, seed);
    c.max_live_sessions = 16;
    c.arrival_p_hot = 0.6;
    c.arrival_p_cold = 0.25;
    c.burst_switch_p = 0.002;
    c.weight_lines_per_tile = 4;
    c
}

/// Embedding-retrieval regime (cf. recency/frequency-adaptive KV caching:
/// policy rankings flip under KV-reuse skew): wide rows of a much larger,
/// flatter-tailed table are read per lookup, shallow model, tiny KV
/// traffic. Majority-embedding traffic with a long polluting tail.
fn rag_embedding(seed: u64) -> GeneratorConfig {
    let p = ModelProfile {
        name: "rag-embedding".into(),
        vocab: 200_000,
        embed_row_bytes: 1024,
        embed_lines_per_lookup: 12,
        zipf_theta: 0.7,
        layers: 2,
        kv_bytes_per_token: 64,
        attn_window: 16,
        kv_reads_per_token: 2,
        kv_longrange_p: 0.02,
        weight_tiles_per_layer: 32,
        weight_tile_bytes: 4096,
        weight_tiles_hot: 2,
        scratch_lines_per_token: 1,
        prompt_len_mean: 12.0,
        gen_len_mean: 24.0,
    };
    let mut c = GeneratorConfig::new(p, seed);
    c.max_ctx = 256;
    c.weight_lines_per_tile = 1;
    c
}

/// Long-context serving: the KV working set per session vastly exceeds the
/// attention window, with a high long-range read probability — the heavy
/// KV re-read pattern whose lines look dead to recency policies but are
/// provably re-read.
fn long_context(seed: u64) -> GeneratorConfig {
    let mut p = ModelProfile::gpt3ish();
    p.name = "long-context".into();
    p.attn_window = 24;
    p.kv_reads_per_token = 24;
    p.kv_longrange_p = 0.3;
    p.weight_tiles_hot = 4;
    p.scratch_lines_per_token = 1;
    p.prompt_len_mean = 600.0;
    p.gen_len_mean = 256.0;
    let mut c = GeneratorConfig::new(p, seed);
    c.max_ctx = 2048;
    c.max_live_sessions = 8;
    c.weight_lines_per_tile = 1;
    c.phase_period = 40_000;
    c
}

/// Multi-tenant interleaving: many concurrent sessions over a LLaMA-style
/// profile with a short phase period, so each tenant's hot token set
/// drifts quickly and cross-session interleaving is maximal.
fn multi_tenant_mix(seed: u64) -> GeneratorConfig {
    let mut p = ModelProfile::llama2ish();
    p.name = "multi-tenant-mix".into();
    p.prompt_len_mean = 48.0;
    p.gen_len_mean = 48.0;
    let mut c = GeneratorConfig::new(p, seed);
    c.max_live_sessions = 24;
    c.phase_period = 4_000;
    c.arrival_p_hot = 0.5;
    c.arrival_p_cold = 0.05;
    c.burst_switch_p = 0.01;
    c
}

/// Speculative decoding: a small draft model proposes a block of tokens
/// and the big model verifies them in one pass. The memory signature is a
/// draft/verify interleave — per accepted token the verifier re-reads the
/// *whole* drafted KV window across all of its (deep) layers, while its
/// weight scans amortize over the verified block (few hot tiles per
/// token). Verify-burst KV reads dominate; acceptance-rate phases rotate
/// the Zipf head fairly quickly.
fn speculative_decode(seed: u64) -> GeneratorConfig {
    let mut p = ModelProfile::gpt3ish();
    p.name = "speculative-decode".into();
    p.layers = 24; // the big verifier model
    p.attn_window = 48;
    p.kv_reads_per_token = 10; // bulk verify re-reads of the draft block
    p.kv_longrange_p = 0.05;
    p.weight_tiles_hot = 2; // amortized over the verified block
    p.scratch_lines_per_token = 2; // draft logits + acceptance bookkeeping
    p.prompt_len_mean = 32.0;
    p.gen_len_mean = 96.0; // speculation stretches generations
    let mut c = GeneratorConfig::new(p, seed);
    c.max_live_sessions = 12;
    c.weight_lines_per_tile = 1;
    c.phase_period = 12_000; // acceptance-rate phases
    c
}

/// Prefix-cache sharing across a churning tenant population (ROADMAP's
/// oldest unclaimed scenario): the profile here only names the regime —
/// [`PopulationWorkload`] synthesizes the stream itself, every session
/// prefilling through one shared system-prompt block before decoding over
/// its tenant's private Zipf footprint.
fn prefix_share(seed: u64) -> GeneratorConfig {
    let mut p = ModelProfile::gpt3ish();
    p.name = "prefix-share".into();
    GeneratorConfig::new(p, seed)
}

/// Open-loop overload stress: the stock decode mix served from a bounded
/// admission queue fed by an on/off burst process whose hot state offers
/// well above service capacity. Autonomous generator arrivals are disabled
/// — every admission flows through the queue so offered, shed, and queue
/// delay are measurable.
fn bursty_batch(seed: u64) -> GeneratorConfig {
    let mut p = ModelProfile::gpt3ish();
    p.name = "bursty-batch".into();
    let mut c = GeneratorConfig::new(p, seed);
    c.arrival_p_hot = 0.0;
    c.arrival_p_cold = 0.0;
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_complete_and_resolvable() {
        assert_eq!(SCENARIO_NAMES.len(), Scenario::all().len());
        for name in SCENARIO_NAMES {
            let sc = Scenario::by_name(name).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(sc.name, *name);
            assert!(!sc.summary.is_empty());
        }
        assert!(Scenario::by_name("nope").is_none());
    }

    #[test]
    fn configs_are_seed_deterministic() {
        for sc in Scenario::all() {
            let a = sc.workload(42).generate(2_000);
            let b = sc.workload(42).generate(2_000);
            let c = sc.workload(43).generate(2_000);
            assert_eq!(a, b, "{}", sc.name);
            assert_ne!(a, c, "{}", sc.name);
        }
    }

    #[test]
    fn scenario_names_stamp_the_workload() {
        for sc in Scenario::all() {
            assert_eq!(sc.config(1).profile.name, sc.name);
            assert_eq!(sc.workload(1).name(), sc.name);
        }
    }

    #[test]
    fn traffic_scenarios_report_their_nature() {
        let mut w = Scenario::by_name("bursty-batch").unwrap().workload(9);
        let _ = w.generate(30_000);
        let t = w.traffic().expect("open-loop scenario reports traffic");
        assert!(t.offered > 0, "{t:?}");
        assert!(t.admitted > 0, "{t:?}");
        let w2 = Scenario::by_name("prefix-share").unwrap().workload(9);
        assert!(w2.traffic().is_none(), "population workload is closed-loop");
    }
}
