//! Trace characterization: stream mix, line-level reuse-distance profile,
//! burstiness. Used by `acpc trace-stats`, by tests that validate the
//! generator actually produces the irregular/bursty patterns the paper
//! describes, and by EXPERIMENTS.md workload documentation.

use super::file::TraceRecord;
use super::{Access, StreamKind};
use crate::util::stats::{cv, Histogram};
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct TraceStats {
    pub accesses: usize,
    pub unique_lines: usize,
    pub per_stream: Vec<(StreamKind, usize)>,
    /// Reuse distance (unique-lines-between-reuses) histogram, log2 buckets
    /// in `[2^0, 2^20)`, plus cold (first-touch) count.
    pub reuse_hist: Histogram,
    pub cold_misses: usize,
    /// Fraction of lines touched exactly once (one-shot / pollution bait).
    pub one_shot_frac: f64,
    /// Coefficient of variation of inter-access times per session (>1 = bursty).
    pub session_burstiness_cv: f64,
    pub write_frac: f64,
}

/// Compute stats with an exact (hash-set stack distance via ordered set
/// approximation) reuse-distance pass. We use the *temporal* reuse distance
/// (accesses since last touch) rather than full stack distance for O(n).
pub fn analyze(trace: &[Access]) -> TraceStats {
    let mut last_touch: HashMap<u64, usize> = HashMap::new();
    let mut touch_count: HashMap<u64, u32> = HashMap::new();
    let mut reuse_hist = Histogram::new(0.0, 20.0, 20); // log2 buckets
    let mut cold = 0usize;
    let mut per_stream: HashMap<StreamKind, usize> = HashMap::new();
    let mut session_times: HashMap<u32, Vec<f64>> = HashMap::new();
    let mut writes = 0usize;

    for (i, a) in trace.iter().enumerate() {
        *per_stream.entry(a.kind).or_default() += 1;
        if a.is_write {
            writes += 1;
        }
        let line = a.line();
        match last_touch.insert(line, i) {
            Some(prev) => {
                let d = (i - prev) as f64;
                reuse_hist.push(d.log2().max(0.0));
            }
            None => cold += 1,
        }
        *touch_count.entry(line).or_default() += 1;
        session_times.entry(a.session).or_default().push(a.time as f64);
    }

    let one_shot = touch_count.values().filter(|&&c| c == 1).count();
    // Burstiness: CV of inter-access gaps within each session, averaged.
    let mut cvs = Vec::new();
    for times in session_times.values() {
        if times.len() > 16 {
            let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
            let c = cv(&gaps);
            if c.is_finite() {
                cvs.push(c);
            }
        }
    }
    let burst = if cvs.is_empty() { f64::NAN } else { cvs.iter().sum::<f64>() / cvs.len() as f64 };

    let mut per_stream: Vec<(StreamKind, usize)> = per_stream.into_iter().collect();
    per_stream.sort_by_key(|(k, _)| *k as u8);

    TraceStats {
        accesses: trace.len(),
        unique_lines: touch_count.len(),
        per_stream,
        reuse_hist,
        cold_misses: cold,
        one_shot_frac: one_shot as f64 / touch_count.len().max(1) as f64,
        session_burstiness_cv: burst,
        write_frac: writes as f64 / trace.len().max(1) as f64,
    }
}

impl TraceStats {
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "accesses={} unique_lines={} cold={} one_shot={:.1}% writes={:.1}% burstiness_cv={:.2}\n",
            self.accesses,
            self.unique_lines,
            self.cold_misses,
            self.one_shot_frac * 100.0,
            self.write_frac * 100.0,
            self.session_burstiness_cv
        ));
        s.push_str("stream mix: ");
        for (k, c) in &self.per_stream {
            s.push_str(&format!("{}={:.1}% ", k.label(), *c as f64 / self.accesses as f64 * 100.0));
        }
        s.push('\n');
        s.push_str("reuse-distance log2 histogram: ");
        for (i, b) in self.reuse_hist.buckets().iter().enumerate() {
            if *b > 0 {
                s.push_str(&format!("2^{i}:{b} "));
            }
        }
        s.push('\n');
        s
    }
}

/// Per-tenant footprint breakdown of a v2 capture (`acpc trace-stats` on a
/// `--capture` file). Tenants are whatever the capturing side stamped —
/// worker indices for serve captures, population tenant ids for synthetic
/// multi-tenant traces.
#[derive(Debug, Clone)]
pub struct TenantBreakdown {
    /// `(tenant, accesses, unique_lines)` sorted by accesses descending
    /// (ties broken by tenant id for determinism).
    pub tenants: Vec<(u32, usize, usize)>,
    /// Share of all accesses owned by the top 3 tenants (1.0 when ≤3).
    pub top3_share: f64,
    /// Coefficient of variation of per-tenant access counts — 0 for a
    /// perfectly balanced population, ≫0 for a skewed one.
    pub footprint_skew_cv: f64,
}

/// Group a v2 record stream by tenant. Cheap single pass; callers already
/// hold the records in memory for [`analyze`].
pub fn analyze_tenants(records: &[TraceRecord]) -> TenantBreakdown {
    let mut acc: HashMap<u32, usize> = HashMap::new();
    let mut lines: HashMap<u32, std::collections::HashSet<u64>> = HashMap::new();
    for r in records {
        *acc.entry(r.tenant).or_default() += 1;
        lines.entry(r.tenant).or_default().insert(r.access.line());
    }
    let mut tenants: Vec<(u32, usize, usize)> = acc
        .iter()
        .map(|(&t, &n)| (t, n, lines.get(&t).map(|s| s.len()).unwrap_or(0)))
        .collect();
    tenants.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let total: usize = tenants.iter().map(|t| t.1).sum();
    let top3: usize = tenants.iter().take(3).map(|t| t.1).sum();
    let counts: Vec<f64> = tenants.iter().map(|t| t.1 as f64).collect();
    let skew = if counts.len() > 1 { cv(&counts) } else { 0.0 };
    TenantBreakdown {
        tenants,
        top3_share: top3 as f64 / total.max(1) as f64,
        footprint_skew_cv: if skew.is_finite() { skew } else { 0.0 },
    }
}

impl TenantBreakdown {
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "tenants={} top3_share={:.1}% footprint_skew_cv={:.2}\n",
            self.tenants.len(),
            self.top3_share * 100.0,
            self.footprint_skew_cv
        ));
        let total: usize = self.tenants.iter().map(|t| t.1).sum();
        for (tenant, accesses, unique) in self.tenants.iter().take(8) {
            s.push_str(&format!(
                "  tenant {tenant}: accesses={accesses} ({:.1}%) unique_lines={unique}\n",
                *accesses as f64 / total.max(1) as f64 * 100.0
            ));
        }
        if self.tenants.len() > 8 {
            s.push_str(&format!("  … {} more tenants\n", self.tenants.len() - 8));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    #[test]
    fn trace_is_bursty_and_irregular() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(31)).generate(100_000);
        let st = analyze(&trace);
        assert_eq!(st.accesses, 100_000);
        assert!(st.unique_lines > 500);
        // The paper's premise: mixed reuse distances (irregular), a real
        // one-shot population (pollution bait), and bursty sessions.
        assert!(st.one_shot_frac > 0.05, "one-shot {:.3}", st.one_shot_frac);
        assert!(st.session_burstiness_cv > 1.0, "cv {:.2}", st.session_burstiness_cv);
        assert!(st.reuse_hist.count() > 0);
        let rep = st.report();
        assert!(rep.contains("stream mix"));
    }

    #[test]
    fn tenant_breakdown_ranks_by_footprint() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(7)).generate(3_000);
        // Tenant 0 gets 2x the accesses of tenants 1 and 2.
        let records: Vec<TraceRecord> = trace
            .iter()
            .enumerate()
            .map(|(i, &access)| TraceRecord {
                access,
                tenant: match i % 4 {
                    0 => 1,
                    1 => 2,
                    _ => 0,
                },
                arrival: i as u64,
            })
            .collect();
        let tb = analyze_tenants(&records);
        assert_eq!(tb.tenants.len(), 3);
        assert_eq!(tb.tenants[0].0, 0, "heaviest tenant first");
        assert_eq!(tb.tenants[0].1, 1_500);
        assert!((tb.top3_share - 1.0).abs() < 1e-12);
        assert!(tb.footprint_skew_cv > 0.0);
        let rep = tb.report();
        assert!(rep.contains("tenants=3"), "{rep}");
        assert!(rep.contains("tenant 0:"), "{rep}");
    }
}
