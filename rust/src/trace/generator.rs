//! Synthetic LLM-inference access-trace generator (substitution for the
//! paper's unreleased 2.3B-record profiling dataset; see DESIGN.md §3).
//!
//! The generator simulates a serving node: sessions arrive in bursts (a
//! two-state MMPP), each session runs prefill (prompt KV writes) and then
//! autoregressive decode. Every decoded token emits the memory streams a
//! transformer actually touches — embedding rows, per-layer weight-tile
//! scans, attention-window KV reads (plus rare long-range reads), a KV
//! append, and activation scratch. Token popularity is Zipfian with a
//! rotating head ("phase drift") so reuse statistics are non-stationary.

use super::profile::ModelProfile;
use super::{region, Access, StreamKind};
use crate::util::rng::{Xoshiro256, Zipf};
use std::collections::VecDeque;

/// Line size is fixed at 64 B across the project.
pub const LINE: u64 = 64;

/// Generator configuration. All randomness derives from `seed`.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    pub profile: ModelProfile,
    pub seed: u64,
    /// Maximum concurrently-live sessions (KV slot count).
    pub max_live_sessions: usize,
    /// MMPP arrival probabilities per decode step (hot/cold states).
    pub arrival_p_hot: f64,
    pub arrival_p_cold: f64,
    /// Per-step probability of switching MMPP state.
    pub burst_switch_p: f64,
    /// Tokens between Zipf-head rotations (0 disables phase drift).
    pub phase_period: u64,
    /// Maximum context length (KV slot capacity in tokens).
    pub max_ctx: u32,
    /// Lines emitted per weight tile scan (the L2-visible residue of a
    /// tile after L1 filtering).
    pub weight_lines_per_tile: u64,
    /// Scratch ring size in lines (large ⇒ scratch lines are ~never reused).
    pub scratch_ring_lines: u64,
}

impl GeneratorConfig {
    pub fn new(profile: ModelProfile, seed: u64) -> Self {
        Self {
            profile,
            seed,
            max_live_sessions: 10,
            arrival_p_hot: 0.25,
            arrival_p_cold: 0.02,
            burst_switch_p: 0.004,
            phase_period: 20_000,
            max_ctx: 512,
            weight_lines_per_tile: 2,
            scratch_ring_lines: 1 << 16,
        }
    }

    /// Small config for unit tests.
    pub fn tiny(seed: u64) -> Self {
        let mut p = ModelProfile::gpt3ish();
        p.layers = 4;
        p.weight_tiles_per_layer = 16;
        p.weight_tiles_hot = 6;
        p.prompt_len_mean = 8.0;
        p.gen_len_mean = 16.0;
        let mut c = Self::new(p, seed);
        c.max_live_sessions = 4;
        c.max_ctx = 64;
        c
    }
}

#[derive(Debug, Clone)]
struct Session {
    id: u32,
    slot: usize,
    ctx_len: u32,
    tokens_left: u32,
}

/// Streaming trace generator. `next_access` yields one access at a time;
/// `generate(n)` collects a vector. Deterministic for a given config.
pub struct TraceGenerator {
    cfg: GeneratorConfig,
    rng: Xoshiro256,
    zipf: Zipf,
    time: u64,
    phase: u64,
    tokens_done: u64,
    sessions_started: u32,
    sessions_completed: u64,
    live: Vec<Session>,
    free_slots: Vec<usize>,
    burst_hot: bool,
    scratch_head: u64,
    /// Accesses already produced for the in-flight token / prefill.
    pending: VecDeque<Access>,
    /// Per-slot-layer KV region stride.
    kv_layer_bytes: u64,
    kv_slot_bytes: u64,
}

impl TraceGenerator {
    pub fn new(cfg: GeneratorConfig) -> Self {
        let rng = Xoshiro256::new(cfg.seed);
        let zipf = Zipf::new(cfg.profile.vocab, cfg.profile.zipf_theta);
        let kv_layer_bytes = cfg.max_ctx as u64 * cfg.profile.kv_bytes_per_token;
        let kv_slot_bytes = kv_layer_bytes * cfg.profile.layers as u64;
        let free_slots = (0..cfg.max_live_sessions).rev().collect();
        Self {
            cfg,
            rng,
            zipf,
            time: 0,
            phase: 0,
            tokens_done: 0,
            sessions_started: 0,
            sessions_completed: 0,
            live: Vec::new(),
            free_slots,
            burst_hot: false,
            scratch_head: 0,
            pending: VecDeque::new(),
            kv_layer_bytes,
            kv_slot_bytes,
        }
    }

    pub fn tokens_done(&self) -> u64 {
        self.tokens_done
    }

    /// The effective configuration (scenarios stamp their name into
    /// `profile.name`, so this also identifies the workload).
    pub fn config(&self) -> &GeneratorConfig {
        &self.cfg
    }

    pub fn profile_name(&self) -> &str {
        &self.cfg.profile.name
    }

    pub fn sessions_completed(&self) -> u64 {
        self.sessions_completed
    }

    pub fn live_sessions(&self) -> usize {
        self.live.len()
    }

    pub fn free_slots(&self) -> usize {
        self.free_slots.len()
    }

    /// True when a `next_access` call will produce session-driven work
    /// without needing an autonomous arrival (serving mode drains on this).
    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.live.is_empty()
    }

    // ---- address helpers -------------------------------------------------

    fn embed_addr(&self, token_id: u64, line_idx: u64) -> u64 {
        region::EMBED + token_id * self.cfg.profile.embed_row_bytes + line_idx * LINE
    }

    fn kv_addr(&self, slot: usize, layer: u16, pos: u32) -> u64 {
        region::KV
            + slot as u64 * self.kv_slot_bytes
            + layer as u64 * self.kv_layer_bytes
            + pos as u64 * self.cfg.profile.kv_bytes_per_token
    }

    fn weight_addr(&self, layer: u16, tile: u64, line_idx: u64) -> u64 {
        region::WEIGHT
            + layer as u64 * self.cfg.profile.weight_tiles_per_layer * self.cfg.profile.weight_tile_bytes
            + tile * self.cfg.profile.weight_tile_bytes
            + line_idx * LINE * 8 // spread emitted lines across the tile
    }

    fn scratch_addr(&mut self) -> u64 {
        let a = region::SCRATCH + (self.scratch_head % self.cfg.scratch_ring_lines) * LINE;
        self.scratch_head += 1;
        a
    }

    fn pc(kind: StreamKind, layer: u16, site: u32) -> u64 {
        ((kind as u64) << 32) | ((layer as u64) << 16) | site as u64
    }

    /// Zipf rank → token id with phase rotation (the head of the
    /// distribution moves every `phase_period` tokens).
    fn sample_token(&mut self) -> u64 {
        let rank = self.zipf.sample(&mut self.rng);
        (rank + self.phase * 9973) % self.cfg.profile.vocab
    }

    // ---- event production --------------------------------------------------

    fn push(&mut self, kind: StreamKind, addr: u64, pc: u64, sess: &Session, is_write: bool) {
        self.time += 1;
        self.pending.push_back(Access {
            time: self.time,
            addr,
            pc,
            kind,
            session: sess.id,
            ctx_len: sess.ctx_len,
            layer: ((pc >> 16) & 0xFFFF) as u16,
            is_write,
        });
    }

    fn maybe_arrive(&mut self) {
        if self.rng.chance(self.cfg.burst_switch_p) {
            self.burst_hot = !self.burst_hot;
        }
        let p = if self.burst_hot { self.cfg.arrival_p_hot } else { self.cfg.arrival_p_cold };
        if self.rng.chance(p) {
            self.force_arrival();
        }
    }

    /// Externally-driven session admission (the serving coordinator's
    /// router calls this; `arrival_p_* = 0` turns off autonomous arrivals).
    /// Returns false when all KV slots are occupied.
    pub fn force_arrival(&mut self) -> bool {
        if !self.free_slots.is_empty() {
            let slot = self.free_slots.pop().unwrap();
            let id = self.sessions_started;
            self.sessions_started += 1;
            let prof = &self.cfg.profile;
            let prompt =
                (self.rng.next_exp(1.0 / prof.prompt_len_mean).round() as u32).clamp(4, self.cfg.max_ctx / 2);
            let gen = (self.rng.next_exp(1.0 / prof.gen_len_mean).round() as u32)
                .clamp(4, self.cfg.max_ctx - prompt - 1);
            let mut sess = Session { id, slot, ctx_len: 0, tokens_left: gen };
            // Prefill: batched KV writes for the prompt (a real write burst),
            // plus one embedding lookup per prompt token.
            for pos in 0..prompt {
                sess.ctx_len = pos;
                let tok = self.sample_token();
                for li in 0..self.cfg.profile.embed_lines_per_lookup {
                    let a = self.embed_addr(tok, li);
                    self.push(StreamKind::Embedding, a, Self::pc(StreamKind::Embedding, 0, 1), &sess, false);
                }
                for layer in 0..self.cfg.profile.layers {
                    let a = self.kv_addr(slot, layer, pos);
                    self.push(StreamKind::KvWrite, a, Self::pc(StreamKind::KvWrite, layer, 2), &sess, true);
                }
            }
            sess.ctx_len = prompt;
            self.live.push(sess);
            true
        } else {
            false
        }
    }

    /// Emit all accesses for one decoded token of session index `si`.
    fn decode_token(&mut self, si: usize) {
        let sess = self.live[si].clone();
        let prof = self.cfg.profile.clone();
        let slot = sess.slot;

        // 1. Input embedding lookup.
        let tok = self.sample_token();
        for li in 0..prof.embed_lines_per_lookup {
            let a = self.embed_addr(tok, li);
            self.push(StreamKind::Embedding, a, Self::pc(StreamKind::Embedding, 0, 1), &sess, false);
        }

        // 2. Per-layer work.
        for layer in 0..prof.layers {
            // 2a. Weight tile scan — cyclic subset, deterministic stride, so
            // the same lines recur each token (a scanning/streaming pattern).
            let base_tile = (self.tokens_done % prof.weight_tiles_per_layer.max(1)) as u64;
            for t in 0..prof.weight_tiles_hot {
                let tile = (base_tile + t) % prof.weight_tiles_per_layer;
                for li in 0..self.cfg.weight_lines_per_tile {
                    let a = self.weight_addr(layer, tile, li);
                    self.push(StreamKind::Weight, a, Self::pc(StreamKind::Weight, layer, 3), &sess, false);
                }
            }

            // 2b. KV reads — attention window sample + rare long-range reads.
            let ctx = sess.ctx_len;
            if ctx > 0 {
                let w = prof.attn_window.min(ctx);
                for _ in 0..prof.kv_reads_per_token {
                    let pos = if ctx > w && self.rng.chance(prof.kv_longrange_p) {
                        self.rng.gen_range((ctx - w) as u64) as u32
                    } else {
                        ctx - 1 - self.rng.gen_range(w as u64) as u32
                    };
                    let a = self.kv_addr(slot, layer, pos);
                    self.push(StreamKind::KvRead, a, Self::pc(StreamKind::KvRead, layer, 4), &sess, false);
                }
            }

            // 2c. KV append for this token.
            let a = self.kv_addr(slot, layer, sess.ctx_len);
            self.push(StreamKind::KvWrite, a, Self::pc(StreamKind::KvWrite, layer, 2), &sess, true);

            // 2d. Scratch traffic.
            for _ in 0..prof.scratch_lines_per_token {
                let a = self.scratch_addr();
                self.push(StreamKind::Scratch, a, Self::pc(StreamKind::Scratch, layer, 5), &sess, true);
            }
        }

        // 3. Output embedding (logit head row for the produced token).
        let out_tok = self.sample_token();
        let a = self.embed_addr(out_tok, 0);
        self.push(StreamKind::Embedding, a, Self::pc(StreamKind::Embedding, prof.layers, 6), &sess, false);

        // Book-keeping.
        self.tokens_done += 1;
        if self.cfg.phase_period > 0 && self.tokens_done % self.cfg.phase_period == 0 {
            self.phase += 1;
        }
        let s = &mut self.live[si];
        s.ctx_len = (s.ctx_len + 1).min(self.cfg.max_ctx - 1);
        s.tokens_left -= 1;
        if s.tokens_left == 0 {
            let done = self.live.swap_remove(si);
            self.free_slots.push(done.slot);
            self.sessions_completed += 1;
        }
    }

    /// Advance the serving loop until at least one access is pending.
    fn refill(&mut self) {
        let mut guard = 0;
        while self.pending.is_empty() {
            self.maybe_arrive();
            if self.live.is_empty() {
                // Force an arrival so the stream never stalls.
                self.burst_hot = true;
                guard += 1;
                if guard > 10_000 {
                    // Pathological config (no slots) — emit scratch filler.
                    let dummy = Session { id: u32::MAX, slot: 0, ctx_len: 0, tokens_left: 1 };
                    let a = self.scratch_addr();
                    self.push(StreamKind::Scratch, a, Self::pc(StreamKind::Scratch, 0, 5), &dummy, true);
                    return;
                }
                continue;
            }
            let si = self.rng.range_usize(0, self.live.len());
            self.decode_token(si);
        }
    }

    pub fn next_access(&mut self) -> Access {
        if self.pending.is_empty() {
            self.refill();
        }
        self.pending.pop_front().expect("refill produced no access")
    }

    /// Collect `n` accesses.
    pub fn generate(&mut self, n: usize) -> Vec<Access> {
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.next_access());
        }
        v
    }
}

impl Iterator for TraceGenerator {
    type Item = Access;

    fn next(&mut self) -> Option<Access> {
        Some(self.next_access())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<Access> = TraceGenerator::new(GeneratorConfig::tiny(7)).generate(5_000);
        let b: Vec<Access> = TraceGenerator::new(GeneratorConfig::tiny(7)).generate(5_000);
        let c: Vec<Access> = TraceGenerator::new(GeneratorConfig::tiny(8)).generate(5_000);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn time_monotonic_and_all_streams_present() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(1)).generate(20_000);
        let mut counts: HashMap<StreamKind, usize> = HashMap::new();
        let mut last = 0;
        for a in &trace {
            assert!(a.time > last, "time must strictly increase");
            last = a.time;
            *counts.entry(a.kind).or_default() += 1;
        }
        for k in StreamKind::ALL {
            assert!(counts.get(&k).copied().unwrap_or(0) > 0, "missing stream {k:?}");
        }
        // Weights dominate (per-layer scans), scratch nontrivial.
        assert!(counts[&StreamKind::Weight] > counts[&StreamKind::Embedding]);
    }

    #[test]
    fn addresses_stay_in_their_regions() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(3)).generate(20_000);
        for a in &trace {
            let want = match a.kind {
                StreamKind::Embedding => region::of(region::EMBED),
                StreamKind::KvRead | StreamKind::KvWrite => region::of(region::KV),
                StreamKind::Weight => region::of(region::WEIGHT),
                StreamKind::Scratch => region::of(region::SCRATCH),
            };
            assert_eq!(region::of(a.addr), want, "{a:?}");
        }
    }

    #[test]
    fn kv_reads_concentrate_in_window() {
        let cfg = GeneratorConfig::tiny(11);
        let window = cfg.profile.attn_window;
        let kv_per_tok = cfg.profile.kv_bytes_per_token;
        let gen = TraceGenerator::new(cfg);
        let mut in_window = 0usize;
        let mut total = 0usize;
        let mut g = gen;
        for _ in 0..50_000 {
            let a = g.next_access();
            if a.kind == StreamKind::KvRead && a.ctx_len > 0 {
                let layer_off = a.addr & ((g.kv_layer_bytes) - 1).next_power_of_two().wrapping_sub(1);
                let _ = layer_off;
                // Recover position from address arithmetic.
                let rel = (a.addr - region::KV) % g.kv_layer_bytes;
                let pos = (rel / kv_per_tok) as u32;
                total += 1;
                if a.ctx_len >= pos && a.ctx_len - pos <= window {
                    in_window += 1;
                }
            }
        }
        assert!(total > 100);
        let frac = in_window as f64 / total as f64;
        assert!(frac > 0.85, "in-window fraction {frac}");
    }

    #[test]
    fn sessions_cycle_and_slots_recycle() {
        let mut g = TraceGenerator::new(GeneratorConfig::tiny(5));
        let _ = g.generate(200_000);
        assert!(g.sessions_completed() > 5, "sessions completed {}", g.sessions_completed());
        assert!(g.live_sessions() <= 4);
        assert!(g.tokens_done() > 100);
    }

    #[test]
    fn embedding_reuse_is_zipf_skewed() {
        let mut g = TraceGenerator::new(GeneratorConfig::tiny(13));
        let mut line_counts: HashMap<u64, usize> = HashMap::new();
        for _ in 0..100_000 {
            let a = g.next_access();
            if a.kind == StreamKind::Embedding {
                *line_counts.entry(a.line()).or_default() += 1;
            }
        }
        let mut counts: Vec<usize> = line_counts.values().copied().collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = counts.iter().sum();
        let decile = counts.len() / 10 + 1;
        let top10: usize = counts.iter().take(decile).sum();
        let bot10: usize = counts.iter().rev().take(decile).sum();
        let top_frac = top10 as f64 / total as f64;
        assert!(top_frac > 0.25, "top-decile embedding lines should dominate: {top_frac}");
        assert!(top10 > bot10 * 3, "head/tail skew too weak: {top10} vs {bot10}");
    }
}
