//! Tiny argument parser: positionals + `--key value` + `--flag` booleans.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct Args {
    positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: usize,
}

impl Args {
    /// Parse raw argv (excluding the program name).
    pub fn new(argv: Vec<String>) -> Self {
        let mut positionals = Vec::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value =
                    it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                if next_is_value {
                    options.insert(key.to_string(), it.next().unwrap());
                } else {
                    flags.push(key.to_string());
                }
            } else {
                positionals.push(a);
            }
        }
        Self { positionals, options, flags, consumed: 0 }
    }

    pub fn next_positional(&mut self) -> Option<String> {
        let p = self.positionals.get(self.consumed).cloned();
        if p.is_some() {
            self.consumed += 1;
        }
        p
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Reject unknown option keys (call after reading all expected ones).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> Args {
        Args::new(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn parses_mixed() {
        // Note: `--flag value`-style ambiguity resolves toward options, so
        // boolean flags belong at the end (or before another `--` token).
        let mut a = mk("simulate --policy acpc --accesses 1000 next --verbose");
        assert_eq!(a.next_positional().as_deref(), Some("simulate"));
        assert_eq!(a.opt("policy"), Some("acpc"));
        assert_eq!(a.usize_or("accesses", 0).unwrap(), 1000);
        assert!(a.flag("verbose"));
        assert_eq!(a.next_positional().as_deref(), Some("next"));
        assert_eq!(a.next_positional(), None);
    }

    #[test]
    fn defaults_and_errors() {
        let a = mk("x --n abc");
        assert_eq!(a.usize_or("m", 7).unwrap(), 7);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn unknown_detection() {
        let a = mk("cmd --good 1 --bad 2");
        assert!(a.ensure_known(&["good"]).is_err());
        assert!(a.ensure_known(&["good", "bad"]).is_ok());
    }
}
