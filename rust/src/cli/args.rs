//! Tiny argument parser: positionals + `--key value` / `-k value` options
//! + `--flag` booleans.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct Args {
    positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: usize,
}

impl Args {
    /// Parse raw argv (excluding the program name).
    pub fn new(argv: Vec<String>) -> Self {
        let mut positionals = Vec::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        // Classify a token as an option key: `--key` long form, `-k`
        // single-letter short form, or `-k8` (attached value). Anything
        // else — including `-3` — is a plain value/positional, so a
        // negative-looking token after an option is still consumed as its
        // value and surfaces a loud parse error rather than vanishing.
        fn key_of(tok: &str) -> Option<(&str, Option<&str>)> {
            if let Some(k) = tok.strip_prefix("--") {
                return Some((k, None));
            }
            let k = tok.strip_prefix('-')?;
            if k.len() == 1 && k.chars().all(|c| c.is_ascii_alphabetic()) {
                Some((k, None))
            } else if k.len() > 1
                && k.starts_with(|c: char| c.is_ascii_alphabetic())
                && k[1..].chars().all(|c| c.is_ascii_digit())
            {
                Some((&k[..1], Some(&k[1..])))
            } else {
                None
            }
        }

        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            match key_of(&a) {
                Some((key, Some(value))) => {
                    options.insert(key.to_string(), value.to_string());
                }
                Some((key, None)) => {
                    let next_is_value = it.peek().map(|n| key_of(n).is_none()).unwrap_or(false);
                    if next_is_value {
                        options.insert(key.to_string(), it.next().unwrap());
                    } else {
                        flags.push(key.to_string());
                    }
                }
                None => positionals.push(a),
            }
        }
        Self { positionals, options, flags, consumed: 0 }
    }

    pub fn next_positional(&mut self) -> Option<String> {
        let p = self.positionals.get(self.consumed).cloned();
        if p.is_some() {
            self.consumed += 1;
        }
        p
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Reject unknown option keys (call after reading all expected ones).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> Args {
        Args::new(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn parses_mixed() {
        // Note: `--flag value`-style ambiguity resolves toward options, so
        // boolean flags belong at the end (or before another `--` token).
        let mut a = mk("simulate --policy acpc --accesses 1000 next --verbose");
        assert_eq!(a.next_positional().as_deref(), Some("simulate"));
        assert_eq!(a.opt("policy"), Some("acpc"));
        assert_eq!(a.usize_or("accesses", 0).unwrap(), 1000);
        assert!(a.flag("verbose"));
        assert_eq!(a.next_positional().as_deref(), Some("next"));
        assert_eq!(a.next_positional(), None);
    }

    #[test]
    fn short_options_parse() {
        let a = mk("sweep --policies lru,acpc -j 8 --scenarios all");
        assert_eq!(a.opt("policies"), Some("lru,acpc"));
        assert_eq!(a.usize_or("j", 1).unwrap(), 8);
        assert_eq!(a.opt("scenarios"), Some("all"));
        // Attached short-option value, make-style.
        let a = mk("sweep -j8");
        assert_eq!(a.usize_or("j", 1).unwrap(), 8);
        // A negative-looking token is consumed as the option's value and
        // surfaces a parse error, not silently dropped.
        let a = mk("x --seed -3");
        assert_eq!(a.opt("seed"), Some("-3"));
        assert!(a.u64_or("seed", 0).is_err());
        // A lone `-5` is a positional, not an option key.
        let mut b = mk("cmd -5");
        assert_eq!(b.next_positional().as_deref(), Some("cmd"));
        assert_eq!(b.next_positional().as_deref(), Some("-5"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = mk("x --n abc");
        assert_eq!(a.usize_or("m", 7).unwrap(), 7);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn unknown_detection() {
        let a = mk("cmd --good 1 --bad 2");
        assert!(a.ensure_known(&["good"]).is_err());
        assert!(a.ensure_known(&["good", "bad"]).is_ok());
    }
}
