//! Hand-rolled CLI (no `clap` in the offline registry): subcommands with
//! `--flag value` options, `--help` per subcommand, typo-hostile parsing.

mod args;
pub mod commands;

pub use args::Args;

use anyhow::Result;

pub const USAGE: &str = "\
acpc — Adaptive Cache Pollution Control for LLM inference workloads

USAGE:
    acpc <COMMAND> [OPTIONS]

COMMANDS:
    run          execute a RunSpec file or a --manifest of specs (cached farm)
    simulate     run one cache simulation (policy × predictor × workload)
    sweep        parallel policy×scenario experiment grid
    diff         compare two run reports, or gate on the perf trajectory
    adapt        closed-loop adaptation: controller ON vs OFF on one seed
    train        train a predictor with the compiled Adam step (Fig. 2)
    table1       reproduce the paper's Table 1 end-to-end
    serve        multi-worker serving-node simulation (router + batcher)
    monitor      live telemetry: wrap a RunSpec or attach to a serve dashboard
    store        report-store housekeeping (ls, gc)
    trace-stats  characterize a generated workload trace
    policies     list replacement policies / prefetchers / profiles / scenarios
    help         show this message

Run `acpc <COMMAND> --help` for per-command options.
Environment: ACPC_LOG=debug|info|warn|error, ACPC_ARTIFACTS=<dir>.";

/// Entry point used by `main.rs`.
pub fn run(argv: Vec<String>) -> Result<i32> {
    crate::util::log::init();
    let mut args = Args::new(argv);
    let cmd = match args.next_positional() {
        Some(c) => c,
        None => {
            println!("{USAGE}");
            return Ok(2);
        }
    };
    match cmd.as_str() {
        "run" => commands::run::run(&mut args),
        "simulate" => commands::simulate::run(&mut args),
        "sweep" => commands::sweep::run(&mut args),
        "diff" => commands::diff::run(&mut args),
        "adapt" => commands::adapt::run(&mut args),
        "train" => commands::train::run(&mut args),
        "table1" => commands::table1::run(&mut args),
        "serve" => commands::serve::run(&mut args),
        "monitor" => commands::monitor::run(&mut args),
        "store" => commands::store::run(&mut args),
        "trace-stats" => commands::trace_stats::run(&mut args),
        "policies" => commands::policies::run(),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => {
            crate::log_error!("unknown command '{other}'");
            println!("{USAGE}");
            Ok(2)
        }
    }
}
