//! `acpc store` — housekeeping for the content-addressed report store:
//! `ls` lists what's on disk, `gc` reclaims entries older than a cutoff
//! (dry run by default; `--apply` deletes).

use crate::api::ReportStore;
use crate::cli::Args;
use crate::util::bench::print_table;
use anyhow::Result;

const HELP: &str = "\
acpc store — inspect / garbage-collect the report store

USAGE:
    acpc store ls [--store <dir>]
    acpc store gc [--keep-days <n>] [--apply] [--store <dir>]

`gc` without --apply is a dry run: it lists what would be deleted and
touches nothing.

OPTIONS:
    --store <dir>       store root [default: $ACPC_STORE or .acpc-store]
    --keep-days <n>     gc cutoff: drop entries older than n days [default: 30]
    --apply             actually delete (gc defaults to a dry run)
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    let Some(action) = args.next_positional() else {
        println!("{HELP}");
        return Ok(2);
    };
    args.ensure_known(&["store", "keep-days", "apply", "help"])?;
    let store = match args.opt("store") {
        Some(p) => ReportStore::open(p),
        None => ReportStore::open_default(),
    };
    match action.as_str() {
        "ls" => ls(&store),
        "gc" => gc(&store, args.f64_or("keep-days", 30.0)?, args.flag("apply")),
        other => anyhow::bail!("unknown store action '{other}' (expected ls or gc)"),
    }
}

fn ls(store: &ReportStore) -> Result<i32> {
    let entries = store.entries();
    if entries.is_empty() {
        println!("report store {}: empty", store.root().display());
        return Ok(0);
    }
    let rows: Vec<Vec<String>> = entries
        .iter()
        .map(|e| {
            vec![
                e.hash[..12].to_string(),
                e.schema.clone(),
                e.label.clone(),
                format!("{:.1}", e.age_days),
                format!("{:.1}", e.bytes as f64 / 1024.0),
            ]
        })
        .collect();
    print_table(
        &format!("report store {}", store.root().display()),
        &["hash", "schema", "label", "age (days)", "size (KiB)"],
        &rows,
    );
    let total: u64 = entries.iter().map(|e| e.bytes).sum();
    println!("\n{} entries, {:.1} KiB total", entries.len(), total as f64 / 1024.0);
    Ok(0)
}

fn gc(store: &ReportStore, keep_days: f64, apply: bool) -> Result<i32> {
    let doomed = store.gc(keep_days, apply)?;
    let verb = if apply { "deleted" } else { "would delete" };
    for e in &doomed {
        println!("{verb} {} ({}, {:.1} days old)", &e.hash[..12], e.label, e.age_days);
    }
    println!(
        "gc --keep-days {keep_days}: {verb} {} of {} entries{}",
        doomed.len(),
        store.len() + if apply { doomed.len() } else { 0 },
        if apply { "" } else { " (dry run; pass --apply to delete)" }
    );
    Ok(0)
}
