//! CLI subcommand implementations.

pub mod adapt;
pub mod policies;
pub mod serve;
pub mod simulate;
pub mod sweep;
pub mod table1;
pub mod trace_stats;
pub mod train;

use crate::config::PredictorKind;
use crate::predictor::{HeuristicPredictor, ModelRuntime, PredictorBox};
use anyhow::Result;

/// Build a predictor box for a kind, loading the model from the AOT
/// artifacts when needed.
pub fn build_predictor(kind: PredictorKind, model_override: Option<&str>) -> Result<PredictorBox> {
    match kind {
        PredictorKind::None => Ok(PredictorBox::None),
        PredictorKind::Heuristic => Ok(PredictorBox::Heuristic(HeuristicPredictor)),
        PredictorKind::Dnn | PredictorKind::Tcn => {
            let name = model_override.unwrap_or(match kind {
                PredictorKind::Dnn => "dnn",
                _ => "tcn",
            });
            let rt = ModelRuntime::load_from_artifacts(name)?;
            Ok(PredictorBox::Model(Box::new(rt)))
        }
    }
}

/// [`build_predictor`] with the sharded-run fallback policy: learned
/// predictors are loaded *inside* each shard thread (PJRT handles are
/// thread-affine), and a load failure there degrades to the heuristic with
/// a warning instead of aborting the whole run mid-flight. `ctx` names the
/// command for the log line.
pub fn build_predictor_or_heuristic(
    kind: PredictorKind,
    model_override: Option<&str>,
    ctx: &str,
) -> PredictorBox {
    build_predictor(kind, model_override).unwrap_or_else(|e| {
        crate::log_warn!(
            "{ctx}: predictor load failed in a shard thread ({e}); falling back to the \
             heuristic predictor"
        );
        PredictorBox::Heuristic(HeuristicPredictor)
    })
}

/// ASCII plot of a loss curve (y auto-scaled), for terminal-friendly Fig 2.
pub fn ascii_plot(curve: &[f64], width: usize, height: usize) -> String {
    if curve.is_empty() {
        return String::new();
    }
    let ymax = curve.iter().cloned().fold(f64::MIN, f64::max);
    let ymin = curve.iter().cloned().fold(f64::MAX, f64::min);
    let span = (ymax - ymin).max(1e-9);
    let mut grid = vec![vec![b' '; width]; height];
    for (i, &v) in curve.iter().enumerate() {
        let x = i * (width - 1) / curve.len().max(1);
        let yr = ((v - ymin) / span * (height - 1) as f64).round() as usize;
        let y = height - 1 - yr.min(height - 1);
        grid[y][x.min(width - 1)] = b'*';
    }
    let mut s = String::new();
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ymax:6.3} |")
        } else if r == height - 1 {
            format!("{ymin:6.3} |")
        } else {
            "       |".to_string()
        };
        s.push_str(&label);
        s.push_str(std::str::from_utf8(row).unwrap());
        s.push('\n');
    }
    s.push_str(&format!("        +{}\n", "-".repeat(width)));
    s.push_str(&format!("         epoch 1 .. {}\n", curve.len()));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_plot_renders() {
        let curve: Vec<f64> = (0..50).map(|i| 0.8 * (-(i as f64) / 15.0).exp() + 0.21).collect();
        let p = ascii_plot(&curve, 60, 12);
        assert!(p.contains('*'));
        assert!(p.lines().count() >= 12);
    }
}
