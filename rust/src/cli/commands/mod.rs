//! CLI subcommand implementations.
//!
//! Every simulation-shaped command (`simulate`, `adapt`, `sweep` cells,
//! `run`) assembles a [`crate::api::RunSpec`] and executes it through the
//! unified [`crate::api::Runner`] — predictor loading, artifact fallback
//! and sharded dispatch live there, not here.

pub mod adapt;
pub mod diff;
pub mod monitor;
pub mod policies;
pub mod run;
pub mod serve;
pub mod simulate;
pub mod store;
pub mod sweep;
pub mod table1;
pub mod trace_stats;
pub mod train;

/// ASCII plot of a loss curve (y auto-scaled), for terminal-friendly Fig 2.
pub fn ascii_plot(curve: &[f64], width: usize, height: usize) -> String {
    if curve.is_empty() {
        return String::new();
    }
    let ymax = curve.iter().cloned().fold(f64::MIN, f64::max);
    let ymin = curve.iter().cloned().fold(f64::MAX, f64::min);
    let span = (ymax - ymin).max(1e-9);
    let mut grid = vec![vec![b' '; width]; height];
    for (i, &v) in curve.iter().enumerate() {
        let x = i * (width - 1) / curve.len().max(1);
        let yr = ((v - ymin) / span * (height - 1) as f64).round() as usize;
        let y = height - 1 - yr.min(height - 1);
        grid[y][x.min(width - 1)] = b'*';
    }
    let mut s = String::new();
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ymax:6.3} |")
        } else if r == height - 1 {
            format!("{ymin:6.3} |")
        } else {
            "       |".to_string()
        };
        s.push_str(&label);
        s.push_str(std::str::from_utf8(row).unwrap());
        s.push('\n');
    }
    s.push_str(&format!("        +{}\n", "-".repeat(width)));
    s.push_str(&format!("         epoch 1 .. {}\n", curve.len()));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_plot_renders() {
        let curve: Vec<f64> = (0..50).map(|i| 0.8 * (-(i as f64) / 15.0).exp() + 0.21).collect();
        let p = ascii_plot(&curve, 60, 12);
        assert!(p.contains('*'));
        assert!(p.lines().count() >= 12);
    }
}
