//! `acpc diff` — compare two run reports (files or store entries) as a
//! keyed metric-delta table, or two `BENCH_sim.json` trajectories as the
//! CI perf-regression gate.

use crate::api::ReportStore;
use crate::cli::Args;
use crate::util::bench::latest_snapshot;
use crate::util::bench::print_table;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

const HELP: &str = "\
acpc diff — compare two run reports, or gate on a perf trajectory

Report mode:
    acpc diff <a> <b> [--store <dir>] [--json <out>]

<a>/<b> are RunReport JSON files (`acpc run --json`), or — when no such
file exists — unique prefixes of report-store entry hashes (the
`spec_hash` values printed by `acpc run --manifest` / `acpc sweep`).
Prints every shared numeric metric with its absolute and relative delta.

Bench mode (the CI regression gate):
    acpc diff --bench <baseline.json> <current.json> [--tolerance 0.5]

Compares the *latest* snapshot of each BENCH_sim.json history, case by
case on mean_ns. Exit code 1 when any case in <current> is slower than
<baseline> by more than the tolerance (fractional: 0.5 = 50% slower);
snapshots at different scales (smoke vs full) are never gated.

OPTIONS:
    --bench <baseline>    trajectory baseline (enables bench mode)
    --tolerance <f>       allowed fractional slowdown [default: 0.5]
    --store <dir>         report store for hash operands
                          [default: $ACPC_STORE or .acpc-store]
    --json <out>          write the report-mode delta table as JSON
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&["bench", "tolerance", "store", "json", "help"])?;
    if args.opt("bench").is_some() || args.flag("bench") {
        return run_bench(args);
    }

    let a = args.next_positional().context("`acpc diff` needs two report arguments")?;
    let b = args.next_positional().context("`acpc diff` needs two report arguments")?;
    let ja = load_report(&a, args).with_context(|| format!("loading '{a}'"))?;
    let jb = load_report(&b, args).with_context(|| format!("loading '{b}'"))?;

    let ma = metric_rows(&ja);
    let mb = metric_rows(&jb);
    let mut keys: Vec<String> = ma.keys().cloned().collect();
    keys.extend(mb.keys().filter(|k| !ma.contains_key(*k)).cloned());
    keys.sort();
    let mut rows = Vec::new();
    let mut deltas = Json::obj();
    for k in keys {
        let (va, vb) = (ma.get(&k).copied(), mb.get(&k).copied());
        let (sa, sb) = (fmt_opt(va), fmt_opt(vb));
        let (d, pct) = match (va, vb) {
            (Some(x), Some(y)) => {
                let d = y - x;
                let pct =
                    if x.abs() > 1e-12 { format!("{:+.2}%", d / x * 100.0) } else { "-".into() };
                (format!("{d:+.6}"), pct)
            }
            _ => ("-".into(), "-".into()),
        };
        if let (Some(x), Some(y)) = (va, vb) {
            deltas.set(
                &k,
                Json::from_pairs(vec![
                    ("a", Json::Num(x)),
                    ("b", Json::Num(y)),
                    ("delta", Json::Num(y - x)),
                ]),
            );
        }
        rows.push(vec![k, sa, sb, d, pct]);
    }
    print_table(&format!("diff: {a} → {b}"), &["metric", "a", "b", "delta", "delta %"], &rows);

    if let Some(out) = args.opt("json") {
        let j = Json::from_pairs(vec![
            ("schema", Json::Str("acpc-diff-v1".into())),
            ("a", Json::Str(a.clone())),
            ("b", Json::Str(b.clone())),
            ("deltas", deltas),
        ]);
        std::fs::write(out, j.to_pretty())?;
        println!("wrote {out}");
    }
    Ok(0)
}

fn fmt_opt(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.6}"),
        None => "-".into(),
    }
}

/// Resolve one diff operand: an existing report file wins; otherwise the
/// token is treated as a (possibly abbreviated) store entry hash.
fn load_report(token: &str, args: &Args) -> Result<Json> {
    let path = Path::new(token);
    if path.is_file() {
        let text = std::fs::read_to_string(path)?;
        return Json::parse(&text).map_err(Into::into);
    }
    let store = match args.opt("store") {
        Some(p) => ReportStore::open(p),
        None => ReportStore::open_default(),
    };
    let hash = store.find(token).with_context(|| {
        format!(
            "'{token}' is neither a file nor a unique hash prefix in store {}",
            store.root().display()
        )
    })?;
    let text = std::fs::read_to_string(store.entry_path(&hash))?;
    Json::parse(&text).map_err(Into::into)
}

/// Every numeric metric a report exposes, keyed for the delta table: the
/// full `metrics` block plus the top-level run counters. Non-finite values
/// serialize as JSON null and are simply absent here.
fn metric_rows(j: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    if let Some(m) = j.get("metrics").and_then(|m| m.as_obj()) {
        for (k, v) in m {
            if let Some(x) = v.as_f64() {
                out.insert(format!("metrics.{k}"), x);
            }
        }
    }
    for k in [
        "prediction_batches",
        "online_train_steps",
        "adapt_windows",
        "drift_events",
        "predictor_swaps",
        "throttled_windows",
        "wall_secs",
        "accesses_per_sec",
    ] {
        if let Some(x) = j.get(k).and_then(|v| v.as_f64()) {
            out.insert(k.to_string(), x);
        }
    }
    out
}

/// The trajectory regression gate: latest snapshot vs latest snapshot,
/// case by case on mean_ns.
fn run_bench(args: &mut Args) -> Result<i32> {
    // `--bench <file>` carries the baseline as its value (flag-then-
    // positional also works: both operands positional).
    let a_path = match args.opt("bench") {
        Some(p) => p.to_string(),
        None => args.next_positional().context("bench mode needs two trajectory files")?,
    };
    let b_path = args.next_positional().context("bench mode needs two trajectory files")?;
    let tolerance = args.f64_or("tolerance", 0.5)?;

    let ja = Json::parse(&std::fs::read_to_string(&a_path)?)
        .with_context(|| format!("parsing {a_path}"))?;
    let jb = Json::parse(&std::fs::read_to_string(&b_path)?)
        .with_context(|| format!("parsing {b_path}"))?;
    let sa = latest_snapshot(&ja)
        .with_context(|| format!("{a_path}: no snapshots (schema acpc-bench-v2 expected)"))?;
    let sb = latest_snapshot(&jb)
        .with_context(|| format!("{b_path}: no snapshots (schema acpc-bench-v2 expected)"))?;

    let scale = |s: &Json| s.get("scale").and_then(|v| v.as_str()).unwrap_or("?").to_string();
    let (scale_a, scale_b) = (scale(sa), scale(sb));
    if scale_a != scale_b {
        println!(
            "bench scales differ (baseline {scale_a}, current {scale_b}); nothing to gate on"
        );
        return Ok(0);
    }

    let ma = case_means(sa);
    let mb = case_means(sb);
    let mut rows = Vec::new();
    let mut regressions = 0usize;
    for (case, &bm) in &mb {
        let Some(&am) = ma.get(case) else {
            rows.push(vec![case.clone(), "-".into(), fmt_ms(bm), "-".into(), "new".into()]);
            continue;
        };
        let ratio = bm / am.max(1e-9);
        let verdict = if bm > am * (1.0 + tolerance) {
            regressions += 1;
            "REGRESSION"
        } else {
            "ok"
        };
        rows.push(vec![
            case.clone(),
            fmt_ms(am),
            fmt_ms(bm),
            format!("{ratio:.2}x"),
            verdict.into(),
        ]);
    }
    for case in ma.keys().filter(|c| !mb.contains_key(*c)) {
        rows.push(vec![case.clone(), fmt_ms(ma[case]), "-".into(), "-".into(), "gone".into()]);
    }
    print_table(
        &format!("perf trajectory: {a_path} → {b_path} (tolerance {tolerance:.2})"),
        &["case", "baseline", "current", "ratio", "verdict"],
        &rows,
    );
    if regressions > 0 {
        crate::log_error!(
            "{regressions} case(s) regressed beyond the {:.0}% tolerance",
            tolerance * 100.0
        );
        return Ok(1);
    }
    println!("\nno regressions beyond the {:.0}% tolerance", tolerance * 100.0);
    Ok(0)
}

fn fmt_ms(ns: f64) -> String {
    if ns < 1e6 {
        format!("{:.1}µs", ns / 1e3)
    } else {
        format!("{:.2}ms", ns / 1e6)
    }
}

/// `bench/case` → mean_ns for every result in a snapshot.
fn case_means(snapshot: &Json) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    let Some(benches) = snapshot.get("benches").and_then(|b| b.as_obj()) else { return out };
    for (bench, sec) in benches {
        let Some(results) = sec.get("results").and_then(|r| r.as_arr()) else { continue };
        for r in results {
            if let (Some(name), Some(mean)) =
                (r.get("name").and_then(|n| n.as_str()), r.get("mean_ns").and_then(|m| m.as_f64()))
            {
                out.insert(format!("{bench}/{name}"), mean);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traj(path: &Path, mean_a: f64, mean_b: f64) {
        let j = format!(
            r#"{{"schema": "acpc-bench-v2", "snapshots": [
                {{"id": "x", "scale": "smoke", "benches": {{
                    "alpha": {{"results": [
                        {{"name": "c1", "iters": 1, "mean_ns": {mean_a}, "ci95_ns": 0, "min_ns": {mean_a}}},
                        {{"name": "c2", "iters": 1, "mean_ns": {mean_b}, "ci95_ns": 0, "min_ns": {mean_b}}}
                    ]}}}}}}]}}"#
        );
        std::fs::write(path, j).unwrap();
    }

    /// The gate passes within tolerance and fails (exit 1) beyond it.
    #[test]
    fn bench_gate_detects_regressions() {
        let dir = std::env::temp_dir().join("acpc_diff_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let ok = dir.join("ok.json");
        let bad = dir.join("bad.json");
        traj(&base, 1000.0, 1000.0);
        traj(&ok, 1200.0, 900.0); // +20% and faster: inside 50% tolerance
        traj(&bad, 1600.0, 1000.0); // +60%: regression

        let run = |b: &Path| {
            let argv: Vec<String> = [
                "diff",
                "--bench",
                base.to_str().unwrap(),
                b.to_str().unwrap(),
            ]
            .iter()
            .map(|s| s.to_string())
            .collect();
            let mut args = Args::new(argv);
            assert_eq!(args.next_positional().as_deref(), Some("diff"));
            super::run(&mut args).unwrap()
        };
        assert_eq!(run(&ok), 0);
        assert_eq!(run(&bad), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metric_rows_flatten_metrics_and_counters() {
        let j = Json::parse(
            r#"{"metrics": {"l2_hit_rate": 0.5, "name": "x", "emu": null},
                "wall_secs": 1.5, "spec": {"seed": "1"}}"#,
        )
        .unwrap();
        let m = metric_rows(&j);
        assert_eq!(m.get("metrics.l2_hit_rate"), Some(&0.5));
        assert_eq!(m.get("wall_secs"), Some(&1.5));
        assert!(!m.contains_key("metrics.name"), "strings are not metrics");
        assert!(!m.contains_key("metrics.emu"), "null (NaN) carries no value");
    }
}
