//! `acpc serve` — multi-worker serving-node simulation.

use crate::cli::Args;
use crate::config::PredictorKind;
use crate::coordinator::{serve, serve_shared, RouterPolicy, ServeConfig};
use crate::predictor::{Backend, HeuristicPredictor, ModelRuntime, PredictorBox};
use crate::runtime::{Manifest, NativeWeights, ParamStore};
use crate::trace::{GeneratorConfig, ModelProfile};
use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::Duration;

const HELP: &str = "\
acpc serve — serving-node simulation: router + workers + batched predictor

OPTIONS:
    --spec <path>        run a ServeSpec JSON (schema acpc-serve-spec-v1):
                         spec-driven tenant-aware serving with per-tenant
                         arrival processes, token-bucket admission, and the
                         noisy-neighbor arbiter. Mutually exclusive with
                         every workload flag below (the spec carries them);
                         combine only with --json
    --workers <n>        worker threads [default: 4]
    --sessions <n>       sessions to admit [default: 200]
    --policy <name>      L2 policy [default: acpc]
    --predictor <kind>   none|heuristic|dnn|tcn [default: heuristic]
    --backend <name>     native|pjrt inference engine for dnn/tcn: native
                         shares one weight snapshot across workers, pjrt
                         runs the central predictor-service thread
                         [default: native]
    --router <policy>    rr|least [default: least]
    --profile <name>     workload profile [default: gpt3ish]
    --scenario <name>    scenario-registry workload (mutually exclusive
                         with --profile; see `acpc policies`)
    --adaptive           per-worker adaptive controllers (drift-triggered
                         prediction throttling; events in the report)
    --batch <n>          predictor batch size [default: 256]
    --deadline-us <n>    batching deadline [default: 2000]
    --arrival-us <n>     inter-arrival pacing [default: 100]
    --seed <n>
    --dashboard <port>   HTTP dashboard on 127.0.0.1:<port> for the run's
                         duration (/health, /metrics.json, /events; 0 = any)
    --dashboard-linger-ms <n>  keep the dashboard up n ms after the run
                         drains (for external scrapers) [default: 0]
    --capture <path>     record every served access into a v2 .acpctrace
                         (tenant = worker, arrival = per-worker ordinal) for
                         `acpc trace-stats --load` and `traffic.replay` runs
    --json <path>        write the ServeReport JSON (schema acpc-serve-v1,
                         includes the full adaptation-event list)
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&[
        "spec", "workers", "sessions", "policy", "predictor", "backend", "router", "profile",
        "scenario", "adaptive", "batch", "deadline-us", "arrival-us", "seed", "dashboard",
        "dashboard-linger-ms", "capture", "json", "help",
    ])?;
    if let Some(path) = args.opt("spec") {
        // Spec-driven tenant-aware mode: the spec carries the whole run
        // description, so classic workload flags are rejected rather than
        // silently ignored.
        const CLASSIC: &[&str] = &[
            "workers", "sessions", "policy", "predictor", "backend", "router", "profile",
            "scenario", "batch", "deadline-us", "arrival-us", "seed", "dashboard",
            "dashboard-linger-ms", "capture",
        ];
        for k in CLASSIC {
            if args.opt(k).is_some() {
                anyhow::bail!("--{k} conflicts with --spec (put it in the spec file)");
            }
        }
        if args.flag("adaptive") {
            anyhow::bail!("--adaptive conflicts with --spec (arbitration lives in the spec)");
        }
        let spec = crate::serve::ServeSpec::from_file(std::path::Path::new(path))?;
        let rep = crate::serve::run(&spec)?;
        print_tenant_report(&rep);
        if let Some(out) = args.opt("json") {
            std::fs::write(out, rep.to_json().to_pretty())?;
            println!("wrote {out}");
        }
        return Ok(0);
    }
    if args.opt("profile").is_some() && args.opt("scenario").is_some() {
        anyhow::bail!("--profile and --scenario are mutually exclusive");
    }

    let kind = PredictorKind::parse(&args.opt_or("predictor", "heuristic"))?;
    if args.flag("adaptive") && kind == PredictorKind::None {
        anyhow::bail!("--adaptive needs a predictor to throttle (drop --predictor none)");
    }
    let learned = matches!(kind, PredictorKind::Dnn | PredictorKind::Tcn);
    let backend = match args.opt("backend") {
        Some(v) => {
            let b = Backend::parse(&v)?;
            if !learned {
                anyhow::bail!(
                    "--backend selects the inference engine of a learned predictor \
                     (use --predictor dnn|tcn)"
                );
            }
            b
        }
        None => Backend::default(),
    };
    let seed = args.u64_or("seed", 0x5E21)?;
    let scenario = args.opt("scenario").map(|s| s.to_string());
    if let Some(name) = &scenario {
        if crate::trace::Scenario::by_name(name).is_none() {
            anyhow::bail!("unknown scenario '{name}' (see `acpc policies`)");
        }
    }
    let profile =
        ModelProfile::by_name(&args.opt_or("profile", "gpt3ish")).context("unknown profile")?;
    let mut generator = GeneratorConfig::new(profile, seed);
    generator.arrival_p_hot = 0.0;
    generator.arrival_p_cold = 0.0;

    let cfg = ServeConfig {
        workers: args.usize_or("workers", 4)?,
        policy: args.opt_or("policy", "acpc"),
        hierarchy: crate::mem::HierarchyConfig::scaled(),
        generator,
        total_sessions: args.u64_or("sessions", 200)?,
        arrival_interval: Duration::from_micros(args.u64_or("arrival-us", 100)?),
        router: RouterPolicy::parse(&args.opt_or("router", "least")).context("router: rr|least")?,
        predict_batch: args.usize_or("batch", 256)?,
        predict_deadline: Duration::from_micros(args.u64_or("deadline-us", 2000)?),
        scenario,
        adaptive: args.flag("adaptive"),
        adapt: crate::adapt::ControllerConfig::default(),
        dashboard_port: match args.opt("dashboard") {
            Some(v) => Some(
                v.parse::<u16>()
                    .map_err(|_| anyhow::anyhow!("--dashboard expects a port, got '{v}'"))?,
            ),
            None => None,
        },
        dashboard_linger: Duration::from_millis(args.u64_or("dashboard-linger-ms", 0)?),
        capture: args.opt("capture").map(std::path::PathBuf::from),
    };

    println!(
        "serving: workers={} sessions={} policy={} predictor={:?} backend={} router={:?} workload={} adaptive={}",
        cfg.workers,
        cfg.total_sessions,
        cfg.policy,
        kind,
        if learned { backend.label() } else { "-" },
        cfg.router,
        cfg.scenario.as_deref().unwrap_or(&cfg.generator.profile.name),
        cfg.adaptive
    );
    let rep = if learned && backend == Backend::Native {
        // Native default: load + repack the weights once on this thread and
        // share the `Send` snapshot across every worker — no predictor
        // service thread at all.
        let dir = crate::runtime::artifacts_dir().context("run `make artifacts`")?;
        let manifest = Manifest::load(&dir)?;
        let name = kind_model(kind).unwrap();
        let mm = manifest.model(&name)?;
        let store = ParamStore::load(&manifest, &name)?;
        let weights = Arc::new(NativeWeights::from_params(mm, &store)?);
        serve_shared(&cfg, weights, None)
    } else {
        // Classic kinds, and the `--backend pjrt` escape hatch: the factory
        // runs inside the predictor-service thread (PJRT is !Send).
        let (window, model_name): (usize, Option<String>) = match kind {
            PredictorKind::None => (0, None),
            PredictorKind::Heuristic | PredictorKind::Dnn => (1, kind_model(kind)),
            PredictorKind::Tcn => {
                let dir = crate::runtime::artifacts_dir().context("run `make artifacts`")?;
                let manifest = Manifest::load(&dir)?;
                (manifest.model("tcn")?.window, Some("tcn".into()))
            }
        };
        serve(&cfg, window, move || build_in_thread(kind, model_name.as_deref()))
    };

    println!("\n== serve report ==");
    println!(
        "sessions: admitted={} completed={} rejected={}",
        rep.sessions_admitted, rep.sessions_completed, rep.sessions_rejected
    );
    println!(
        "tokens={} accesses={} wall={:.2}s throughput={:.0} tok/s (wall)",
        rep.tokens, rep.accesses, rep.wall_secs, rep.tokens_per_sec_wall
    );
    println!(
        "L2 hit rate={:.1}% pollution={:.2}% | session latency p50={:.1}ms p95={:.1}ms",
        rep.l2_hit_rate * 100.0,
        rep.l2_pollution_ratio * 100.0,
        rep.session_latency_ms_p50,
        rep.session_latency_ms_p95
    );
    println!(
        "prediction: batches={} mean_fill={:.1} | router imbalance(max)={}",
        rep.prediction_batches, rep.mean_batch_fill, rep.router_imbalance_max
    );
    if cfg.adaptive {
        println!(
            "adaptation: windows={} drift_events={} throttled_windows={} events={}",
            rep.adapt_windows,
            rep.drift_events,
            rep.throttled_windows,
            rep.adaptation_events.len()
        );
    }
    if let Some(out) = args.opt("json") {
        std::fs::write(out, rep.to_json().to_pretty())?;
        println!("wrote {out}");
    }
    Ok(0)
}

fn print_tenant_report(rep: &crate::coordinator::ServeReport) {
    println!("\n== serve report (tenant-aware) ==");
    println!(
        "sessions: admitted={} completed={} shed={}",
        rep.sessions_admitted, rep.sessions_completed, rep.sessions_rejected
    );
    println!(
        "tokens={} accesses={} | L2 hit rate={:.1}% pollution={:.2}%",
        rep.tokens,
        rep.accesses,
        rep.l2_hit_rate * 100.0,
        rep.l2_pollution_ratio * 100.0
    );
    println!("arbiter: windows={} throttled_windows={}", rep.adapt_windows, rep.throttled_windows);
    for t in &rep.tenants {
        println!(
            "tenant {:>12}: offered={} admitted={} shed={} deferred={} completed={} \
             hit={:.1}% pollution={:.2}% delay(mean/max)={:.1}/{} throttled={}",
            t.name,
            t.offered,
            t.admitted,
            t.shed,
            t.deferred,
            t.completed,
            t.l2_hit_rate * 100.0,
            t.l2_pollution_ratio * 100.0,
            t.queue_delay_mean_ticks,
            t.queue_delay_max_ticks,
            t.throttled_windows
        );
    }
}

fn kind_model(kind: PredictorKind) -> Option<String> {
    match kind {
        PredictorKind::Dnn => Some("dnn".into()),
        PredictorKind::Tcn => Some("tcn".into()),
        _ => None,
    }
}

/// Factory body run inside the predictor-service thread. Learned kinds
/// reach this only under `--backend pjrt` (native runs use
/// [`serve_shared`]), so the runtime is pinned to the PJRT predict path.
fn build_in_thread(kind: PredictorKind, model: Option<&str>) -> PredictorBox {
    match kind {
        PredictorKind::None => PredictorBox::None,
        PredictorKind::Heuristic => PredictorBox::Heuristic(HeuristicPredictor),
        PredictorKind::Dnn | PredictorKind::Tcn => {
            let mut rt =
                ModelRuntime::load_from_artifacts(model.unwrap()).expect("model artifacts");
            rt.set_backend(Backend::Pjrt);
            PredictorBox::Model(Box::new(rt))
        }
    }
}
