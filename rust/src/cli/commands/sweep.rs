//! `acpc sweep` — multi-threaded policy×scenario grid sweep.

use crate::api::CacheMode;
use crate::cli::Args;
use crate::sim::sweep::{render_cells, run_sweep, SweepConfig};
use crate::trace::SCENARIO_NAMES;
use crate::util::json::Json;
use crate::util::pool::default_threads;
use anyhow::Result;
use std::path::PathBuf;
use std::time::Instant;

const HELP: &str = "\
acpc sweep — run the policy×scenario experiment grid in parallel

Each grid cell simulates one replacement policy against one workload
scenario through the shared engine, with a deterministic per-cell seed:
results are identical for any -j.

OPTIONS:
    --policies <a,b,..>   comma-separated policies [default: lru,srrip,ship,acpc]
    --scenarios <a,b,..>  comma-separated scenarios or 'all' [default: all]
    --predictor <spec>    auto|heuristic|tcn|adaptive|none [default: auto]
                          (tcn loads the AOT artifacts per worker thread and
                          falls back to heuristic when absent; adaptive runs
                          a per-cell drift controller)
    -j, --jobs <n>        worker threads [default: cores-1]
    --shards <n>          set-shards per cell (power of two): each cell runs
                          on n extra threads with exact stat merging; total
                          parallelism ≈ jobs × shards [default: 1]
    --accesses <n>        accesses per cell [default: 400000]
    --seed <n>            base seed (per-cell seeds derive from it)
    --cache <mode>        report-store use: off | read | read-write
                          [default: read-write — a repeated sweep simulates
                          nothing, every cell is served from the store]
    --store <dir>         store root [default: $ACPC_STORE or .acpc-store]
    --json <path>         write all cell reports as JSON (each row carries
                          `cached` and `spec_hash` provenance)
    --help

Scenarios: decode-heavy prefill-burst rag-embedding long-context
           multi-tenant-mix speculative-decode
Example:
    acpc sweep --policies lru,drrip,ship,acpc --scenarios all --predictor tcn -j 8";

fn parse_list(s: &str) -> Vec<String> {
    s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect()
}

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&[
        "policies", "scenarios", "predictor", "jobs", "j", "shards", "accesses", "seed", "cache",
        "store", "json", "help",
    ])?;

    let policies = parse_list(&args.opt_or("policies", "lru,srrip,ship,acpc"));
    let scenarios = match args.opt_or("scenarios", "all").as_str() {
        "all" => SCENARIO_NAMES.iter().map(|s| s.to_string()).collect(),
        csv => parse_list(csv),
    };
    let mut cfg = SweepConfig::new(policies, scenarios);
    cfg.threads = args.usize_or("j", args.usize_or("jobs", default_threads())?)?;
    cfg.shards = args.usize_or("shards", 1)?.max(1);
    cfg.accesses = args.usize_or("accesses", cfg.accesses)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.predictor = args.opt_or("predictor", &cfg.predictor);
    // The CLI sweeps through the report store by default: a repeated
    // identical grid is pure cache hits. (The library default stays Off.)
    cfg.cache = CacheMode::parse(&args.opt_or("cache", "read-write"))?;
    cfg.store = args.opt("store").map(PathBuf::from);

    println!(
        "sweep: {} policies × {} scenarios = {} cells, {} accesses/cell, predictor={}, -j {}, \
         shards/cell {}, cache={}",
        cfg.policies.len(),
        cfg.scenarios.len(),
        cfg.policies.len() * cfg.scenarios.len(),
        cfg.accesses,
        cfg.predictor,
        cfg.threads,
        cfg.shards,
        cfg.cache.label()
    );
    let t0 = Instant::now();
    let cells = run_sweep(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n{}", render_cells(&cells));
    let total_accesses: u64 = cells.iter().map(|c| c.result.report.accesses).sum();
    let hits = cells.iter().filter(|c| c.cached).count();
    println!(
        "{} cells ({} cached, {} simulated) in {:.2}s wall ({:.2}M accesses/s aggregate)",
        cells.len(),
        hits,
        cells.len() - hits,
        wall,
        total_accesses as f64 / wall / 1e6
    );

    if let Some(path) = args.opt("json") {
        let rows: Vec<Json> = cells
            .iter()
            .map(|c| {
                Json::from_pairs(vec![
                    ("policy", Json::Str(c.policy.clone())),
                    ("scenario", Json::Str(c.scenario.clone())),
                    ("predictor", Json::Str(c.predictor.clone())),
                    // String, not Num: u64 seeds exceed f64's 2^53 integer
                    // range and must round-trip into `--seed` exactly.
                    ("seed", Json::Str(c.seed.to_string())),
                    ("spec_hash", Json::Str(c.spec_hash.clone())),
                    ("cached", Json::Bool(c.cached)),
                    ("tokens", Json::Num(c.result.tokens as f64)),
                    ("adapt_windows", Json::Num(c.result.adapt_windows as f64)),
                    ("drift_events", Json::Num(c.result.drift_events as f64)),
                    ("predictor_swaps", Json::Num(c.result.predictor_swaps as f64)),
                    ("throttled_windows", Json::Num(c.result.throttled_windows as f64)),
                    ("report", c.result.report.to_json()),
                ])
            })
            .collect();
        std::fs::write(path, Json::Arr(rows).to_pretty())?;
        println!("wrote {path}");
    }
    Ok(0)
}
