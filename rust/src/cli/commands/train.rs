//! `acpc train` — train a predictor from rust via the compiled Adam step;
//! reproduces the Figure 2 loss curve.

use super::ascii_plot;
use crate::cli::Args;
use crate::predictor::{Dataset, GeometryHints, ModelRuntime};
use crate::runtime::{Engine, Manifest};
use crate::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use crate::training::{train, TrainConfig};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

const HELP: &str = "\
acpc train — train a predictor (compiled train-step HLO, rust-driven)

OPTIONS:
    --model <name>      tcn|tcn_flat|tcn_short|dnn [default: tcn]
    --epochs <n>        [default: 80]
    --patience <n>      early-stopping patience [default: 10]
    --accesses <n>      training-trace length [default: 1200000]
    --sample-every <n>  keep 1/n of accesses as samples [default: 6]
    --max-batches <n>   cap train minibatches per epoch [default: 120]
    --profile <name>    workload profile [default: gpt3ish]
    --seed <n>
    --save <path.ckpt>  checkpoint the trained parameters
    --curve <path>      write the loss curve (JSON)
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&[
        "model", "epochs", "patience", "accesses", "sample-every", "max-batches", "profile",
        "seed", "save", "curve", "help",
    ])?;

    let dir = crate::runtime::artifacts_dir().context("run `make artifacts` first")?;
    let manifest = Manifest::load(&dir)?;
    let engine = Engine::cpu()?;
    let model = args.opt_or("model", "tcn");
    let mut rt = ModelRuntime::load(&engine, &manifest, &model)?;
    let seed = args.u64_or("seed", 0xF162)?;

    let profile = ModelProfile::by_name(&args.opt_or("profile", "gpt3ish"))
        .context("unknown profile")?;
    let gcfg = GeneratorConfig::new(profile, seed);
    let geom = GeometryHints::from_generator(&gcfg);
    let n_acc = args.usize_or("accesses", 1_200_000)?;
    println!("generating training trace ({n_acc} accesses) ...");
    let trace = TraceGenerator::new(gcfg).generate(n_acc);
    let ds = Dataset::build(&trace, rt.mm.window, geom, 4096, args.usize_or("sample-every", 6)?);
    let split = ds.split(seed);
    println!("dataset: n={} positive_rate={:.3}", ds.n, ds.positive_rate());

    let cfg = TrainConfig {
        epochs: args.usize_or("epochs", 80)?,
        patience: args.usize_or("patience", 10)?,
        max_batches_per_epoch: args.usize_or("max-batches", 120)?,
        seed,
        verbose_every: 5,
    };
    let res = train(&mut rt, &ds, &split, &cfg);

    println!("\nFigure 2 — training loss ({}):", res.model);
    println!("{}", ascii_plot(&res.train_curve, 64, 14));
    println!(
        "final train loss {:.3} | final val {:.3} | best val {:.3} | epochs {} | {} | stability: {}",
        res.final_train_loss,
        res.final_val_loss,
        res.best_val_loss,
        res.epochs_run,
        if res.stopped_early { "early-stopped" } else { "full run" },
        res.stability()
    );

    if let Some(path) = args.opt("save") {
        rt.store.save_checkpoint(Path::new(path))?;
        println!("checkpoint saved to {path}");
    }
    if let Some(path) = args.opt("curve") {
        let j = Json::from_pairs(vec![
            ("model", Json::Str(res.model.clone())),
            ("train_curve", Json::array_f64(&res.train_curve)),
            ("val_curve", Json::array_f64(&res.val_curve)),
            ("final_train_loss", Json::Num(res.final_train_loss)),
            ("stability", Json::Str(res.stability())),
        ]);
        std::fs::write(path, j.to_pretty())?;
        println!("curve written to {path}");
    }
    Ok(0)
}
