//! `acpc trace-stats` — workload characterization (validates the premise:
//! bursty, irregular, mixed-reuse LLM access streams).

use crate::cli::Args;
use crate::trace::{stats, GeneratorConfig, ModelProfile, TraceGenerator};
use anyhow::{Context, Result};
use std::path::Path;

const HELP: &str = "\
acpc trace-stats — generate + characterize a workload trace

OPTIONS:
    --profile <name>   gpt3ish|llama2ish|t5ish [default: gpt3ish]
    --accesses <n>     [default: 500000]
    --seed <n>
    --save <path>      also persist the trace (.acpctrace binary format)
    --load <path>      analyze an existing trace file instead
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&["profile", "accesses", "seed", "save", "load", "help"])?;

    let trace = if let Some(path) = args.opt("load") {
        crate::trace::file::read_trace(Path::new(path))?
    } else {
        let profile = ModelProfile::by_name(&args.opt_or("profile", "gpt3ish"))
            .context("unknown profile")?;
        let cfg = GeneratorConfig::new(profile, args.u64_or("seed", 0x7AC3)?);
        let mut gen = TraceGenerator::new(cfg);
        let t = gen.generate(args.usize_or("accesses", 500_000)?);
        println!(
            "generated {} accesses / {} tokens / {} sessions completed",
            t.len(),
            gen.tokens_done(),
            gen.sessions_completed()
        );
        if let Some(path) = args.opt("save") {
            crate::trace::file::write_trace(Path::new(path), &t)?;
            println!("trace saved to {path}");
        }
        t
    };

    let st = stats::analyze(&trace);
    println!("\n{}", st.report());
    Ok(0)
}
