//! `acpc trace-stats` — workload characterization (validates the premise:
//! bursty, irregular, mixed-reuse LLM access streams).

use crate::cli::Args;
use crate::trace::{stats, GeneratorConfig, ModelProfile, TraceGenerator};
use anyhow::{Context, Result};
use std::path::Path;

const HELP: &str = "\
acpc trace-stats — generate + characterize a workload trace

OPTIONS:
    --profile <name>   gpt3ish|llama2ish|t5ish [default: gpt3ish]
    --accesses <n>     [default: 500000]
    --seed <n>
    --save <path>      also persist the trace (.acpctrace binary format)
    --load <path>      analyze an existing trace file instead; v2 captures
                       (acpc serve --capture) add a per-tenant breakdown
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&["profile", "accesses", "seed", "save", "load", "help"])?;

    let trace = if let Some(path) = args.opt("load") {
        let reader = crate::trace::file::TraceReader::open(Path::new(path))?;
        if reader.version() == 2 {
            // Captures carry provenance: totals in the header, a tenant id
            // per record. Surface both before the standard characterization.
            println!(
                "v2 capture: {} records / {} tokens / {} sessions",
                reader.count(),
                reader.tokens(),
                reader.sessions()
            );
            let records = reader.collect::<Result<Vec<_>>>()?;
            println!("\n{}", stats::analyze_tenants(&records).report());
            records.into_iter().map(|r| r.access).collect()
        } else {
            // v1: same bytes on stdout as the pre-streaming reader printed.
            reader.map(|r| r.map(|rec| rec.access)).collect::<Result<Vec<_>>>()?
        }
    } else {
        let profile = ModelProfile::by_name(&args.opt_or("profile", "gpt3ish"))
            .context("unknown profile")?;
        let cfg = GeneratorConfig::new(profile, args.u64_or("seed", 0x7AC3)?);
        let mut gen = TraceGenerator::new(cfg);
        let t = gen.generate(args.usize_or("accesses", 500_000)?);
        println!(
            "generated {} accesses / {} tokens / {} sessions completed",
            t.len(),
            gen.tokens_done(),
            gen.sessions_completed()
        );
        if let Some(path) = args.opt("save") {
            crate::trace::file::write_trace(Path::new(path), &t)?;
            println!("trace saved to {path}");
        }
        t
    };

    let st = stats::analyze(&trace);
    println!("\n{}", st.report());
    Ok(0)
}
