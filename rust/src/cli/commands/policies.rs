//! `acpc policies` — list selectable components.

use anyhow::Result;

pub fn run() -> Result<i32> {
    println!("replacement policies (L2, under test):");
    for p in crate::policy::POLICY_NAMES {
        println!("  {p}");
    }
    println!("\nprefetchers:");
    for p in crate::mem::prefetch::PREFETCHER_NAMES {
        println!("  {p}");
    }
    println!("\nworkload profiles: gpt3ish llama2ish t5ish");
    println!("\nworkload scenarios (sweep grid):");
    for s in crate::trace::Scenario::all() {
        println!("  {:<17} {}", s.name, s.summary);
    }
    println!("\nhierarchy presets: scaled epyc7763");
    println!("predictors: none heuristic dnn tcn (artifact models: tcn tcn_flat tcn_short dnn)");
    println!("sweep predictor specs: {}", crate::sim::sweep::PREDICTOR_SPECS.join(" "));
    Ok(0)
}
