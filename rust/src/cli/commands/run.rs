//! `acpc run` — execute a reproducible `RunSpec` file through the unified
//! [`crate::api::Runner`] (the CLI face of the library's one front door),
//! or a whole manifest of specs through the experiment farm with
//! content-addressed caching.

use crate::api::{
    cells_to_json, load_manifest, run_farm, CacheMode, FarmConfig, ReportStore, RunSpec, Runner,
    FARM_BASE_SEED,
};
use crate::cli::Args;
use crate::util::bench::print_table;
use crate::util::pool::default_threads;
use anyhow::Result;
use std::path::Path;
use std::time::Instant;

const HELP: &str = "\
acpc run — execute a RunSpec file (schema acpc-run-v1) or a manifest

A RunSpec describes one run completely: policy, workload (scenario or
profile + generator overrides), predictor kind + artifact override,
hierarchy, accesses, set-shards, adaptive controller, seed. The report
embeds the fully-resolved spec, so `--json out.json` then re-running the
report's `spec` object reproduces the run bit-for-bit. See the README's
\"Library API\" section for the spec format; `acpc simulate --config`
accepts the same files.

With --manifest, every spec in a directory of *.json files (or in one
file holding a spec, an array, or {\"runs\": [...]}) executes on the
sweep thread pool, routed through the content-addressed report store:
cells whose resolved spec was already run are served from the store
(zero simulation), and a warm repeat of the same manifest is 100% cache
hits. See the README's \"Experiment farm\" section.

OPTIONS:
    --spec <file.json>    the RunSpec to execute
    --manifest <path>     run every spec in a dir (or multi-spec file)
    --seed <n>            override the spec's seed / farm base seed
    --accesses <n>        override the spec's trace length (--spec only)
    --shards <n>          override the spec's set-shard count (--spec only)
    --cache <mode>        off | read | read-write
                          [default: off for --spec, read-write for --manifest]
    --store <dir>         report store root [default: $ACPC_STORE or .acpc-store]
    -j, --jobs <n>        farm worker threads [default: cores-1]
    --json <path>         write the RunReport JSON (or farm cells JSON)
    --spec-out <path>     write the fully-resolved spec JSON (--spec only)
    --help

Example:
    echo '{\"policy\": \"acpc\", \"workload\": {\"scenario\": \"decode-heavy\"},
           \"accesses\": 200000, \"seed\": \"7\"}' > runs/a.json
    acpc run --manifest runs --json farm.json   # 2nd invocation: all cached";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&[
        "spec", "manifest", "seed", "accesses", "shards", "cache", "store", "jobs", "j", "json",
        "spec-out", "help",
    ])?;
    if let Some(manifest) = args.opt("manifest") {
        if args.opt("spec").is_some() {
            anyhow::bail!("--spec and --manifest are mutually exclusive");
        }
        return run_manifest(args, manifest.to_string());
    }
    let Some(path) = args.opt("spec") else {
        anyhow::bail!(
            "--spec <file.json> or --manifest <path> is required (see `acpc run --help`)"
        );
    };
    let mut spec = RunSpec::from_file(Path::new(path))?;
    if args.opt("seed").is_some() {
        spec.seed = Some(args.u64_or("seed", 0)?);
    }
    if args.opt("accesses").is_some() {
        spec.accesses = Some(args.usize_or("accesses", 0)?);
    }
    if args.opt("shards").is_some() {
        spec.shards = args.usize_or("shards", 1)?;
    }

    let mut runner = Runner::new(spec)?;
    let cache = CacheMode::parse(&args.opt_or("cache", "off"))?;
    if cache.reads() {
        runner = runner.with_store(store_from(args), cache);
    }
    {
        let s = runner.spec();
        println!(
            "run: name={} policy={} predictor={} accesses={} shards={} adaptive={} cache={}",
            s.name.as_deref().unwrap_or("-"),
            s.policy,
            s.predictor.label(),
            s.accesses.unwrap_or(0),
            s.shards,
            s.adaptive.is_some(),
            cache.label(),
        );
    }
    let (report, cached) = runner.run_cached()?;
    if cached {
        println!("(served from report store: {})", runner.spec_hash());
    }

    println!("\n{}", report.result.report.summary());
    println!("{}", report.counters_line());
    if let Some(a) = report.adaptation() {
        println!(
            "adaptation: windows={} drift_events={} swaps={} throttled_windows={}",
            a.windows_observed, a.drift_events, a.swaps, a.throttled_windows
        );
    }
    if let Some(t) = report.result.traffic {
        println!("{}", t.summary_line());
    }
    if let Some(out) = args.opt("spec-out") {
        std::fs::write(out, report.spec.to_json().to_pretty())?;
        println!("wrote {out}");
    }
    if let Some(out) = args.opt("json") {
        std::fs::write(out, report.to_json().to_pretty())?;
        println!("wrote {out}");
    }
    Ok(0)
}

/// The store the CLI flags select: `--store <dir>`, else the default root.
fn store_from(args: &Args) -> ReportStore {
    match args.opt("store") {
        Some(p) => ReportStore::open(p),
        None => ReportStore::open_default(),
    }
}

fn run_manifest(args: &Args, manifest: String) -> Result<i32> {
    let base_seed = args.u64_or("seed", FARM_BASE_SEED)?;
    let entries = load_manifest(Path::new(&manifest), base_seed)?;
    let cache = CacheMode::parse(&args.opt_or("cache", "read-write"))?;
    let store = cache.reads().then(|| store_from(args));
    let threads = args.usize_or("j", args.usize_or("jobs", default_threads())?)?.max(1);
    println!(
        "farm: {} entries from {manifest}, cache={}{}, -j {threads}",
        entries.len(),
        cache.label(),
        store.as_ref().map(|s| format!(" (store {})", s.root().display())).unwrap_or_default(),
    );

    let t0 = Instant::now();
    let cells = run_farm(entries, &FarmConfig { threads, store, cache, base_seed })?;
    let wall = t0.elapsed().as_secs_f64();

    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            let r = &c.report.result.report;
            vec![
                c.label.clone(),
                r.policy.clone(),
                c.report.predictor_effective.clone(),
                format!("{:.4}", r.l2_hit_rate),
                format!("{:.4}", r.l2_pollution_ratio),
                format!("{:.2}", r.amat),
                if c.cached { "yes".into() } else { "no".into() },
                c.spec_hash[..12].to_string(),
            ]
        })
        .collect();
    print_table(
        "experiment farm",
        &["label", "policy", "predictor", "l2 hit", "pollution", "amat", "cached", "spec hash"],
        &rows,
    );

    let hits = cells.iter().filter(|c| c.cached).count();
    println!(
        "\n{} cells ({} cached, {} simulated) in {:.2}s wall",
        cells.len(),
        hits,
        cells.len() - hits,
        wall
    );
    if let Some(out) = args.opt("json") {
        std::fs::write(out, cells_to_json(&cells).to_pretty())?;
        println!("wrote {out}");
    }
    Ok(0)
}
