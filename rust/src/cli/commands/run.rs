//! `acpc run` — execute a reproducible `RunSpec` file through the unified
//! [`crate::api::Runner`]: the CLI face of the library's one front door.

use crate::api::{RunSpec, Runner};
use crate::cli::Args;
use anyhow::Result;
use std::path::Path;

const HELP: &str = "\
acpc run — execute a RunSpec file (schema acpc-run-v1)

A RunSpec describes one run completely: policy, workload (scenario or
profile + generator overrides), predictor kind + artifact override,
hierarchy, accesses, set-shards, adaptive controller, seed. The report
embeds the fully-resolved spec, so `--json out.json` then re-running the
report's `spec` object reproduces the run bit-for-bit. See the README's
\"Library API\" section for the spec format; `acpc simulate --config`
accepts the same files.

OPTIONS:
    --spec <file.json>    the RunSpec to execute (required)
    --seed <n>            override the spec's seed
    --accesses <n>        override the spec's trace length
    --shards <n>          override the spec's set-shard count
    --json <path>         write the RunReport JSON (schema acpc-run-v1)
    --spec-out <path>     write the fully-resolved spec JSON
    --help

Example:
    echo '{\"policy\": \"acpc\", \"workload\": {\"scenario\": \"decode-heavy\"},
           \"accesses\": 200000, \"seed\": \"7\"}' > run.json
    acpc run --spec run.json --json report.json";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&["spec", "seed", "accesses", "shards", "json", "spec-out", "help"])?;
    let Some(path) = args.opt("spec") else {
        anyhow::bail!("--spec <file.json> is required (see `acpc run --help`)");
    };
    let mut spec = RunSpec::from_file(Path::new(path))?;
    if args.opt("seed").is_some() {
        spec.seed = Some(args.u64_or("seed", 0)?);
    }
    if args.opt("accesses").is_some() {
        spec.accesses = Some(args.usize_or("accesses", 0)?);
    }
    if args.opt("shards").is_some() {
        spec.shards = args.usize_or("shards", 1)?;
    }

    let runner = Runner::new(spec)?;
    {
        let s = runner.spec();
        println!(
            "run: name={} policy={} predictor={} accesses={} shards={} adaptive={}",
            s.name.as_deref().unwrap_or("-"),
            s.policy,
            s.predictor.label(),
            s.accesses.unwrap_or(0),
            s.shards,
            s.adaptive.is_some(),
        );
    }
    let report = runner.run()?;

    println!("\n{}", report.result.report.summary());
    println!("{}", report.counters_line());
    if let Some(a) = report.adaptation() {
        println!(
            "adaptation: windows={} drift_events={} swaps={} throttled_windows={}",
            a.windows_observed, a.drift_events, a.swaps, a.throttled_windows
        );
    }
    if let Some(out) = args.opt("spec-out") {
        std::fs::write(out, report.spec.to_json().to_pretty())?;
        println!("wrote {out}");
    }
    if let Some(out) = args.opt("json") {
        std::fs::write(out, report.to_json().to_pretty())?;
        println!("wrote {out}");
    }
    Ok(0)
}
