//! `acpc adapt` — replay one scenario with the adaptive controller ON vs
//! OFF on the same seed and report the comparison (windows, drift points,
//! swap count, hit-rate delta) as a table and optional JSON. Both arms
//! execute through the unified [`crate::api::Runner`]
//! ([`crate::api::run_compare`]).

use crate::adapt::ControllerConfig;
use crate::api::{run_compare, AdaptSpec, RunSpec};
use crate::cli::Args;
use crate::config::PredictorKind;
use crate::util::json::Json;
use anyhow::Result;

const HELP: &str = "\
acpc adapt — closed-loop adaptation: controller ON vs OFF on one seed

Replays the scenario twice with identical seeds: once plain, once with the
adaptive controller (windowed pollution telemetry → Page–Hinkley drift
detection → replay-buffer retrain for trainable predictors, throttle
back-off otherwise). Prints the per-arm metrics, the adaptation event log,
and the deltas; --json emits the full comparison, --telemetry the
per-window series for plotting.

OPTIONS:
    --scenario <name>     scenario-registry workload [default: multi-tenant-mix]
    --policy <name>       L2 policy [default: acpc]
    --predictor <kind>    heuristic|tcn|dnn [default: heuristic]
    --accesses <n>        accesses per arm [default: 400000]
    --window <n>          telemetry window in accesses [default: 8192]
    --ph-delta <x>        Page-Hinkley tolerance [default: 0.002]
    --ph-lambda <x>       Page-Hinkley threshold [default: 0.03]
    --train-steps <n>     Adam steps per drift retrain [default: 8]
    --shards <n>          split each arm across n set-partitioned worker
                          threads, one controller per shard [default: 1]
    --seed <n>            RNG seed
    --json <path>         write the comparison JSON
    --telemetry <path>    write the adaptive arm's per-window telemetry
                          series (schema acpc-adapt-telemetry-v1)
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&[
        "scenario", "policy", "predictor", "accesses", "window", "ph-delta", "ph-lambda",
        "train-steps", "shards", "seed", "json", "telemetry", "help",
    ])?;

    let scenario = args.opt_or("scenario", "multi-tenant-mix");
    let policy = args.opt_or("policy", "acpc");
    let kind = PredictorKind::parse(&args.opt_or("predictor", "heuristic"))?;
    if kind == PredictorKind::None {
        anyhow::bail!(
            "--predictor none gives the controller nothing to adapt (no predictions to \
             throttle, no model to retrain) — both arms would be identical"
        );
    }
    let seed = args.u64_or("seed", 0xADA7_2026)?;
    let accesses = args.usize_or("accesses", 400_000)?;
    let shards = args.usize_or("shards", 1)?;

    // Defaults come from the controller itself, so the CLI cannot drift
    // from `acpc run`/`acpc sweep` adaptive specs.
    let base = ControllerConfig::default();
    let adapt = AdaptSpec {
        window_accesses: Some(args.u64_or("window", base.window_accesses)?.max(256)),
        ph_delta: args.opt("ph-delta").map(|_| args.f64_or("ph-delta", 0.0)).transpose()?,
        ph_lambda: args.opt("ph-lambda").map(|_| args.f64_or("ph-lambda", 0.0)).transpose()?,
        train_steps_on_drift: args
            .opt("train-steps")
            .map(|_| args.usize_or("train-steps", 0))
            .transpose()?,
        seed: Some(seed),
        ..AdaptSpec::default()
    };
    let spec = RunSpec::builder()
        .scenario(&scenario)
        .policy(&policy)
        .predictor(kind)
        .accesses(accesses)
        .seed(seed)
        .shards(shards.max(1))
        .adaptive_spec(adapt)
        .build()?;
    // Resolve once for the provenance JSON below (the compare harness
    // resolves per arm internally).
    let resolved = spec.resolve()?.spec;
    let window_accesses = resolved
        .adaptive
        .as_ref()
        .and_then(|a| a.window_accesses)
        .unwrap_or(base.window_accesses);

    println!(
        "adapt: scenario={} policy={} predictor={} accesses={} window={} shards={} \
         (2 arms, same seed)",
        scenario,
        policy,
        kind.label(),
        accesses,
        window_accesses,
        shards.max(1)
    );
    let out = run_compare(&spec)?;

    println!(
        "\n== controller OFF (baseline) == [predictor: {}]",
        out.predictor_effective_baseline
    );
    println!("{}", out.baseline.report.summary());
    println!("== controller ON == [predictor: {}]", out.predictor_effective_adaptive);
    println!("{}", out.adaptive.report.summary());
    let s = &out.summary;
    println!(
        "\nadaptation: windows={} drift_windows={:?} drift_events={} swaps={} throttled_windows={} online_steps={}",
        s.windows_observed,
        s.drift_windows,
        s.drift_events,
        s.swaps,
        s.throttled_windows,
        s.online_train_steps,
    );
    for e in &s.events {
        println!(
            "  window {:>4} @access {:>9}: {:<8} (hit_rate {:.3}, v{})",
            e.window,
            e.access,
            e.action.label(),
            e.hit_rate,
            e.predictor_version
        );
    }
    println!(
        "\ndeltas (adaptive − baseline): CHR {:+.2} pp, pollution {:+.2} pp, AMAT {:+.2}",
        out.hit_rate_delta() * 100.0,
        out.pollution_delta() * 100.0,
        out.adaptive.report.amat - out.baseline.report.amat,
    );

    if let Some(path) = args.opt("json") {
        let mut j = out.to_json();
        j.set("spec", resolved.to_json());
        std::fs::write(path, j.to_pretty())?;
        println!("wrote {path}");
    }
    if let Some(path) = args.opt("telemetry") {
        // Per-window series of the adaptive arm — the plotting input
        // (fig-style): columnar arrays aligned on the window log.
        let mut t = out.summary.telemetry_json();
        t.set("scenario", Json::Str(scenario.clone()));
        t.set("policy", Json::Str(policy.clone()));
        // What actually ran (artifact fallback included), plus the request.
        t.set("predictor", Json::Str(out.predictor_effective_adaptive.clone()));
        t.set("predictor_requested", Json::Str(kind.label().into()));
        // String, not Num: u64 seeds exceed f64's exact-integer range.
        t.set("seed", Json::Str(seed.to_string()));
        t.set("window_accesses", Json::Num(window_accesses as f64));
        std::fs::write(path, t.to_pretty())?;
        println!("wrote {path}");
    }
    Ok(0)
}
