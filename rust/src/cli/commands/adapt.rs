//! `acpc adapt` — replay one scenario with the adaptive controller ON vs
//! OFF on the same seed and report the comparison (windows, drift points,
//! swap count, hit-rate delta) as a table and optional JSON.

use super::build_predictor;
use crate::adapt::{run_compare, run_compare_sharded, ControllerConfig};
use crate::cli::Args;
use crate::config::{ExperimentConfig, PredictorKind};
use crate::predictor::PredictorBox;
use crate::util::json::Json;
use anyhow::Result;

const HELP: &str = "\
acpc adapt — closed-loop adaptation: controller ON vs OFF on one seed

Replays the scenario twice with identical seeds: once plain, once with the
adaptive controller (windowed pollution telemetry → Page–Hinkley drift
detection → replay-buffer retrain for trainable predictors, throttle
back-off otherwise). Prints the per-arm metrics, the adaptation event log,
and the deltas; --json emits the full comparison.

OPTIONS:
    --scenario <name>     scenario-registry workload [default: multi-tenant-mix]
    --policy <name>       L2 policy [default: acpc]
    --predictor <kind>    heuristic|tcn|dnn [default: heuristic]
    --accesses <n>        accesses per arm [default: 400000]
    --window <n>          telemetry window in accesses [default: 8192]
    --ph-delta <x>        Page-Hinkley tolerance [default: 0.002]
    --ph-lambda <x>       Page-Hinkley threshold [default: 0.03]
    --train-steps <n>     Adam steps per drift retrain [default: 8]
    --shards <n>          split each arm across n set-partitioned worker
                          threads, one controller per shard [default: 1]
    --seed <n>            RNG seed
    --json <path>         write the comparison JSON
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&[
        "scenario", "policy", "predictor", "accesses", "window", "ph-delta", "ph-lambda",
        "train-steps", "shards", "seed", "json", "help",
    ])?;

    let scenario = args.opt_or("scenario", "multi-tenant-mix");
    let policy = args.opt_or("policy", "acpc");
    let kind = PredictorKind::parse(&args.opt_or("predictor", "heuristic"))?;
    if kind == PredictorKind::None {
        anyhow::bail!(
            "--predictor none gives the controller nothing to adapt (no predictions to \
             throttle, no model to retrain) — both arms would be identical"
        );
    }
    let seed = args.u64_or("seed", 0xADA7_2026)?;
    let mut cfg = ExperimentConfig::for_scenario(&scenario, &policy, kind, seed)?;
    cfg.accesses = args.usize_or("accesses", 400_000)?;
    if crate::policy::make_policy(&cfg.policy, 2, 2, 0).is_none() {
        anyhow::bail!("unknown policy '{}' (see `acpc policies`)", cfg.policy);
    }

    let base = ControllerConfig::default();
    let ccfg = ControllerConfig {
        window_accesses: args.u64_or("window", base.window_accesses)?.max(256),
        ph_delta: args.f64_or("ph-delta", base.ph_delta)?,
        ph_lambda: args.f64_or("ph-lambda", base.ph_lambda)?,
        train_steps_on_drift: args.usize_or("train-steps", base.train_steps_on_drift)?,
        seed,
        ..base
    };

    let shards = args.usize_or("shards", 1)?;
    if shards > 1 {
        cfg.hierarchy
            .validate_shards(shards)
            .map_err(|e| anyhow::anyhow!("--shards: {e}"))?;
    }

    println!(
        "adapt: scenario={} policy={} predictor={} accesses={} window={} shards={} \
         (2 arms, same seed)",
        scenario,
        cfg.policy,
        kind.label(),
        cfg.accesses,
        ccfg.window_accesses,
        shards.max(1)
    );
    let out = if shards > 1 {
        let mk = move |_shard: usize| -> PredictorBox {
            super::build_predictor_or_heuristic(kind, None, "adapt")
        };
        run_compare_sharded(&cfg, &ccfg, shards, &mk)?
    } else {
        // One fresh predictor per arm so the adaptive arm's fine-tuning
        // cannot leak into the baseline. Built up front so artifact errors
        // surface as CLI errors, not mid-run panics.
        let mut pool: Vec<PredictorBox> =
            vec![build_predictor(kind, None)?, build_predictor(kind, None)?];
        run_compare(&cfg, &ccfg, move || pool.pop().expect("two prebuilt arms"))
    };

    println!("\n== controller OFF (baseline) ==");
    println!("{}", out.baseline.report.summary());
    println!("== controller ON ==");
    println!("{}", out.adaptive.report.summary());
    let s = &out.summary;
    println!(
        "\nadaptation: windows={} drift_windows={:?} drift_events={} swaps={} throttled_windows={} online_steps={}",
        s.windows_observed,
        s.drift_windows,
        s.drift_events,
        s.swaps,
        s.throttled_windows,
        s.online_train_steps,
    );
    for e in &s.events {
        println!(
            "  window {:>4} @access {:>9}: {:<8} (hit_rate {:.3}, v{})",
            e.window,
            e.access,
            e.action.label(),
            e.hit_rate,
            e.predictor_version
        );
    }
    println!(
        "\ndeltas (adaptive − baseline): CHR {:+.2} pp, pollution {:+.2} pp, AMAT {:+.2}",
        out.hit_rate_delta() * 100.0,
        out.pollution_delta() * 100.0,
        out.adaptive.report.amat - out.baseline.report.amat,
    );

    if let Some(path) = args.opt("json") {
        let mut j = out.to_json();
        j.set("scenario", Json::Str(scenario.clone()));
        j.set("policy", Json::Str(cfg.policy.clone()));
        j.set("predictor", Json::Str(kind.label().into()));
        // String, not Num: u64 seeds exceed f64's exact-integer range.
        j.set("seed", Json::Str(seed.to_string()));
        j.set("accesses", Json::Num(cfg.accesses as f64));
        j.set("window_accesses", Json::Num(ccfg.window_accesses as f64));
        std::fs::write(path, j.to_pretty())?;
        println!("wrote {path}");
    }
    Ok(0)
}
