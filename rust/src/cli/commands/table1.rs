//! `acpc table1` — the paper's Table 1, end-to-end.

use crate::cli::Args;
use crate::metrics::report::render_table1;
use crate::sim::{run_table1, Table1Scale};
use anyhow::Result;

const HELP: &str = "\
acpc table1 — reproduce Table 1 (train TCN + DNN, simulate 4 policies)

OPTIONS:
    --scale <full|smoke>   [default: full]
    --json <path>          dump rows as JSON
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&["scale", "json", "help"])?;
    let scale = match args.opt_or("scale", "full").as_str() {
        "smoke" => Table1Scale::smoke(),
        _ => Table1Scale::full(),
    };
    let out = run_table1(&scale)?;
    println!("\nTable 1 — Comparative Performance of Different Models (reproduced)\n");
    println!("{}", render_table1(&out.rows));
    println!("{}", out.headline_deltas());
    println!(
        "\nheld-out (test) BCE: tcn={:.3} dnn={:.3}",
        out.tcn_test_loss, out.dnn_test_loss
    );
    if let Some(path) = args.opt("json") {
        use crate::util::json::Json;
        let rows: Vec<Json> = out
            .rows
            .iter()
            .map(|r| {
                Json::from_pairs(vec![
                    ("model", Json::Str(r.model.clone())),
                    ("chr", Json::Num(r.chr)),
                    ("ppr", Json::Num(r.ppr)),
                    ("mpr", Json::Num(r.mpr)),
                    ("tgt", Json::Num(r.tgt)),
                    ("final_loss", Json::Num(r.final_loss)),
                    ("stability", Json::Str(r.stability.clone())),
                ])
            })
            .collect();
        std::fs::write(path, Json::Arr(rows).to_pretty())?;
        println!("wrote {path}");
    }
    Ok(0)
}
