//! `acpc simulate` — one cache simulation with full metric output.
//!
//! Flags assemble a [`crate::api::RunSpec`] which the unified
//! [`crate::api::Runner`] executes — the same code path as `acpc run`,
//! `acpc adapt`, the sweep cells and the library API. `--config` accepts a
//! spec file (the pre-API `simulate --config` keys all parse; files that
//! omit `policy`/`predictor` now take the spec defaults `acpc`/`heuristic`
//! instead of the old loader's `lru`/none); explicit CLI flags override
//! the file.

use crate::api::{RunSpec, Runner};
use crate::cli::Args;
use crate::config::PredictorKind;
use anyhow::Result;
use std::path::Path;

const HELP: &str = "\
acpc simulate — run one cache simulation

OPTIONS:
    --policy <name>       L2 replacement policy [default: acpc]
    --predictor <kind>    none|heuristic|dnn|tcn [default: heuristic]
    --model <name>        artifact model override (tcn_flat, tcn_short, ...)
    --accesses <n>        trace length [default: 2000000]
    --profile <name>      gpt3ish|llama2ish|t5ish [default: gpt3ish]
    --scenario <name>     scenario-registry workload (see `acpc policies`)
    --prefetcher <name>   none|nextline|stride|correlation|composite
    --hierarchy <preset>  scaled|epyc7763 [default: scaled]
    --config <file.json>  RunSpec file to start from (see `acpc run --help`)
    --feedback <n>        online-learning interval in accesses (0 = off)
    --shards <n>          split the run across n set-partitioned worker
                          threads (power of two; exact aggregate stats) [default: 1]
    --seed <n>            RNG seed
    --json <path>         write the RunReport as JSON (schema acpc-run-v1,
                          embeds the resolved spec)
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&[
        "policy", "predictor", "model", "accesses", "profile", "scenario", "prefetcher",
        "hierarchy", "config", "feedback", "shards", "seed", "json", "help",
    ])?;
    if args.opt("profile").is_some() && args.opt("scenario").is_some() {
        anyhow::bail!("--profile and --scenario are mutually exclusive");
    }

    // The config file (if any) is the base; explicit flags override it.
    let mut spec = match args.opt("config") {
        Some(path) => RunSpec::from_file(Path::new(path))?,
        None => RunSpec::default(),
    };
    if let Some(p) = args.opt("policy") {
        spec.policy = p.to_string();
    }
    if let Some(k) = args.opt("predictor") {
        spec.predictor = PredictorKind::parse(k)?;
    }
    if let Some(m) = args.opt("model") {
        spec.model = Some(m.to_string());
    }
    if args.opt("accesses").is_some() {
        spec.accesses = Some(args.usize_or("accesses", 0)?);
    }
    if let Some(p) = args.opt("profile") {
        spec.profile = Some(p.to_string());
        // A config file may have set a scenario; an explicit profile
        // replaces the workload wholesale.
        spec.scenario = None;
    }
    if let Some(s) = args.opt("scenario") {
        spec.scenario = Some(s.to_string());
        spec.profile = None;
    }
    if let Some(p) = args.opt("prefetcher") {
        spec.hierarchy.prefetcher = Some(p.to_string());
    }
    if let Some(h) = args.opt("hierarchy") {
        spec.hierarchy.preset = Some(h.to_string());
    }
    if args.opt("feedback").is_some() {
        spec.feedback_interval = Some(args.usize_or("feedback", 0)?);
    }
    if args.opt("seed").is_some() {
        spec.seed = Some(args.u64_or("seed", 0)?);
    }
    if args.opt("shards").is_some() {
        spec.shards = args.usize_or("shards", 1)?;
    }

    let runner = Runner::new(spec)?;
    {
        let s = runner.spec();
        println!(
            "simulating: policy={} predictor={} accesses={} workload={} shards={}",
            s.policy,
            s.predictor.label(),
            s.accesses.unwrap_or(0),
            s.scenario.as_deref().or_else(|| s.profile.as_deref()).unwrap_or("gpt3ish"),
            s.shards,
        );
    }
    let report = runner.run()?;

    println!("\n{}", report.result.report.summary());
    println!("{}", report.counters_line());
    if let Some(path) = args.opt("json") {
        std::fs::write(path, report.to_json().to_pretty())?;
        println!("wrote {path}");
    }
    Ok(0)
}
