//! `acpc simulate` — one simulation run with full metric output.

use super::build_predictor;
use crate::cli::Args;
use crate::config::{ExperimentConfig, PredictorKind};
use crate::predictor::PredictorBox;
use crate::sim::{run_experiment, run_workload_sharded};
use anyhow::Result;
use std::path::Path;

const HELP: &str = "\
acpc simulate — run one cache simulation

OPTIONS:
    --policy <name>       L2 replacement policy [default: acpc]
    --predictor <kind>    none|heuristic|dnn|tcn [default: heuristic]
    --model <name>        artifact model override (tcn_flat, tcn_short, ...)
    --accesses <n>        trace length [default: 2000000]
    --profile <name>      gpt3ish|llama2ish|t5ish [default: gpt3ish]
    --scenario <name>     scenario-registry workload (see `acpc policies`)
    --prefetcher <name>   none|nextline|stride|correlation|composite
    --hierarchy <preset>  scaled|epyc7763 [default: scaled]
    --config <file.json>  JSON config overrides (see config module)
    --feedback <n>        online-learning interval in accesses (0 = off)
    --shards <n>          split the run across n set-partitioned worker
                          threads (power of two; exact aggregate stats) [default: 1]
    --seed <n>            RNG seed
    --json <path>         write the metrics report as JSON
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&[
        "policy", "predictor", "model", "accesses", "profile", "scenario", "prefetcher",
        "hierarchy", "config", "feedback", "shards", "seed", "json", "help",
    ])?;
    if args.opt("profile").is_some() && args.opt("scenario").is_some() {
        anyhow::bail!("--profile and --scenario are mutually exclusive");
    }

    let mut kind = PredictorKind::parse(&args.opt_or("predictor", "heuristic"))?;
    let mut cfg = ExperimentConfig::table1(&args.opt_or("policy", "acpc"), kind);
    if let Some(path) = args.opt("config") {
        cfg = ExperimentConfig::from_file(Path::new(path))?;
        // Explicitly-given CLI flags beat the file; otherwise the file is
        // authoritative — including for the predictor actually built below,
        // so the run matches the provenance the report records.
        if let Some(p) = args.opt("policy") {
            cfg.policy = p.to_string();
        }
        if args.opt("predictor").is_some() {
            cfg.predictor = kind;
        } else {
            kind = cfg.predictor;
        }
    }
    cfg.accesses = args.usize_or("accesses", cfg.accesses)?;
    cfg.feedback_interval = args.usize_or("feedback", cfg.feedback_interval)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.generator.seed = cfg.seed;
    if let Some(p) = args.opt("profile") {
        let profile = crate::trace::ModelProfile::by_name(p)
            .ok_or_else(|| anyhow::anyhow!("unknown profile '{p}'"))?;
        cfg.generator = crate::trace::GeneratorConfig::new(profile, cfg.seed);
        // A --config file may have set a scenario; the profile replaces
        // its generator wholesale, so drop the stale provenance.
        cfg.scenario = None;
    }
    if let Some(s) = args.opt("scenario") {
        cfg.set_scenario(s)?;
    }
    if let Some(p) = args.opt("prefetcher") {
        cfg.hierarchy.prefetcher = p.to_string();
    }
    if let Some(h) = args.opt("hierarchy") {
        let pf = cfg.hierarchy.prefetcher.clone();
        cfg.hierarchy = crate::mem::HierarchyConfig::by_name(h)
            .ok_or_else(|| anyhow::anyhow!("unknown hierarchy '{h}'"))?;
        cfg.hierarchy.prefetcher = pf;
    }
    if crate::policy::make_policy(&cfg.policy, 2, 2, 0).is_none() {
        anyhow::bail!("unknown policy '{}' (see `acpc policies`)", cfg.policy);
    }
    cfg.hierarchy.validate().map_err(|e| anyhow::anyhow!("invalid hierarchy geometry: {e}"))?;
    let shards = args.usize_or("shards", 1)?;
    if shards > 1 {
        cfg.hierarchy
            .validate_shards(shards)
            .map_err(|e| anyhow::anyhow!("--shards: {e}"))?;
    }

    let res = if shards > 1 {
        let model = args.opt("model").map(|s| s.to_string());
        let mk = move |_shard: usize| -> PredictorBox {
            super::build_predictor_or_heuristic(kind, model.as_deref(), "simulate")
        };
        println!(
            "simulating: policy={} predictor={} accesses={} workload={} prefetcher={} shards={}",
            cfg.policy,
            kind.label(),
            cfg.accesses,
            cfg.generator.profile.name,
            cfg.hierarchy.prefetcher,
            shards
        );
        let mut workload = cfg.workload();
        run_workload_sharded(&cfg, workload.as_mut(), shards, &mk, None)?.result
    } else {
        let mut predictor = build_predictor(kind, args.opt("model"))?;
        println!(
            "simulating: policy={} predictor={} accesses={} workload={} prefetcher={}",
            cfg.policy, predictor.name(), cfg.accesses, cfg.generator.profile.name, cfg.hierarchy.prefetcher
        );
        run_experiment(&cfg, &mut predictor)
    };

    println!("\n{}", res.report.summary());
    println!(
        "tokens={} emu={:.3} pred_batches={} online_steps={} wall={:.2}s ({:.2}M acc/s)",
        res.tokens,
        res.emu,
        res.prediction_batches,
        res.online_train_steps,
        res.wall_secs,
        res.accesses_per_sec / 1e6
    );
    if let Some(path) = args.opt("json") {
        let mut j = res.report.to_json();
        j.set("config", cfg.to_json());
        std::fs::write(path, j.to_pretty())?;
        println!("wrote {path}");
    }
    Ok(0)
}
