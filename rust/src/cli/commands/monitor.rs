//! `acpc monitor` — live telemetry: wrap a RunSpec with a subscribed bus,
//! attach to a running serve dashboard, or schema-validate an NDJSON
//! capture. Events follow the `acpc-telemetry-v1` schema.

use crate::api::{RunSpec, Runner};
use crate::cli::Args;
use crate::obs::http::{http_get, DASHBOARD_SCHEMA};
use crate::obs::{
    validate_ndjson, MonitorState, TelemetryBus, TelemetryEvent, TelemetrySubscriber,
};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

const HELP: &str = "\
acpc monitor — live telemetry (schema acpc-telemetry-v1)

Wraps a RunSpec with a subscribed telemetry bus and renders a refreshing
per-source health table while it runs; or attaches to the dashboard of a
live `acpc serve --dashboard <port>`; or validates a captured NDJSON
stream. With --ndjson, stdout carries exactly one event JSON per line
(the firehose) and all status goes to stderr — pipe it to a file, then
check it with --validate.

OPTIONS:
    --spec <file.json>   run the RunSpec with telemetry attached
    --attach <addr>      follow a serve dashboard (e.g. 127.0.0.1:7199)
    --validate <file>    schema-check an NDJSON capture and exit
    --ndjson             raw event stream on stdout instead of the table
    --interval-ms <n>    refresh/poll interval [default: 500]
    --seed <n>           override the spec's seed (--spec only)
    --accesses <n>       override the spec's trace length (--spec only)
    --shards <n>         override the spec's set-shard count (--spec only)
    --help";

pub fn run(args: &mut Args) -> Result<i32> {
    if args.flag("help") {
        println!("{HELP}");
        return Ok(0);
    }
    args.ensure_known(&[
        "spec", "attach", "validate", "ndjson", "interval-ms", "seed", "accesses", "shards",
        "help",
    ])?;
    let modes = [args.opt("spec"), args.opt("attach"), args.opt("validate")];
    if modes.iter().flatten().count() != 1 {
        anyhow::bail!(
            "exactly one of --spec, --attach, or --validate is required \
             (see `acpc monitor --help`)"
        );
    }
    let ndjson = args.flag("ndjson");
    let interval = Duration::from_millis(args.u64_or("interval-ms", 500)?);

    if let Some(path) = args.opt("validate") {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let n = validate_ndjson(&text)
            .with_context(|| format!("{path}: invalid acpc-telemetry-v1 stream"))?;
        println!("{path}: {n} events, schema acpc-telemetry-v1 OK");
        return Ok(0);
    }
    if let Some(addr) = args.opt("attach") {
        return attach(addr, ndjson, interval);
    }

    let path = args.opt("spec").expect("mode checked above");
    let mut spec = RunSpec::from_file(Path::new(path))?;
    if args.opt("seed").is_some() {
        spec.seed = Some(args.u64_or("seed", 0)?);
    }
    if args.opt("accesses").is_some() {
        spec.accesses = Some(args.usize_or("accesses", 0)?);
    }
    if args.opt("shards").is_some() {
        spec.shards = args.usize_or("shards", 1)?;
    }

    let bus = TelemetryBus::new();
    let sub = bus.subscribe();
    let runner = Runner::new(spec)?.with_telemetry(bus);
    crate::log_info!(
        "monitor: running {} with telemetry attached",
        runner.spec().name.as_deref().unwrap_or(path)
    );
    // The run stays on this thread (predictors may be thread-affine); the
    // monitor renders from its own.
    let stop = AtomicBool::new(false);
    let (report, state) = std::thread::scope(|s| {
        let handle = s.spawn(|| monitor_loop(sub, &stop, ndjson, interval));
        let report = runner.run();
        stop.store(true, Ordering::Release);
        let state = handle.join().expect("monitor thread panicked");
        (report, state)
    });
    let report = report?;
    if ndjson {
        crate::log_info!(
            "monitor: run complete — {} events, {} dropped",
            state.events,
            state.dropped
        );
    } else {
        println!("\n{}", report.result.report.summary());
        println!("{}", report.counters_line());
    }
    Ok(0)
}

/// Drain the subscriber until `stop`, rendering the table (or echoing
/// NDJSON) as events arrive; returns the final folded state.
fn monitor_loop(
    mut sub: TelemetrySubscriber,
    stop: &AtomicBool,
    ndjson: bool,
    interval: Duration,
) -> MonitorState {
    let mut state = MonitorState::new();
    let mut events = Vec::new();
    let stdout = std::io::stdout();
    loop {
        let stopping = stop.load(Ordering::Acquire);
        events.clear();
        sub.drain(&mut events);
        state.dropped = sub.dropped();
        let mut out = stdout.lock();
        for ev in &events {
            state.apply(ev);
            if ndjson {
                let _ = writeln!(out, "{}", ev.to_json().to_string());
            }
        }
        if !ndjson && (!events.is_empty() || stopping) {
            // Home + clear so the table refreshes in place.
            let _ = write!(out, "\x1b[H\x1b[2J{}", state.render_table());
        }
        let _ = out.flush();
        drop(out);
        if stopping {
            return state;
        }
        std::thread::sleep(interval);
    }
}

/// Follow a live dashboard: poll `/events?since=<n>` and fold locally, so
/// the table is the same one a `--spec` run renders.
fn attach(addr: &str, ndjson: bool, interval: Duration) -> Result<i32> {
    let health = http_get(addr, "/health")
        .with_context(|| format!("no dashboard at {addr} (serve with --dashboard <port>?)"))?;
    let h = Json::parse(health.trim()).context("malformed /health body")?;
    let schema = h.get("schema").and_then(Json::as_str).unwrap_or("?");
    if schema != DASHBOARD_SCHEMA {
        anyhow::bail!("{addr} speaks '{schema}', expected '{DASHBOARD_SCHEMA}'");
    }
    crate::log_info!("monitor: attached to http://{addr}/");
    let mut state = MonitorState::new();
    let mut since = 0u64;
    loop {
        // The dashboard disappearing (serve finished its linger) is the
        // normal way this loop ends.
        let body = match http_get(addr, &format!("/events?since={since}")) {
            Ok(b) => b,
            Err(e) => {
                crate::log_info!("monitor: dashboard gone ({e:#}); exiting");
                return Ok(0);
            }
        };
        let mut out = std::io::stdout().lock();
        for line in body.lines().filter(|l| !l.trim().is_empty()) {
            let ev = TelemetryEvent::from_json(&Json::parse(line)?)
                .context("dashboard sent a non-telemetry line")?;
            state.apply(&ev);
            since += 1;
            if ndjson {
                let _ = writeln!(out, "{line}");
            }
        }
        if !ndjson {
            let _ = write!(out, "\x1b[H\x1b[2J{}", state.render_table());
        }
        let _ = out.flush();
        drop(out);
        std::thread::sleep(interval);
    }
}
