//! Multi-threaded policy×scenario sweep runner.
//!
//! Fans the full experiment grid out over a scoped thread pool
//! ([`crate::util::pool`]): one cell = one [`crate::api::RunSpec`] executed
//! through the [`crate::api::Runner`] — the same front door the CLI and the
//! library use, so a sweep cell cannot drift from a standalone run. Cells
//! are completely independent — each derives its own seed deterministically
//! from the base seed and the cell coordinates ([`cell_seed`]), and its
//! runner builds workload, hierarchy and predictor inside the worker
//! thread. Results come back in grid order regardless of the thread count,
//! so a sweep at `-j 1` and `-j 8` is byte-identical (asserted by
//! `tests/integration_sweep.rs`).
//!
//! The per-cell predictor is selectable (`--predictor`): `auto`/`heuristic`
//! (artifact-free, the default), `tcn` (the TCN executed by the native
//! kernel over one process-wide weight snapshot shared by every worker and
//! shard thread, falling back to the heuristic with a warning when
//! artifacts are absent; `backend: pjrt` specs instead load PJRT inside
//! each worker thread — handles are thread-affine — cached per thread),
//! `adaptive` (heuristic + a per-cell drift controller closing the loop),
//! or `none`. Classic policies ignore the predictor entirely.

use super::engine::SimResult;
use crate::api::{run_farm, CacheMode, FarmConfig, FarmEntry, ReportStore, RunSpec};
use crate::config::PredictorKind;
use crate::metrics::{render_sweep, SweepRowView};
use crate::policy;
use crate::trace::{Scenario, SCENARIO_NAMES};
use crate::util::pool::default_threads;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// Predictor specs `--predictor` accepts.
pub const PREDICTOR_SPECS: &[&str] = &["auto", "heuristic", "tcn", "adaptive", "none"];

/// The sweep grid and its execution knobs.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub policies: Vec<String>,
    pub scenarios: Vec<String>,
    /// Accesses simulated per grid cell.
    pub accesses: usize,
    /// Worker threads (`-j`); cells queue onto the pool in grid order.
    pub threads: usize,
    /// Base seed; per-cell seeds derive from it deterministically.
    pub seed: u64,
    pub predict_batch: usize,
    /// Per-cell predictor spec (see [`PREDICTOR_SPECS`]). Only affects
    /// utility-consuming policies; classic policies run predictor-free.
    pub predictor: String,
    /// Set-shards *per cell* (`crate::sim::shard`): total worker threads
    /// ≈ `threads × shards`, letting a sweep use idle cores when the grid
    /// is smaller than the machine. 1 = classic single-threaded cells.
    pub shards: usize,
    /// Report-store mode for every cell ([`CacheMode::Off`] by default in
    /// the library — the `acpc sweep` CLI defaults to read-write). With
    /// caching on, a repeated grid serves every unchanged cell from the
    /// store and simulates nothing.
    pub cache: CacheMode,
    /// Store root; `None` = [`ReportStore::default_root`]. Ignored when
    /// `cache` is off.
    pub store: Option<PathBuf>,
}

impl SweepConfig {
    pub fn new(policies: Vec<String>, scenarios: Vec<String>) -> Self {
        Self {
            policies,
            scenarios,
            accesses: 400_000,
            threads: default_threads(),
            seed: 0xACDC_5EED,
            predict_batch: 256,
            predictor: "auto".into(),
            shards: 1,
            cache: CacheMode::Off,
            store: None,
        }
    }

    /// The default grid: Table-1-adjacent policies × every scenario.
    pub fn default_grid() -> Self {
        Self::new(
            ["lru", "srrip", "ship", "acpc"].iter().map(|s| s.to_string()).collect(),
            SCENARIO_NAMES.iter().map(|s| s.to_string()).collect(),
        )
    }
}

/// One completed grid cell.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub policy: String,
    pub scenario: String,
    /// The derived per-cell seed (provenance).
    pub seed: u64,
    /// The predictor that actually ran (e.g. `tcn`, `heuristic`,
    /// `heuristic(fallback)`, `adaptive(heuristic)`, `none`).
    pub predictor: String,
    /// Content address of the cell's resolved spec (the report-store key).
    pub spec_hash: String,
    /// `true` when the cell was served without simulation — from the
    /// report store, or deduped against an identical cell in this grid.
    pub cached: bool,
    pub result: SimResult,
}

/// Deterministic per-cell seed: FNV-1a over (base seed, policy, scenario)
/// with a splitmix64 finalizer, so neighbouring cells get well-separated
/// generator streams and the assignment of cells to threads is irrelevant.
pub fn cell_seed(base: u64, policy: &str, scenario: &str) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut fold = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    fold(&base.to_le_bytes());
    fold(policy.as_bytes());
    fold(b"/");
    fold(scenario.as_bytes());
    let mut z = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Does this policy consume predicted utilities at all?
fn policy_uses_predictor(policy: &str) -> bool {
    policy.starts_with("acpc") || policy == "mlpredict"
}

/// Resolve the cell's (predictor kind, adaptive-controller) pair from the
/// sweep-level spec. Classic policies always run predictor-free.
fn resolve_spec(spec: &str, policy: &str) -> (PredictorKind, bool) {
    if !policy_uses_predictor(policy) {
        return (PredictorKind::None, false);
    }
    match spec {
        "tcn" => (PredictorKind::Tcn, false),
        "adaptive" => (PredictorKind::Heuristic, true),
        "none" => (PredictorKind::None, false),
        // "auto" | "heuristic"
        _ => (PredictorKind::Heuristic, false),
    }
}

/// Validate the grid, then run every cell through the [`Runner`] on the
/// pool. Results are in grid order (scenarios outer, policies inner)
/// independent of `threads`.
pub fn run_sweep(cfg: &SweepConfig) -> Result<Vec<SweepCell>> {
    if cfg.policies.is_empty() || cfg.scenarios.is_empty() {
        bail!("sweep grid is empty (need at least one policy and one scenario)");
    }
    for p in &cfg.policies {
        if policy::make_policy(p, 2, 2, 0).is_none() {
            bail!("unknown policy '{p}' (see `acpc policies`)");
        }
    }
    for s in &cfg.scenarios {
        if Scenario::by_name(s).is_none() {
            bail!("unknown scenario '{s}' (known: {})", SCENARIO_NAMES.join(", "));
        }
    }
    if !PREDICTOR_SPECS.contains(&cfg.predictor.as_str()) {
        bail!("unknown predictor '{}' (known: {})", cfg.predictor, PREDICTOR_SPECS.join("|"));
    }
    if cfg.shards > 1 {
        // Fast-fail against the preset every cell currently uses
        // (scenario cells resolve onto the scaled hierarchy). This is a
        // convenience check only: each cell's runner re-validates its
        // actual hierarchy, so a future per-cell geometry override still
        // errors correctly — just later, inside the cell.
        crate::mem::HierarchyConfig::scaled()
            .validate_shards(cfg.shards)
            .map_err(|e| anyhow::anyhow!("--shards: {e}"))?;
    }

    // The sweep is a special case of the experiment farm: each cell builds
    // a RunSpec up front, the farm hashes/dedupes/executes them on the
    // pool (through the report store when caching is on), and results come
    // back in grid order (scenarios outer, policies inner).
    let n = cfg.policies.len() * cfg.scenarios.len();
    let mut entries = Vec::with_capacity(n);
    let mut coords = Vec::with_capacity(n);
    for scenario in &cfg.scenarios {
        for policy in &cfg.policies {
            let (kind, adaptive) = resolve_spec(&cfg.predictor, policy);
            let seed = cell_seed(cfg.seed, policy, scenario);
            let mut builder = RunSpec::builder()
                .scenario(scenario)
                .policy(policy)
                .predictor(kind)
                .accesses(cfg.accesses)
                .predict_batch(cfg.predict_batch)
                .seed(seed)
                .shards(cfg.shards.max(1));
            if adaptive {
                builder = builder.adaptive(true);
            }
            entries.push(FarmEntry {
                label: format!("{scenario}/{policy}"),
                spec: builder.build()?,
            });
            coords.push((policy.clone(), scenario.clone(), seed));
        }
    }
    let store = if cfg.cache.reads() {
        Some(match &cfg.store {
            Some(root) => ReportStore::open(root.clone()),
            None => ReportStore::open_default(),
        })
    } else {
        None
    };
    let farm =
        FarmConfig { threads: cfg.threads.max(1), store, cache: cfg.cache, base_seed: cfg.seed };
    let cells = run_farm(entries, &farm)?;
    Ok(cells
        .into_iter()
        .zip(coords)
        .map(|(c, (policy, scenario, seed))| SweepCell {
            policy,
            scenario,
            seed,
            predictor: c.report.predictor_effective.clone(),
            spec_hash: c.spec_hash,
            cached: c.cached,
            result: c.report.result,
        })
        .collect())
}

/// Render the finished grid as the aggregated metrics table (per-scenario
/// MPR baselines resolved against that scenario's `lru` cell when present).
pub fn render_cells(cells: &[SweepCell]) -> String {
    let rows: Vec<SweepRowView> = cells
        .iter()
        .map(|c| SweepRowView { policy: &c.policy, scenario: &c.scenario, report: &c.result.report })
        .collect();
    render_sweep(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_seeds_are_distinct_and_stable() {
        let a = cell_seed(1, "lru", "decode-heavy");
        assert_eq!(a, cell_seed(1, "lru", "decode-heavy"));
        assert_ne!(a, cell_seed(2, "lru", "decode-heavy"));
        assert_ne!(a, cell_seed(1, "srrip", "decode-heavy"));
        assert_ne!(a, cell_seed(1, "lru", "rag-embedding"));
        // Coordinate separator matters: ("ab","c") != ("a","bc").
        assert_ne!(cell_seed(1, "ab", "c"), cell_seed(1, "a", "bc"));
    }

    #[test]
    fn invalid_grid_rejected_before_running() {
        let cfg = SweepConfig::new(vec!["lru".into()], vec!["no-such-scenario".into()]);
        assert!(run_sweep(&cfg).is_err());
        let cfg = SweepConfig::new(vec!["no-such-policy".into()], vec!["decode-heavy".into()]);
        assert!(run_sweep(&cfg).is_err());
        let cfg = SweepConfig::new(vec![], vec![]);
        assert!(run_sweep(&cfg).is_err());
        let mut cfg = SweepConfig::new(vec!["lru".into()], vec!["decode-heavy".into()]);
        cfg.predictor = "no-such-predictor".into();
        assert!(run_sweep(&cfg).is_err());
    }

    #[test]
    fn small_grid_runs_in_order() {
        let mut cfg = SweepConfig::new(
            vec!["lru".into(), "srrip".into()],
            vec!["decode-heavy".into(), "rag-embedding".into()],
        );
        cfg.accesses = 15_000;
        cfg.threads = 2;
        let cells = run_sweep(&cfg).unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!((cells[0].scenario.as_str(), cells[0].policy.as_str()), ("decode-heavy", "lru"));
        assert_eq!((cells[3].scenario.as_str(), cells[3].policy.as_str()), ("rag-embedding", "srrip"));
        for c in &cells {
            assert_eq!(c.result.report.accesses, 15_000);
            assert_eq!(c.predictor, "none", "classic policies run predictor-free");
        }
        let table = render_cells(&cells);
        assert!(table.contains("decode-heavy") && table.contains("srrip"), "{table}");
    }

    #[test]
    fn predictor_spec_resolves_per_policy() {
        assert_eq!(resolve_spec("auto", "lru"), (PredictorKind::None, false));
        assert_eq!(resolve_spec("tcn", "srrip"), (PredictorKind::None, false));
        assert_eq!(resolve_spec("auto", "acpc"), (PredictorKind::Heuristic, false));
        assert_eq!(resolve_spec("tcn", "acpc"), (PredictorKind::Tcn, false));
        assert_eq!(resolve_spec("adaptive", "acpc"), (PredictorKind::Heuristic, true));
        assert_eq!(resolve_spec("none", "acpc"), (PredictorKind::None, false));
        assert_eq!(resolve_spec("auto", "mlpredict"), (PredictorKind::Heuristic, false));
    }

    #[test]
    fn sharded_cells_match_unsharded_for_classic_policies() {
        let mut cfg = SweepConfig::new(vec!["lru".into()], vec!["decode-heavy".into()]);
        cfg.accesses = 20_000;
        cfg.threads = 1;
        let plain = run_sweep(&cfg).unwrap();
        cfg.shards = 2;
        let sharded = run_sweep(&cfg).unwrap();
        // decode-heavy runs the composite prefetcher, whose history tables
        // are per-shard — so the *hit-rate* aggregates may differ slightly,
        // but the cell must complete with the full access count and stay
        // deterministic.
        assert_eq!(sharded[0].result.report.accesses, 20_000);
        assert_eq!(plain[0].result.tokens, sharded[0].result.tokens);
        let again = run_sweep(&cfg).unwrap();
        assert_eq!(
            sharded[0].result.report.to_json().to_pretty(),
            again[0].result.report.to_json().to_pretty(),
            "sharded cells must be deterministic per shard count"
        );
    }

    /// A repeated grid with the store attached simulates nothing: every
    /// cell comes back `cached` with byte-identical metrics.
    #[test]
    fn repeated_sweep_is_fully_cached_and_byte_identical() {
        let dir = std::env::temp_dir().join("acpc_sweep_store_unit");
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = SweepConfig::new(
            vec!["lru".into(), "acpc".into()],
            vec!["decode-heavy".into()],
        );
        cfg.accesses = 10_000;
        cfg.threads = 2;
        cfg.cache = CacheMode::ReadWrite;
        cfg.store = Some(dir.clone());
        let cold = run_sweep(&cfg).unwrap();
        assert!(cold.iter().all(|c| !c.cached), "cold grid must simulate");
        let warm = run_sweep(&cfg).unwrap();
        assert!(warm.iter().all(|c| c.cached), "warm grid must serve from the store");
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.spec_hash, b.spec_hash);
            assert_eq!(
                a.result.report.to_json().to_pretty(),
                b.result.report.to_json().to_pretty()
            );
        }
        // CacheMode::Off bypasses the store entirely.
        cfg.cache = CacheMode::Off;
        let off = run_sweep(&cfg).unwrap();
        assert!(off.iter().all(|c| !c.cached));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adaptive_cells_run_and_are_deterministic() {
        let mut cfg = SweepConfig::new(vec!["acpc".into()], vec!["multi-tenant-mix".into()]);
        cfg.accesses = 30_000;
        cfg.threads = 2;
        cfg.predictor = "adaptive".into();
        let a = run_sweep(&cfg).unwrap();
        let b = run_sweep(&cfg).unwrap();
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].predictor, "adaptive(heuristic)");
        assert!(a[0].result.adapt_windows > 0, "controller must tick windows");
        assert_eq!(a[0].result.report.l2_hit_rate, b[0].result.report.l2_hit_rate);
        assert_eq!(a[0].result.drift_events, b[0].result.drift_events);
        assert_eq!(a[0].result.predictor_swaps, b[0].result.predictor_swaps);
    }
}
