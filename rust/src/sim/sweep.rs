//! Multi-threaded policy×scenario sweep runner.
//!
//! Fans the full experiment grid out over a scoped thread pool
//! ([`crate::util::pool`]): one cell = one policy run against one scenario
//! workload through the shared [`super::Engine`]. Cells are completely
//! independent — each derives its own seed deterministically from the base
//! seed and the cell coordinates ([`cell_seed`]), builds its own workload,
//! hierarchy and predictor inside the worker thread, and returns a
//! [`SimResult`]. Results come back in grid order regardless of the thread
//! count, so a sweep at `-j 1` and `-j 8` is byte-identical (asserted by
//! `tests/integration_sweep.rs`).
//!
//! The per-cell predictor is selectable (`--predictor`): `auto`/`heuristic`
//! (artifact-free, the default), `tcn` (the compiled TCN loaded from the
//! artifacts *inside* each worker thread — PJRT handles are thread-affine —
//! falling back to the heuristic with a warning when artifacts are absent),
//! `adaptive` (heuristic + a per-cell [`AdaptiveController`] closing the
//! loop), or `none`. Classic policies ignore the predictor entirely.

use super::engine::{run_experiment, run_workload_adaptive, SimResult};
use super::shard::run_workload_sharded;
use crate::adapt::{AdaptiveController, ControllerConfig};
use crate::config::{ExperimentConfig, PredictorKind};
use crate::metrics::{render_sweep, SweepRowView};
use crate::policy;
use crate::predictor::{HeuristicPredictor, PredictorBox};
use crate::trace::{Scenario, SCENARIO_NAMES};
use crate::util::pool::{default_threads, run_parallel};
use anyhow::{bail, Result};

/// Predictor specs `--predictor` accepts.
pub const PREDICTOR_SPECS: &[&str] = &["auto", "heuristic", "tcn", "adaptive", "none"];

/// The sweep grid and its execution knobs.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub policies: Vec<String>,
    pub scenarios: Vec<String>,
    /// Accesses simulated per grid cell.
    pub accesses: usize,
    /// Worker threads (`-j`); cells queue onto the pool in grid order.
    pub threads: usize,
    /// Base seed; per-cell seeds derive from it deterministically.
    pub seed: u64,
    pub predict_batch: usize,
    /// Per-cell predictor spec (see [`PREDICTOR_SPECS`]). Only affects
    /// utility-consuming policies; classic policies run predictor-free.
    pub predictor: String,
    /// Set-shards *per cell* ([`crate::sim::shard`]): total worker threads
    /// ≈ `threads × shards`, letting a sweep use idle cores when the grid
    /// is smaller than the machine. 1 = classic single-threaded cells.
    pub shards: usize,
}

impl SweepConfig {
    pub fn new(policies: Vec<String>, scenarios: Vec<String>) -> Self {
        Self {
            policies,
            scenarios,
            accesses: 400_000,
            threads: default_threads(),
            seed: 0xACDC_5EED,
            predict_batch: 256,
            predictor: "auto".into(),
            shards: 1,
        }
    }

    /// The default grid: Table-1-adjacent policies × every scenario.
    pub fn default_grid() -> Self {
        Self::new(
            ["lru", "srrip", "ship", "acpc"].iter().map(|s| s.to_string()).collect(),
            SCENARIO_NAMES.iter().map(|s| s.to_string()).collect(),
        )
    }
}

/// One completed grid cell.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub policy: String,
    pub scenario: String,
    /// The derived per-cell seed (provenance).
    pub seed: u64,
    /// The predictor that actually ran (e.g. `tcn`, `heuristic`,
    /// `heuristic(fallback)`, `adaptive(heuristic)`, `none`).
    pub predictor: String,
    pub result: SimResult,
}

/// Deterministic per-cell seed: FNV-1a over (base seed, policy, scenario)
/// with a splitmix64 finalizer, so neighbouring cells get well-separated
/// generator streams and the assignment of cells to threads is irrelevant.
pub fn cell_seed(base: u64, policy: &str, scenario: &str) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut fold = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    fold(&base.to_le_bytes());
    fold(policy.as_bytes());
    fold(b"/");
    fold(scenario.as_bytes());
    let mut z = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Does this policy consume predicted utilities at all?
fn policy_uses_predictor(policy: &str) -> bool {
    policy.starts_with("acpc") || policy == "mlpredict"
}

/// Resolve the cell's (predictor kind, adaptive-controller) pair from the
/// sweep-level spec. Classic policies always run predictor-free.
fn resolve_spec(spec: &str, policy: &str) -> (PredictorKind, bool) {
    if !policy_uses_predictor(policy) {
        return (PredictorKind::None, false);
    }
    match spec {
        "tcn" => (PredictorKind::Tcn, false),
        "adaptive" => (PredictorKind::Heuristic, true),
        "none" => (PredictorKind::None, false),
        // "auto" | "heuristic"
        _ => (PredictorKind::Heuristic, false),
    }
}

/// Load the compiled TCN inside the calling (worker) thread. `None` when
/// the AOT artifacts are unavailable or fail to load.
fn build_tcn_in_thread() -> Option<PredictorBox> {
    let rt = crate::predictor::ModelRuntime::load_from_artifacts("tcn").ok()?;
    Some(PredictorBox::Model(Box::new(rt)))
}

thread_local! {
    /// Per-worker-thread TCN cache: PJRT handles are thread-affine, and
    /// sweep cells never mutate weights (no online feedback in sweeps), so
    /// one artifact load + PJRT compile serves every cell the thread runs.
    /// Tri-state: outer `None` = never probed; `Some(None)` = probe failed
    /// (also permanent — a broken PJRT setup is not retried per cell);
    /// `Some(Some(_))` = loaded. The box is taken for the duration of a
    /// cell and put back afterwards.
    static THREAD_TCN: std::cell::RefCell<Option<Option<PredictorBox>>> =
        const { std::cell::RefCell::new(None) };
}

/// Fetch the thread's cached TCN, probing the artifacts at most once per
/// thread (success *and* failure are both cached).
fn take_thread_tcn() -> Option<PredictorBox> {
    THREAD_TCN.with(|c| {
        let mut slot = c.borrow_mut();
        if slot.is_none() {
            let loaded = build_tcn_in_thread();
            if loaded.is_none() {
                crate::log_warn!(
                    "sweep: TCN load failed in this worker thread; its tcn cells fall back \
                     to the heuristic predictor"
                );
            }
            *slot = Some(loaded);
        }
        slot.as_mut().unwrap().take()
    })
}

fn put_back_thread_tcn(p: PredictorBox) {
    THREAD_TCN.with(|c| *c.borrow_mut() = Some(Some(p)));
}

/// Validate the grid, then run every cell on the pool. Results are in grid
/// order (scenarios outer, policies inner) independent of `threads`.
pub fn run_sweep(cfg: &SweepConfig) -> Result<Vec<SweepCell>> {
    if cfg.policies.is_empty() || cfg.scenarios.is_empty() {
        bail!("sweep grid is empty (need at least one policy and one scenario)");
    }
    for p in &cfg.policies {
        if policy::make_policy(p, 2, 2, 0).is_none() {
            bail!("unknown policy '{p}' (see `acpc policies`)");
        }
    }
    for s in &cfg.scenarios {
        if Scenario::by_name(s).is_none() {
            bail!("unknown scenario '{s}' (known: {})", SCENARIO_NAMES.join(", "));
        }
    }
    if !PREDICTOR_SPECS.contains(&cfg.predictor.as_str()) {
        bail!("unknown predictor '{}' (known: {})", cfg.predictor, PREDICTOR_SPECS.join("|"));
    }
    if cfg.shards > 1 {
        // Fast-fail against the preset every cell currently uses
        // (`ExperimentConfig::for_scenario` → table1 → scaled). This is a
        // convenience check only: `run_workload_sharded` re-validates each
        // cell's actual hierarchy, so a future per-cell geometry override
        // still errors correctly — just later, inside the cell.
        crate::mem::HierarchyConfig::scaled()
            .validate_shards(cfg.shards)
            .map_err(|e| anyhow::anyhow!("--shards: {e}"))?;
    }
    // Probe artifact availability once for the whole grid, not once per
    // cell: when the bundle is absent every tcn cell would repeat the
    // filesystem walk and the fallback warning.
    let tcn_unavailable =
        cfg.predictor == "tcn" && !crate::runtime::artifacts_available();
    if tcn_unavailable {
        crate::log_warn!(
            "sweep: AOT artifacts unavailable; --predictor tcn cells fall back to the \
             heuristic predictor"
        );
    }

    let mut jobs = Vec::with_capacity(cfg.policies.len() * cfg.scenarios.len());
    for scenario in &cfg.scenarios {
        for policy in &cfg.policies {
            let policy = policy.clone();
            let scenario = scenario.clone();
            let spec = cfg.predictor.clone();
            let seed = cell_seed(cfg.seed, &policy, &scenario);
            let accesses = cfg.accesses;
            let predict_batch = cfg.predict_batch;
            let shards = cfg.shards.max(1);
            jobs.push(move || -> Result<SweepCell> {
                let (kind, adaptive) = resolve_spec(&spec, &policy);
                let mut ecfg = ExperimentConfig::for_scenario(&scenario, &policy, kind, seed)?;
                ecfg.accesses = accesses;
                ecfg.predict_batch = predict_batch;
                if shards > 1 {
                    // Sharded cell: the predictor is constructed inside each
                    // shard thread (PJRT handles are thread-affine), so the
                    // per-sweep-thread TCN cache does not apply here — tcn
                    // cells reload the artifacts per shard thread, falling
                    // back to the heuristic on load failure.
                    let (kind_eff, mut effective) = match kind {
                        PredictorKind::Tcn if tcn_unavailable => {
                            (PredictorKind::Heuristic, "heuristic(fallback)".to_string())
                        }
                        // Probe a real load once (cached per sweep thread) so
                        // the provenance label reflects loadability, not just
                        // the manifest's presence on disk. Individual shard
                        // threads can still fail and fall back with a warning.
                        PredictorKind::Tcn => match take_thread_tcn() {
                            Some(p) => {
                                put_back_thread_tcn(p);
                                (PredictorKind::Tcn, "tcn".to_string())
                            }
                            None => {
                                (PredictorKind::Heuristic, "heuristic(fallback)".to_string())
                            }
                        },
                        PredictorKind::Heuristic => {
                            (PredictorKind::Heuristic, "heuristic".to_string())
                        }
                        _ => (PredictorKind::None, "none".to_string()),
                    };
                    ecfg.predictor = kind_eff;
                    let mk = move |_shard: usize| -> PredictorBox {
                        match kind_eff {
                            PredictorKind::Tcn => build_tcn_in_thread().unwrap_or_else(|| {
                                crate::log_warn!(
                                    "sweep: TCN load failed in a shard thread; falling back to \
                                     the heuristic predictor for this shard"
                                );
                                PredictorBox::Heuristic(HeuristicPredictor)
                            }),
                            PredictorKind::Heuristic => {
                                PredictorBox::Heuristic(HeuristicPredictor)
                            }
                            _ => PredictorBox::None,
                        }
                    };
                    let ccfg = if adaptive {
                        effective = format!("adaptive({effective})");
                        Some(ControllerConfig::default())
                    } else {
                        None
                    };
                    let mut workload = ecfg.workload();
                    let run = run_workload_sharded(
                        &ecfg,
                        workload.as_mut(),
                        shards,
                        &mk,
                        ccfg.as_ref(),
                    )?;
                    return Ok(SweepCell {
                        policy,
                        scenario,
                        seed,
                        predictor: effective,
                        result: run.result,
                    });
                }
                let (mut predictor, mut effective) = match kind {
                    PredictorKind::Tcn => {
                        let loaded = if tcn_unavailable { None } else { take_thread_tcn() };
                        match loaded {
                            Some(p) => (p, "tcn".to_string()),
                            // Fallback already warned about: grid-level for
                            // absent artifacts, once per thread for load
                            // failures (take_thread_tcn).
                            None => {
                                ecfg.predictor = PredictorKind::Heuristic;
                                (
                                    PredictorBox::Heuristic(HeuristicPredictor),
                                    "heuristic(fallback)".to_string(),
                                )
                            }
                        }
                    }
                    PredictorKind::Heuristic => {
                        (PredictorBox::Heuristic(HeuristicPredictor), "heuristic".to_string())
                    }
                    _ => (PredictorBox::None, "none".to_string()),
                };
                let result = if adaptive {
                    effective = format!("adaptive({effective})");
                    let mut controller = AdaptiveController::new(ControllerConfig::default());
                    let mut workload = ecfg.workload();
                    run_workload_adaptive(
                        &ecfg,
                        workload.as_mut(),
                        &mut predictor,
                        Some(&mut controller),
                    )
                } else {
                    run_experiment(&ecfg, &mut predictor)
                };
                if effective == "tcn" {
                    // Return the loaded model to the thread cache for the
                    // next cell (weights untouched — sweeps run no online
                    // feedback, so reuse cannot leak state between cells).
                    put_back_thread_tcn(predictor);
                }
                Ok(SweepCell { policy, scenario, seed, predictor: effective, result })
            });
        }
    }
    run_parallel(cfg.threads.max(1), jobs).into_iter().collect()
}

/// Render the finished grid as the aggregated metrics table (per-scenario
/// MPR baselines resolved against that scenario's `lru` cell when present).
pub fn render_cells(cells: &[SweepCell]) -> String {
    let rows: Vec<SweepRowView> = cells
        .iter()
        .map(|c| SweepRowView { policy: &c.policy, scenario: &c.scenario, report: &c.result.report })
        .collect();
    render_sweep(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_seeds_are_distinct_and_stable() {
        let a = cell_seed(1, "lru", "decode-heavy");
        assert_eq!(a, cell_seed(1, "lru", "decode-heavy"));
        assert_ne!(a, cell_seed(2, "lru", "decode-heavy"));
        assert_ne!(a, cell_seed(1, "srrip", "decode-heavy"));
        assert_ne!(a, cell_seed(1, "lru", "rag-embedding"));
        // Coordinate separator matters: ("ab","c") != ("a","bc").
        assert_ne!(cell_seed(1, "ab", "c"), cell_seed(1, "a", "bc"));
    }

    #[test]
    fn invalid_grid_rejected_before_running() {
        let cfg = SweepConfig::new(vec!["lru".into()], vec!["no-such-scenario".into()]);
        assert!(run_sweep(&cfg).is_err());
        let cfg = SweepConfig::new(vec!["no-such-policy".into()], vec!["decode-heavy".into()]);
        assert!(run_sweep(&cfg).is_err());
        let cfg = SweepConfig::new(vec![], vec![]);
        assert!(run_sweep(&cfg).is_err());
        let mut cfg = SweepConfig::new(vec!["lru".into()], vec!["decode-heavy".into()]);
        cfg.predictor = "no-such-predictor".into();
        assert!(run_sweep(&cfg).is_err());
    }

    #[test]
    fn small_grid_runs_in_order() {
        let mut cfg = SweepConfig::new(
            vec!["lru".into(), "srrip".into()],
            vec!["decode-heavy".into(), "rag-embedding".into()],
        );
        cfg.accesses = 15_000;
        cfg.threads = 2;
        let cells = run_sweep(&cfg).unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!((cells[0].scenario.as_str(), cells[0].policy.as_str()), ("decode-heavy", "lru"));
        assert_eq!((cells[3].scenario.as_str(), cells[3].policy.as_str()), ("rag-embedding", "srrip"));
        for c in &cells {
            assert_eq!(c.result.report.accesses, 15_000);
            assert_eq!(c.predictor, "none", "classic policies run predictor-free");
        }
        let table = render_cells(&cells);
        assert!(table.contains("decode-heavy") && table.contains("srrip"), "{table}");
    }

    #[test]
    fn predictor_spec_resolves_per_policy() {
        assert_eq!(resolve_spec("auto", "lru"), (PredictorKind::None, false));
        assert_eq!(resolve_spec("tcn", "srrip"), (PredictorKind::None, false));
        assert_eq!(resolve_spec("auto", "acpc"), (PredictorKind::Heuristic, false));
        assert_eq!(resolve_spec("tcn", "acpc"), (PredictorKind::Tcn, false));
        assert_eq!(resolve_spec("adaptive", "acpc"), (PredictorKind::Heuristic, true));
        assert_eq!(resolve_spec("none", "acpc"), (PredictorKind::None, false));
        assert_eq!(resolve_spec("auto", "mlpredict"), (PredictorKind::Heuristic, false));
    }

    #[test]
    fn sharded_cells_match_unsharded_for_classic_policies() {
        let mut cfg = SweepConfig::new(vec!["lru".into()], vec!["decode-heavy".into()]);
        cfg.accesses = 20_000;
        cfg.threads = 1;
        let plain = run_sweep(&cfg).unwrap();
        cfg.shards = 2;
        let sharded = run_sweep(&cfg).unwrap();
        // decode-heavy runs the composite prefetcher, whose history tables
        // are per-shard — so the *hit-rate* aggregates may differ slightly,
        // but the cell must complete with the full access count and stay
        // deterministic.
        assert_eq!(sharded[0].result.report.accesses, 20_000);
        assert_eq!(plain[0].result.tokens, sharded[0].result.tokens);
        let again = run_sweep(&cfg).unwrap();
        assert_eq!(
            sharded[0].result.report.to_json().to_pretty(),
            again[0].result.report.to_json().to_pretty(),
            "sharded cells must be deterministic per shard count"
        );
    }

    #[test]
    fn adaptive_cells_run_and_are_deterministic() {
        let mut cfg = SweepConfig::new(vec!["acpc".into()], vec!["multi-tenant-mix".into()]);
        cfg.accesses = 30_000;
        cfg.threads = 2;
        cfg.predictor = "adaptive".into();
        let a = run_sweep(&cfg).unwrap();
        let b = run_sweep(&cfg).unwrap();
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].predictor, "adaptive(heuristic)");
        assert!(a[0].result.adapt_windows > 0, "controller must tick windows");
        assert_eq!(a[0].result.report.l2_hit_rate, b[0].result.report.l2_hit_rate);
        assert_eq!(a[0].result.drift_events, b[0].result.drift_events);
        assert_eq!(a[0].result.predictor_swaps, b[0].result.predictor_swaps);
    }
}
