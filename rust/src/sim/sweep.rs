//! Multi-threaded policy×scenario sweep runner.
//!
//! Fans the full experiment grid out over a scoped thread pool
//! ([`crate::util::pool`]): one cell = one policy run against one scenario
//! workload through the shared [`super::Engine`]. Cells are completely
//! independent — each derives its own seed deterministically from the base
//! seed and the cell coordinates ([`cell_seed`]), builds its own workload,
//! hierarchy and predictor inside the worker thread, and returns a
//! [`SimResult`]. Results come back in grid order regardless of the thread
//! count, so a sweep at `-j 1` and `-j 8` is byte-identical (asserted by
//! `tests/integration_sweep.rs`).

use super::engine::{run_experiment, SimResult};
use crate::config::{ExperimentConfig, PredictorKind};
use crate::metrics::{render_sweep, SweepRowView};
use crate::policy;
use crate::predictor::{HeuristicPredictor, PredictorBox};
use crate::trace::{Scenario, SCENARIO_NAMES};
use crate::util::pool::{default_threads, run_parallel};
use anyhow::{bail, Result};

/// The sweep grid and its execution knobs.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub policies: Vec<String>,
    pub scenarios: Vec<String>,
    /// Accesses simulated per grid cell.
    pub accesses: usize,
    /// Worker threads (`-j`); cells queue onto the pool in grid order.
    pub threads: usize,
    /// Base seed; per-cell seeds derive from it deterministically.
    pub seed: u64,
    pub predict_batch: usize,
}

impl SweepConfig {
    pub fn new(policies: Vec<String>, scenarios: Vec<String>) -> Self {
        Self {
            policies,
            scenarios,
            accesses: 400_000,
            threads: default_threads(),
            seed: 0xACDC_5EED,
            predict_batch: 256,
        }
    }

    /// The default grid: Table-1-adjacent policies × every scenario.
    pub fn default_grid() -> Self {
        Self::new(
            ["lru", "srrip", "ship", "acpc"].iter().map(|s| s.to_string()).collect(),
            SCENARIO_NAMES.iter().map(|s| s.to_string()).collect(),
        )
    }
}

/// One completed grid cell.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub policy: String,
    pub scenario: String,
    /// The derived per-cell seed (provenance).
    pub seed: u64,
    pub result: SimResult,
}

/// Deterministic per-cell seed: FNV-1a over (base seed, policy, scenario)
/// with a splitmix64 finalizer, so neighbouring cells get well-separated
/// generator streams and the assignment of cells to threads is irrelevant.
pub fn cell_seed(base: u64, policy: &str, scenario: &str) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut fold = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    fold(&base.to_le_bytes());
    fold(policy.as_bytes());
    fold(b"/");
    fold(scenario.as_bytes());
    let mut z = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Utility-consuming policies get the heuristic predictor in sweeps (no
/// artifacts required, constructible inside any worker thread); classic
/// policies run predictor-free.
fn predictor_kind_for(policy: &str) -> PredictorKind {
    if policy.starts_with("acpc") || policy == "mlpredict" {
        PredictorKind::Heuristic
    } else {
        PredictorKind::None
    }
}

/// Validate the grid, then run every cell on the pool. Results are in grid
/// order (scenarios outer, policies inner) independent of `threads`.
pub fn run_sweep(cfg: &SweepConfig) -> Result<Vec<SweepCell>> {
    if cfg.policies.is_empty() || cfg.scenarios.is_empty() {
        bail!("sweep grid is empty (need at least one policy and one scenario)");
    }
    for p in &cfg.policies {
        if policy::make_policy(p, 2, 2, 0).is_none() {
            bail!("unknown policy '{p}' (see `acpc policies`)");
        }
    }
    for s in &cfg.scenarios {
        if Scenario::by_name(s).is_none() {
            bail!("unknown scenario '{s}' (known: {})", SCENARIO_NAMES.join(", "));
        }
    }

    let mut jobs = Vec::with_capacity(cfg.policies.len() * cfg.scenarios.len());
    for scenario in &cfg.scenarios {
        for policy in &cfg.policies {
            let policy = policy.clone();
            let scenario = scenario.clone();
            let seed = cell_seed(cfg.seed, &policy, &scenario);
            let accesses = cfg.accesses;
            let predict_batch = cfg.predict_batch;
            jobs.push(move || -> Result<SweepCell> {
                let kind = predictor_kind_for(&policy);
                let mut ecfg = ExperimentConfig::for_scenario(&scenario, &policy, kind, seed)?;
                ecfg.accesses = accesses;
                ecfg.predict_batch = predict_batch;
                let mut predictor = match kind {
                    PredictorKind::Heuristic => PredictorBox::Heuristic(HeuristicPredictor),
                    _ => PredictorBox::None,
                };
                let result = run_experiment(&ecfg, &mut predictor);
                Ok(SweepCell { policy, scenario, seed, result })
            });
        }
    }
    run_parallel(cfg.threads.max(1), jobs).into_iter().collect()
}

/// Render the finished grid as the aggregated metrics table (per-scenario
/// MPR baselines resolved against that scenario's `lru` cell when present).
pub fn render_cells(cells: &[SweepCell]) -> String {
    let rows: Vec<SweepRowView> = cells
        .iter()
        .map(|c| SweepRowView { policy: &c.policy, scenario: &c.scenario, report: &c.result.report })
        .collect();
    render_sweep(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_seeds_are_distinct_and_stable() {
        let a = cell_seed(1, "lru", "decode-heavy");
        assert_eq!(a, cell_seed(1, "lru", "decode-heavy"));
        assert_ne!(a, cell_seed(2, "lru", "decode-heavy"));
        assert_ne!(a, cell_seed(1, "srrip", "decode-heavy"));
        assert_ne!(a, cell_seed(1, "lru", "rag-embedding"));
        // Coordinate separator matters: ("ab","c") != ("a","bc").
        assert_ne!(cell_seed(1, "ab", "c"), cell_seed(1, "a", "bc"));
    }

    #[test]
    fn invalid_grid_rejected_before_running() {
        let cfg = SweepConfig::new(vec!["lru".into()], vec!["no-such-scenario".into()]);
        assert!(run_sweep(&cfg).is_err());
        let cfg = SweepConfig::new(vec!["no-such-policy".into()], vec!["decode-heavy".into()]);
        assert!(run_sweep(&cfg).is_err());
        let cfg = SweepConfig::new(vec![], vec![]);
        assert!(run_sweep(&cfg).is_err());
    }

    #[test]
    fn small_grid_runs_in_order() {
        let mut cfg = SweepConfig::new(
            vec!["lru".into(), "srrip".into()],
            vec!["decode-heavy".into(), "rag-embedding".into()],
        );
        cfg.accesses = 15_000;
        cfg.threads = 2;
        let cells = run_sweep(&cfg).unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!((cells[0].scenario.as_str(), cells[0].policy.as_str()), ("decode-heavy", "lru"));
        assert_eq!((cells[3].scenario.as_str(), cells[3].policy.as_str()), ("rag-embedding", "srrip"));
        for c in &cells {
            assert_eq!(c.result.report.accesses, 15_000);
        }
        let table = render_cells(&cells);
        assert!(table.contains("decode-heavy") && table.contains("srrip"), "{table}");
    }
}
