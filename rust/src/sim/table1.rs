//! The full Table 1 reproduction pipeline (paper §4.4):
//!
//! 1. generate a GPT-style training trace, extract features + labels,
//!    split 70/15/15;
//! 2. train the TCN (ACPC) and the DNN (ML-Predict) with the compiled Adam
//!    step — all from rust;
//! 3. evaluate each policy's "final loss" (trained models: training-curve
//!    end; LRU/RRIP: implicit-predictor BCE on the test split);
//! 4. simulate the four Table 1 policies on the evaluation workload and
//!    assemble the paper's metric columns (CHR/PPR/MPR/TGT/loss/stability).
//!
//! Scaled by [`Table1Scale`] so smoke tests, benches and the full
//! reproduction share one code path.

use crate::config::{ExperimentConfig, PredictorKind};
use crate::metrics::{MetricsReport, Row, ThroughputModel};
use crate::predictor::{Dataset, GeometryHints, ModelRuntime, PredictorBox};
use crate::runtime::{Engine, Manifest};
use crate::sim::run_experiment;
use crate::trace::TraceGenerator;
use crate::training::{implicit_loss, train, ImplicitKind, TrainConfig};
use anyhow::{Context, Result};

/// Knobs that scale the pipeline without changing its shape.
#[derive(Debug, Clone)]
pub struct Table1Scale {
    /// Accesses in the training trace.
    pub train_accesses: usize,
    /// Keep 1/k of training-trace accesses as samples.
    pub sample_every: usize,
    /// Accesses in each evaluation simulation.
    pub eval_accesses: usize,
    pub epochs: usize,
    pub patience: usize,
    pub max_batches_per_epoch: usize,
    pub seed: u64,
}

impl Table1Scale {
    /// Full paper-scale reproduction (minutes of wall time).
    pub fn full() -> Self {
        Self {
            train_accesses: 1_200_000,
            sample_every: 6,
            eval_accesses: 2_000_000,
            epochs: 80,
            patience: 10,
            max_batches_per_epoch: 120,
            seed: 0xAC9C_2025,
        }
    }

    /// Seconds-scale smoke (tests).
    pub fn smoke() -> Self {
        Self {
            train_accesses: 120_000,
            sample_every: 4,
            eval_accesses: 120_000,
            epochs: 4,
            patience: 0,
            max_batches_per_epoch: 10,
            seed: 0xAC9C_2025,
        }
    }
}

/// Everything the bench/CLI needs to print the table and the deltas.
#[derive(Debug, Clone)]
pub struct Table1Output {
    pub rows: Vec<Row>,
    pub reports: Vec<MetricsReport>,
    pub tcn_curve: Vec<f64>,
    pub dnn_curve: Vec<f64>,
    pub tcn_test_loss: f64,
    pub dnn_test_loss: f64,
}

impl Table1Output {
    /// The abstract's headline deltas (ACPC row vs ML-Predict row).
    pub fn headline_deltas(&self) -> String {
        let ml = &self.rows[2];
        let ours = &self.rows[3];
        format!(
            "vs ML-Predict: pollution {:+.1}% (paper −41.7%), CHR {:+.1}pp (paper +~7.3pp/8.9%), \
             MPR delta {:+.1}pp (paper 15.5→24.8), TGT {:+.1}% (paper +15.9%)",
            (ours.ppr / ml.ppr - 1.0) * 100.0,
            ours.chr - ml.chr,
            ours.mpr - ml.mpr,
            (ours.tgt / ml.tgt - 1.0) * 100.0,
        )
    }
}

/// Run the pipeline. Requires built artifacts; errors out otherwise.
pub fn run_table1(scale: &Table1Scale) -> Result<Table1Output> {
    let dir = crate::runtime::artifacts_dir()
        .context("artifacts/ not found — run `make artifacts` first")?;
    let manifest = Manifest::load(&dir)?;
    let engine = Engine::cpu()?;

    // ---- 1. dataset -------------------------------------------------------
    let base = ExperimentConfig::table1("lru", PredictorKind::None);
    let mut gcfg = base.generator.clone();
    gcfg.seed = scale.seed ^ 0x7717; // training trace ≠ eval trace
    let geom = GeometryHints::from_generator(&gcfg);
    let window = manifest.model("tcn")?.window;
    crate::log_info!("table1: generating training trace ({} accesses)", scale.train_accesses);
    let trace = TraceGenerator::new(gcfg).generate(scale.train_accesses);
    let ds = Dataset::build(&trace, window, geom, 4096, scale.sample_every);
    let split = ds.split(scale.seed);
    crate::log_info!(
        "table1: dataset n={} positive_rate={:.3}",
        ds.n,
        ds.positive_rate()
    );

    // ---- 2. train TCN + DNN ----------------------------------------------
    let tcfg = TrainConfig {
        epochs: scale.epochs,
        patience: scale.patience,
        max_batches_per_epoch: scale.max_batches_per_epoch,
        seed: scale.seed,
        verbose_every: 10,
    };
    let mut tcn = ModelRuntime::load(&engine, &manifest, "tcn")?;
    let tcn_res = train(&mut tcn, &ds, &split, &tcfg);
    let mut dnn = ModelRuntime::load(&engine, &manifest, "dnn")?;
    let dnn_res = train(&mut dnn, &ds, &split, &tcfg);

    // ---- 3. losses ---------------------------------------------------------
    let tcn_test = crate::training::eval_split(&tcn, &ds, &split.test);
    let dnn_test = crate::training::eval_split(&dnn, &ds, &split.test);
    let lru_loss = implicit_loss(ImplicitKind::Lru, &ds, &split.test);
    let rrip_loss = implicit_loss(ImplicitKind::Rrip, &ds, &split.test);

    // ---- 4. simulate the four policies ------------------------------------
    let mk_cfg = |policy: &str, predictor: PredictorKind| {
        let mut c = ExperimentConfig::table1(policy, predictor);
        c.accesses = scale.eval_accesses;
        c.seed = scale.seed;
        c.generator.seed = scale.seed;
        c
    };
    crate::log_info!("table1: simulating lru/srrip/mlpredict/acpc ({} accesses each)", scale.eval_accesses);
    let lru = run_experiment(&mk_cfg("lru", PredictorKind::None), &mut PredictorBox::None);
    let srrip = run_experiment(&mk_cfg("srrip", PredictorKind::None), &mut PredictorBox::None);
    let mut dnn_box = PredictorBox::Model(Box::new(dnn));
    let mlp = run_experiment(&mk_cfg("mlpredict", PredictorKind::Dnn), &mut dnn_box);
    let mut tcn_box = PredictorBox::Model(Box::new(tcn));
    let acpc = run_experiment(&mk_cfg("acpc", PredictorKind::Tcn), &mut tcn_box);

    // ---- 5. assemble rows --------------------------------------------------
    // TGT calibration: anchor LRU at the paper's 187 tok/s.
    let lru_mem = ThroughputModel::mem_cycles_per_token(lru.report.total_latency, lru.tokens);
    let tm = ThroughputModel::calibrated(lru_mem);
    let tgt = |r: &crate::sim::SimResult| {
        tm.tokens_per_sec(ThroughputModel::mem_cycles_per_token(r.report.total_latency, r.tokens))
    };
    // NaN = undefined baseline; `render_table1` shows it as `n/a`.
    let mpr = |r: &crate::sim::SimResult| {
        r.report.miss_penalty_reduction_vs(&lru.report).unwrap_or(f64::NAN)
    };

    let rows = vec![
        Row {
            model: "LRU Baseline".into(),
            chr: lru.report.l2_hit_rate * 100.0,
            ppr: lru.report.l2_pollution_ratio * 100.0,
            mpr: 0.0,
            tgt: tgt(&lru),
            final_loss: lru_loss,
            stability: "Moderate".into(),
        },
        Row {
            model: "RRIP (Static)".into(),
            chr: srrip.report.l2_hit_rate * 100.0,
            ppr: srrip.report.l2_pollution_ratio * 100.0,
            mpr: mpr(&srrip),
            tgt: tgt(&srrip),
            final_loss: rrip_loss,
            stability: "Moderate".into(),
        },
        Row {
            model: "ML-Predict (DNN)".into(),
            chr: mlp.report.l2_hit_rate * 100.0,
            ppr: mlp.report.l2_pollution_ratio * 100.0,
            mpr: mpr(&mlp),
            tgt: tgt(&mlp),
            final_loss: dnn_res.final_train_loss,
            stability: dnn_res.stability(),
        },
        Row {
            model: "Temporal CNN (Ours)".into(),
            chr: acpc.report.l2_hit_rate * 100.0,
            ppr: acpc.report.l2_pollution_ratio * 100.0,
            mpr: mpr(&acpc),
            tgt: tgt(&acpc),
            final_loss: tcn_res.final_train_loss,
            stability: tcn_res.stability(),
        },
    ];

    Ok(Table1Output {
        rows,
        reports: vec![lru.report, srrip.report, mlp.report, acpc.report],
        tcn_curve: tcn_res.train_curve,
        dnn_curve: dnn_res.train_curve,
        tcn_test_loss: tcn_test,
        dnn_test_loss: dnn_test,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline shape test: ordering of the four rows must match the
    /// paper on CHR (ascending) and PPR (descending). Smoke scale — the
    /// full run lives in the bench.
    #[test]
    fn smoke_table1_ordering() {
        if crate::runtime::artifacts_dir().is_none() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let out = run_table1(&Table1Scale::smoke()).unwrap();
        assert_eq!(out.rows.len(), 4);
        let chr: Vec<f64> = out.rows.iter().map(|r| r.chr).collect();
        // ACPC must beat LRU decisively; learned rows must beat LRU.
        assert!(chr[3] > chr[0] + 1.0, "acpc {chr:?}");
        assert!(out.rows[3].ppr < out.rows[0].ppr, "pollution must drop: {:?}", out.rows);
        // Loss column ordering (learned beat implicit baselines).
        assert!(out.rows[3].final_loss < out.rows[0].final_loss);
        assert!(out.tcn_curve.len() >= 3);
        assert!(!out.headline_deltas().is_empty());
    }
}
