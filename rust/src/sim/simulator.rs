//! The simulation loop binding everything together.
//!
//! Prediction is *asynchronous and batched*, mirroring the paper's pipeline
//! (§3.1): every L2-relevant access enqueues a prediction request; when
//! `predict_batch` requests have accumulated, the predictor runs once and
//! the resulting utilities update (a) a bounded line→utility cache consulted
//! at fill time and (b) the utilities of still-resident L2 lines. A fill
//! therefore uses the *most recent completed* prediction for its line —
//! never a same-cycle oracle.
//!
//! The optional [`OnlineLearner`] implements §3.4: observed outcomes (was
//! the line actually reused within the horizon?) are turned into labeled
//! samples, and every `feedback_interval` accesses a few Adam steps run on
//! a replay buffer — the compiled train-step HLO, from rust.

use crate::config::ExperimentConfig;
use crate::mem::Hierarchy;
use crate::metrics::MetricsReport;
use crate::policy::AccessMeta;
use crate::predictor::{FeatureExtractor, GeometryHints, PredictorBox, FEATURE_DIM};
use crate::trace::TraceGenerator;
use crate::util::rng::Xoshiro256;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::time::Instant;

/// Outcome of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub report: MetricsReport,
    pub tokens: u64,
    pub emu: f64,
    pub predictor: String,
    pub prediction_batches: u64,
    pub online_train_steps: u64,
    pub wall_secs: f64,
    /// Accesses simulated per wall-clock second (L3 perf metric).
    pub accesses_per_sec: f64,
}

/// Replay-buffer online learner (§3.4).
pub struct OnlineLearner {
    /// (features, label) samples awaiting training.
    buf_x: Vec<f32>,
    buf_y: Vec<f32>,
    row: usize,
    capacity: usize,
    /// In-flight observations: line → (enqueue position, features start).
    pending: VecDeque<(u64, u64, usize)>,
    /// Lines touched recently (for labeling): line → last touch position.
    last_touch: HashMap<u64, u64>,
    horizon: u64,
    pub steps_run: u64,
    rng: Xoshiro256,
}

impl OnlineLearner {
    pub fn new(row: usize, horizon: u64, seed: u64) -> Self {
        Self {
            buf_x: Vec::new(),
            buf_y: Vec::new(),
            row,
            capacity: 1 << 15,
            pending: VecDeque::new(),
            last_touch: HashMap::new(),
            horizon,
            steps_run: 0,
            rng: Xoshiro256::new(seed ^ 0xFEED),
        }
    }

    /// Record a touch and enqueue the access as a future training sample.
    pub fn observe(&mut self, pos: u64, line: u64, features: &[f32]) {
        self.last_touch.insert(line, pos);
        if self.buf_x.len() / self.row < self.capacity {
            let start = self.buf_x.len();
            self.buf_x.extend_from_slice(features);
            self.buf_y.push(f32::NAN); // resolved later
            self.pending.push_back((line, pos, start / self.row));
        }
        // Resolve matured observations.
        while let Some(&(l, p, idx)) = self.pending.front() {
            if pos.saturating_sub(p) < self.horizon {
                break;
            }
            let reused = self.last_touch.get(&l).map(|&t| t > p && t - p <= self.horizon).unwrap_or(false);
            self.buf_y[idx] = reused as u8 as f32;
            self.pending.pop_front();
        }
    }

    /// Run up to `steps` Adam steps on resolved samples. Returns mean loss.
    pub fn train(&mut self, model: &mut crate::predictor::ModelRuntime, steps: usize) -> Option<f32> {
        let b = model.mm.train.batch;
        let resolved: Vec<usize> =
            (0..self.buf_y.len()).filter(|&i| !self.buf_y[i].is_nan()).collect();
        if resolved.len() < b {
            return None;
        }
        let mut total = 0.0;
        for _ in 0..steps {
            let mut x = Vec::with_capacity(b * self.row);
            let mut y = Vec::with_capacity(b);
            for _ in 0..b {
                let i = *self.rng.choose(&resolved);
                x.extend_from_slice(&self.buf_x[i * self.row..(i + 1) * self.row]);
                y.push(self.buf_y[i]);
            }
            total += model.train_step(x, y).expect("online train step");
            self.steps_run += 1;
        }
        // Keep the buffer fresh: drop the oldest half when full.
        if self.buf_y.len() >= self.capacity {
            let keep = self.capacity / 2;
            let drop_n = self.buf_y.len() - keep;
            self.buf_x.drain(..drop_n * self.row);
            self.buf_y.drain(..drop_n);
            self.pending.clear(); // positions invalidated; restart labeling
        }
        Some(total / steps as f32)
    }
}

/// Run one experiment. The predictor is taken by value inside `PredictorBox`
/// so learned runs can feed the online learner.
pub fn run_experiment(cfg: &ExperimentConfig, predictor: &mut PredictorBox) -> SimResult {
    let t0 = Instant::now();
    let mut hier = Hierarchy::new(cfg.hierarchy.clone(), &cfg.policy);
    let geom = GeometryHints::from_generator(&cfg.generator);
    let window = predictor.window();
    let row = if window == 1 { FEATURE_DIM } else { window * FEATURE_DIM };
    let mut fx = FeatureExtractor::new(window.max(1), geom);
    let mut seq = vec![0.0f32; window.max(1) * FEATURE_DIM];

    // Oracle mode pre-materializes the trace for next-use annotation.
    let oracle = cfg.policy == "belady";
    let (trace_vec, next_use) = if oracle {
        let mut gen = TraceGenerator::new(cfg.generator.clone());
        let tv = gen.generate(cfg.accesses);
        let nu = super::oracle::annotate_next_use(&tv);
        (Some((tv, gen.tokens_done())), Some(nu))
    } else {
        (None, None)
    };
    let mut gen = TraceGenerator::new(cfg.generator.clone());

    // Pending prediction batch.
    let mut pend_x: Vec<f32> = Vec::with_capacity(cfg.predict_batch * row);
    let mut pend_lines: Vec<u64> = Vec::with_capacity(cfg.predict_batch);
    let mut prediction_batches = 0u64;

    let mut learner = if cfg.feedback_interval > 0 && predictor.model_mut().is_some() {
        Some(OnlineLearner::new(row, 4096, cfg.seed))
    } else {
        None
    };

    let mut emu_acc = 0.0;
    let mut emu_samples = 0u64;

    for i in 0..cfg.accesses {
        let a = match &trace_vec {
            Some((tv, _)) => tv[i],
            None => gen.next_access(),
        };
        let line = a.line();

        let mut meta = AccessMeta {
            line,
            pc: a.pc,
            kind: a.kind,
            is_prefetch: false,
            predicted_utility: None, // late-bound by the hierarchy's cache
            next_use: next_use.as_ref().map(|nu| nu[i]),
        };
        // Belady encoding: u64::MAX means "never" — keep as None.
        if meta.next_use == Some(u64::MAX) {
            meta.next_use = None;
        }

        hier.access(&a, &meta);

        if predictor.is_some() {
            fx.push(&a, &mut seq);
            let feats: &[f32] =
                if window == 1 { &seq[(fx.window() - 1) * FEATURE_DIM..] } else { &seq };
            pend_x.extend_from_slice(feats);
            pend_lines.push(line);
            if let Some(l) = learner.as_mut() {
                l.observe(i as u64, line, feats);
            }
            if pend_lines.len() >= cfg.predict_batch {
                let probs = predictor.predict(&pend_x, pend_lines.len());
                prediction_batches += 1;
                for (&l, &p) in pend_lines.iter().zip(&probs) {
                    hier.update_utility(l, p);
                }
                pend_x.clear();
                pend_lines.clear();
            }
        }

        // Online feedback (§3.4).
        if let (Some(l), true) =
            (learner.as_mut(), cfg.feedback_interval > 0 && i > 0 && i % cfg.feedback_interval == 0)
        {
            if let Some(model) = predictor.model_mut() {
                l.train(model, 2);
            }
        }

        // EMU sampling.
        if i % 8192 == 0 && i > 0 {
            let f = hier.l2.useful_fraction();
            if f.is_finite() {
                emu_acc += f;
                emu_samples += 1;
            }
        }
    }

    let tokens = match &trace_vec {
        Some((_, t)) => *t,
        None => gen.tokens_done(),
    };
    let emu = if emu_samples > 0 { emu_acc / emu_samples as f64 } else { f64::NAN };
    let report = MetricsReport::from_hierarchy(&cfg.name, &hier, tokens, emu);
    let wall = t0.elapsed().as_secs_f64();
    SimResult {
        report,
        tokens,
        emu,
        predictor: predictor.name(),
        prediction_batches,
        online_train_steps: learner.map(|l| l.steps_run).unwrap_or(0),
        wall_secs: wall,
        accesses_per_sec: cfg.accesses as f64 / wall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::predictor::HeuristicPredictor;

    #[test]
    fn smoke_run_all_classic_policies() {
        for policy in ["lru", "srrip", "dip", "ship", "plru", "random"] {
            let cfg = ExperimentConfig::smoke(policy);
            let mut p = PredictorBox::None;
            let r = run_experiment(&cfg, &mut p);
            assert_eq!(r.report.accesses as usize, cfg.accesses, "{policy}");
            assert!(r.report.l2_hit_rate > 0.0 && r.report.l2_hit_rate < 1.0, "{policy}");
            assert!(r.tokens > 0);
            assert!(r.emu > 0.0 && r.emu <= 1.0, "{policy}: emu {}", r.emu);
        }
    }

    #[test]
    fn belady_upper_bounds_lru() {
        let lru = run_experiment(&ExperimentConfig::smoke("lru"), &mut PredictorBox::None);
        let bel = run_experiment(&ExperimentConfig::smoke("belady"), &mut PredictorBox::None);
        assert!(
            bel.report.l2_hit_rate >= lru.report.l2_hit_rate - 0.005,
            "belady {:.4} must dominate lru {:.4}",
            bel.report.l2_hit_rate,
            lru.report.l2_hit_rate
        );
    }

    #[test]
    fn heuristic_acpc_beats_lru_and_cuts_pollution() {
        let mut cfg = ExperimentConfig::smoke("acpc");
        cfg.accesses = 120_000;
        let mut p = PredictorBox::Heuristic(HeuristicPredictor);
        let acpc = run_experiment(&cfg, &mut p);

        let mut cfg_lru = ExperimentConfig::smoke("lru");
        cfg_lru.accesses = 120_000;
        let lru = run_experiment(&cfg_lru, &mut PredictorBox::None);

        assert!(acpc.prediction_batches > 0);
        assert!(
            acpc.report.l2_hit_rate > lru.report.l2_hit_rate,
            "acpc {:.4} vs lru {:.4}",
            acpc.report.l2_hit_rate,
            lru.report.l2_hit_rate
        );
        assert!(
            acpc.report.l2_pollution_ratio < lru.report.l2_pollution_ratio,
            "pollution acpc {:.4} vs lru {:.4}",
            acpc.report.l2_pollution_ratio,
            lru.report.l2_pollution_ratio
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ExperimentConfig::smoke("srrip");
        let a = run_experiment(&cfg, &mut PredictorBox::None);
        let b = run_experiment(&cfg, &mut PredictorBox::None);
        assert_eq!(a.report.l2_hit_rate, b.report.l2_hit_rate);
        assert_eq!(a.report.l2_miss_cycles, b.report.l2_miss_cycles);
    }
}
