//! Set-sharded single-cell simulation: split *one* policy×scenario run
//! across N worker threads by cache-set partition.
//!
//! Cache sets are independent under every replacement policy we model, so a
//! set partition is **exact**, not approximate: shard `k` of `N` owns every
//! line with `line & (N-1) == k`, which — because set counts are powers of
//! two and `N` divides all of them — carves out the same 1/N slice of the
//! sets at L1, L2 *and* L3 ([`Hierarchy::new_sharded`]). Each shard runs the
//! same per-access pipeline as the single-threaded path (the shared
//! [`super::engine::AccessDriver`]): its own sub-hierarchy, feature
//! extractor, prediction batch and (optionally) adaptive-controller window.
//! The workload stream is produced once, in order, and routed into bounded
//! lock-free SPSC rings ([`crate::util::spsc`]) as per-shard chunks, so the
//! access path takes no locks.
//!
//! Aggregation is exact: [`CacheStats`](crate::mem::CacheStats) /
//! [`SimResult`] merge by summing monotone counters and recomputing derived
//! rates ([`MetricsReport::from_hierarchies`]). Consequences:
//!
//! - a fully **set-local configuration** — per-set policies at every level
//!   (lru, srrip, plru, belady; `l3_policy = "srrip"` instead of the
//!   global-PSEL DRRIP default) and the prefetcher off — reports
//!   byte-identical aggregate hit rate / pollution / AMAT for *any* shard
//!   count — asserted by `tests/integration_shard.rs`;
//! - policies with global state (DIP's/DRRIP's PSEL, SHiP's SHCT),
//!   history-based prefetchers (stride/correlation tables become
//!   per-shard, like per-bank prefetch engines) and ML predictors
//!   (per-shard batch boundaries) are *deterministic for a fixed shard
//!   count* via seeded per-shard tie-breaks, the same contract LLaMCAT's
//!   per-bank arbitration provides.

use super::engine::{run_workload_adaptive, AccessDriver, Engine, SimResult};
use crate::adapt::{AdaptiveController, ControllerConfig, ControllerSummary};
use crate::config::ExperimentConfig;
use crate::mem::Hierarchy;
use crate::metrics::MetricsReport;
use crate::predictor::{GeometryHints, PredictorBox};
use crate::trace::{Access, Workload};
use crate::util::spsc;
use anyhow::{anyhow, Result};
use std::time::Instant;

/// Accesses per routed chunk: big enough that ring-atomic traffic is
/// amortized to noise, small enough that shards stay busy on skewed
/// partitions.
const CHUNK: usize = 1024;
/// Chunks buffered per shard ring before the producer back-pressures.
const RING_CHUNKS: usize = 8;

const SHARD_SEED_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// One access plus its Belady next-use annotation (`u64::MAX` = none).
type Item = (Access, u64);

/// Everything a finished shard hands back for the exact merge.
struct ShardOut {
    hier: Hierarchy,
    emu_acc: f64,
    emu_samples: u64,
    steps: u64,
    prediction_batches: u64,
    train_steps: u64,
    predictor_name: String,
    adapt: Option<(u64, u64, u64, u64)>, // windows, drifts, swaps, throttled
    summary: Option<ControllerSummary>,
}

/// Result of a sharded run: the exactly-merged [`SimResult`] plus the
/// per-shard controller summaries of adaptive runs (empty otherwise).
pub struct ShardedRun {
    pub result: SimResult,
    pub controllers: Vec<ControllerSummary>,
}

/// Run one simulation cell split across `shards` worker threads by L2 set
/// index. `mk_predictor` is invoked once *inside* each shard thread (PJRT
/// executables are thread-affine); `ccfg` attaches a per-shard
/// [`AdaptiveController`] (seeded per shard). `shards <= 1` is exactly the
/// single-threaded [`run_workload_adaptive`] path.
pub fn run_workload_sharded(
    cfg: &ExperimentConfig,
    workload: &mut dyn Workload,
    shards: usize,
    mk_predictor: &(dyn Fn(usize) -> PredictorBox + Sync),
    ccfg: Option<&ControllerConfig>,
) -> Result<ShardedRun> {
    if shards <= 1 {
        let mut predictor = mk_predictor(0);
        let mut controller = ccfg.map(|c| AdaptiveController::new(c.clone()));
        let result = run_workload_adaptive(cfg, workload, &mut predictor, controller.as_mut());
        let controllers = controller.map(|c| vec![c.into_summary()]).unwrap_or_default();
        return Ok(ShardedRun { result, controllers });
    }
    cfg.hierarchy
        .validate_shards(shards)
        .map_err(|e| anyhow!("cannot shard this hierarchy: {e}"))?;

    let t0 = Instant::now();
    let geom = GeometryHints::from_generator(&cfg.generator);
    let mask = shards as u64 - 1;

    // Oracle mode pre-materializes the trace for next-use annotation (the
    // annotations carry *global* positions; within a set — and therefore
    // within a shard — their ordering is exactly the unsharded one).
    let (trace_vec, next_use) = if cfg.policy == "belady" {
        let tv = workload.generate(cfg.accesses);
        let nu = super::oracle::annotate_next_use(&tv);
        (Some(tv), Some(nu))
    } else {
        (None, None)
    };

    let mut producers = Vec::with_capacity(shards);
    let mut consumers = Vec::with_capacity(shards);
    for _ in 0..shards {
        let (tx, rx) = spsc::channel::<Vec<Item>>(RING_CHUNKS);
        producers.push(tx);
        consumers.push(rx);
    }

    let outs: Vec<ShardOut> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(shards);
        for (k, mut rx) in consumers.into_iter().enumerate() {
            handles.push(s.spawn(move || {
                let hier = Hierarchy::new_sharded(cfg.hierarchy.clone(), &cfg.policy, k, shards);
                let mut predictor = mk_predictor(k);
                let pw = if predictor.is_some() { predictor.window().max(1) } else { 0 };
                let engine = Engine::with_hierarchy(hier, geom, pw);
                let mut controller = ccfg.map(|c| {
                    let mut cc = c.clone();
                    cc.seed ^= (k as u64).wrapping_mul(SHARD_SEED_MIX);
                    AdaptiveController::new(cc)
                });
                let mut driver =
                    AccessDriver::new(cfg, engine, &mut predictor, controller.as_mut());
                while let Some(chunk) = rx.pop() {
                    for (a, nu) in chunk {
                        driver.drive(&a, (nu != u64::MAX).then_some(nu));
                    }
                }
                let out = driver.finish();
                let (emu_acc, emu_samples) = out.engine.emu_parts();
                let steps = out.engine.steps();
                let (adapt, controller_steps, summary) = match controller {
                    Some(c) => {
                        let counters =
                            (c.windows(), c.drift_count(), c.swap_count(), c.throttled_windows());
                        let steps = c.online_train_steps();
                        (Some(counters), steps, Some(c.into_summary()))
                    }
                    None => (None, 0, None),
                };
                ShardOut {
                    hier: out.engine.hier,
                    emu_acc,
                    emu_samples,
                    steps,
                    prediction_batches: out.prediction_batches,
                    train_steps: out.learner_steps + controller_steps,
                    predictor_name: predictor.name(),
                    adapt,
                    summary,
                }
            }));
        }

        // Producer: route the single ordered stream into per-shard chunks.
        let mut staging: Vec<Vec<Item>> =
            (0..shards).map(|_| Vec::with_capacity(CHUNK)).collect();
        for i in 0..cfg.accesses {
            let a = match &trace_vec {
                Some(tv) => tv[i],
                None => workload.next_access(),
            };
            let nu = next_use.as_ref().map(|v| v[i]).unwrap_or(u64::MAX);
            let k = (a.line() & mask) as usize;
            staging[k].push((a, nu));
            if staging[k].len() == CHUNK {
                let chunk = std::mem::replace(&mut staging[k], Vec::with_capacity(CHUNK));
                producers[k].push(chunk);
            }
        }
        for (k, st) in staging.into_iter().enumerate() {
            if !st.is_empty() {
                producers[k].push(st);
            }
        }
        for p in &mut producers {
            p.close();
        }

        handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
    });

    Ok(merge_shards(cfg, outs, workload.tokens_done(), t0.elapsed().as_secs_f64()))
}

/// Exact merge of the per-shard outcomes into one [`SimResult`].
fn merge_shards(cfg: &ExperimentConfig, outs: Vec<ShardOut>, tokens: u64, wall: f64) -> ShardedRun {
    debug_assert_eq!(
        outs.iter().map(|o| o.steps).sum::<u64>(),
        cfg.accesses as u64,
        "every access must be routed to exactly one shard"
    );
    let emu_acc: f64 = outs.iter().map(|o| o.emu_acc).sum();
    let emu_n: u64 = outs.iter().map(|o| o.emu_samples).sum();
    let emu = if emu_n > 0 { emu_acc / emu_n as f64 } else { f64::NAN };
    let hiers: Vec<&Hierarchy> = outs.iter().map(|o| &o.hier).collect();
    let report = MetricsReport::from_hierarchies(&cfg.name, &hiers, tokens, emu);
    let prediction_batches: u64 = outs.iter().map(|o| o.prediction_batches).sum();
    let online_train_steps: u64 = outs.iter().map(|o| o.train_steps).sum();
    let (mut aw, mut de, mut ps, mut tw) = (0u64, 0u64, 0u64, 0u64);
    for o in &outs {
        if let Some((w, d, p, t)) = o.adapt {
            aw += w;
            de += d;
            ps += p;
            tw += t;
        }
    }
    // Provenance: shards normally run the same predictor, but per-shard
    // artifact-load fallbacks can differ — report that honestly instead of
    // letting shard 0 speak for everyone.
    let mut names: Vec<String> = outs.iter().map(|o| o.predictor_name.clone()).collect();
    names.sort();
    names.dedup();
    let predictor = match names.len() {
        0 => "none".to_string(),
        1 => names.pop().expect("one name"),
        _ => format!("mixed({})", names.join("+")),
    };
    let controllers: Vec<ControllerSummary> =
        outs.into_iter().filter_map(|o| o.summary).collect();
    ShardedRun {
        result: SimResult {
            report,
            tokens,
            emu,
            predictor,
            prediction_batches,
            online_train_steps,
            wall_secs: wall,
            accesses_per_sec: cfg.accesses as f64 / wall,
            adapt_windows: aw,
            drift_events: de,
            predictor_swaps: ps,
            throttled_windows: tw,
        },
        controllers,
    }
}
