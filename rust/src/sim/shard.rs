//! Set-sharded single-cell simulation: split *one* policy×scenario run
//! across N worker threads by cache-set partition.
//!
//! Cache sets are independent under every replacement policy we model, so a
//! set partition is **exact**, not approximate: shard `k` of `N` owns every
//! line with `line & (N-1) == k`, which — because set counts are powers of
//! two and `N` divides all of them — carves out the same 1/N slice of the
//! sets at L1, L2 *and* L3 ([`Hierarchy::new_sharded`]). Each shard runs the
//! same per-access pipeline as the single-threaded path (the shared
//! [`super::engine::AccessDriver`]): its own sub-hierarchy, feature
//! extractor, prediction batch and (optionally) adaptive-controller window.
//! The workload stream is produced once, in order, and routed into bounded
//! lock-free SPSC rings ([`crate::util::spsc`]) as per-shard chunks, so the
//! access path takes no locks. Drained chunk buffers flow *back* to the
//! producer through a second ring per shard, so the steady-state routing
//! path allocates no fresh chunk vectors.
//!
//! Shard workers are **persistent per calling thread**: the first sharded
//! run on a thread spawns its pool, later runs reuse it (and the pool dies
//! with the thread). Under the default native backend every shard's
//! predictor is a [`PredictorBox::Native`] clone over one process-wide
//! weight snapshot — workers share the model rather than reloading
//! artifacts per thread. Predictor factories still run on the long-lived
//! worker threads, which is what lets the runner's per-thread *PJRT* cache
//! (the `backend: pjrt` escape hatch) amortize its one artifact load + XLA
//! compile across every sharded sweep cell a thread executes.
//!
//! Aggregation is exact: [`CacheStats`](crate::mem::CacheStats) /
//! [`SimResult`] merge by summing monotone counters and recomputing derived
//! rates ([`MetricsReport::from_hierarchies`]). Consequences:
//!
//! - a fully **set-local configuration** — per-set policies at every level
//!   (lru, srrip, plru, belady; `l3_policy = "srrip"` instead of the
//!   global-PSEL DRRIP default) and the prefetcher off — reports
//!   byte-identical aggregate hit rate / pollution / AMAT for *any* shard
//!   count — asserted by `tests/integration_shard.rs`;
//! - policies with global state (DIP's/DRRIP's PSEL, SHiP's SHCT),
//!   history-based prefetchers (stride/correlation tables become
//!   per-shard, like per-bank prefetch engines) and ML predictors
//!   (per-shard batch boundaries) are *deterministic for a fixed shard
//!   count* via seeded per-shard tie-breaks, the same contract LLaMCAT's
//!   per-bank arbitration provides.

use super::engine::{run_workload_adaptive, AccessDriver, Engine, SimResult};
use crate::adapt::{AdaptiveController, ControllerConfig, ControllerSummary};
use crate::config::ExperimentConfig;
use crate::mem::Hierarchy;
use crate::metrics::MetricsReport;
use crate::obs::{SourceId, TelemetryBus, TelemetryPublisher};
use crate::predictor::{GeometryHints, PredictorBox};
use crate::trace::{Access, Workload};
use crate::util::spsc;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Accesses per routed chunk: big enough that ring-atomic traffic is
/// amortized to noise, small enough that shards stay busy on skewed
/// partitions.
const CHUNK: usize = 1024;
/// Chunks buffered per shard ring before the producer back-pressures.
const RING_CHUNKS: usize = 8;

const SHARD_SEED_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// One access plus its Belady next-use annotation (`u64::MAX` = none).
type Item = (Access, u64);

/// Constructs a shard's predictor *inside* the shard's worker thread
/// (PJRT handles are thread-affine). The canonical (public) alias lives in
/// the API layer: [`crate::api::PredictorFactory`].
pub(crate) use crate::api::PredictorFactory;

/// Called with each shard's predictor after its run completes — the hook
/// the runner uses to return cached (weight-untouched) models to the
/// worker thread's TCN cache.
pub(crate) type PredictorReclaim = Arc<dyn Fn(usize, PredictorBox) + Send + Sync>;

/// Everything a finished shard hands back for the exact merge.
struct ShardOut {
    hier: Hierarchy,
    emu_acc: f64,
    emu_samples: u64,
    steps: u64,
    prediction_batches: u64,
    train_steps: u64,
    predictor_name: String,
    adapt: Option<(u64, u64, u64, u64)>, // windows, drifts, swaps, throttled
    summary: Option<ControllerSummary>,
}

/// Result of a sharded run: the exactly-merged [`SimResult`] plus the
/// per-shard controller summaries of adaptive runs (empty otherwise).
pub struct ShardedRun {
    pub result: SimResult,
    pub controllers: Vec<ControllerSummary>,
}

// ---- persistent shard-worker pool --------------------------------------

type ShardJob = Box<dyn FnOnce() + Send>;

struct PoolWorker {
    tx: Option<mpsc::Sender<ShardJob>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Long-lived shard workers owned by one calling thread. Worker `k` always
/// executes shard `k`, so per-thread state (the runner's TCN cache) maps
/// stably onto shard indices across runs.
struct ShardPool {
    workers: Vec<PoolWorker>,
}

impl ShardPool {
    fn new() -> Self {
        Self { workers: Vec::new() }
    }

    fn ensure(&mut self, n: usize) {
        while self.workers.len() < n {
            let idx = self.workers.len();
            let (tx, rx) = mpsc::channel::<ShardJob>();
            let handle = std::thread::Builder::new()
                .name(format!("acpc-shard-{idx}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                })
                .expect("spawn shard worker");
            self.workers.push(PoolWorker { tx: Some(tx), handle: Some(handle) });
        }
    }

    fn submit(&self, k: usize, job: ShardJob) {
        self.workers[k]
            .tx
            .as_ref()
            .expect("pool worker sender present")
            .send(job)
            .expect("shard worker accepting jobs");
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // Close the job channels first so every worker's recv loop ends,
        // then join. A worker that panicked reports a join error, which is
        // ignored here — the run that observed the panic already surfaced
        // it.
        for w in &mut self.workers {
            w.tx.take();
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

thread_local! {
    /// The calling thread's persistent pool; created lazily by the first
    /// sharded run and reused (growing as needed) afterwards. Dropped with
    /// the thread — sweep worker threads keep their shard workers for the
    /// whole sweep.
    static SHARD_POOL: RefCell<Option<ShardPool>> = const { RefCell::new(None) };
}

// ------------------------------------------------------------------------

/// Run one simulation cell split across `shards` worker threads by L2 set
/// index. `mk_predictor` is invoked once *inside* each shard's worker
/// thread; `reclaim` (if any) receives each shard's predictor after the
/// run; `ccfg` attaches a per-shard [`AdaptiveController`] (seeded per
/// shard). `shards <= 1` is exactly the single-threaded
/// [`run_workload_adaptive`] path. `bus` (if any) receives each shard's
/// telemetry stream under source `sim/k` — attaching one does not perturb
/// the run. Crate-internal delegate of [`crate::api::Runner::run`].
pub(crate) fn run_workload_sharded(
    cfg: &ExperimentConfig,
    workload: &mut dyn Workload,
    shards: usize,
    mk_predictor: &PredictorFactory,
    reclaim: Option<&PredictorReclaim>,
    ccfg: Option<&ControllerConfig>,
    bus: Option<&TelemetryBus>,
) -> Result<ShardedRun> {
    if shards <= 1 {
        let mut predictor = mk_predictor(0);
        let mut controller = ccfg.map(|c| AdaptiveController::new(c.clone()));
        let publisher = bus.map(|b| b.publisher(SourceId::sim(0)));
        let result =
            run_workload_adaptive(cfg, workload, &mut predictor, controller.as_mut(), publisher);
        if let Some(r) = reclaim {
            r(0, predictor);
        }
        let controllers = controller.map(|c| vec![c.into_summary()]).unwrap_or_default();
        return Ok(ShardedRun { result, controllers });
    }
    cfg.hierarchy
        .validate_shards(shards)
        .map_err(|e| anyhow!("cannot shard this hierarchy: {e}"))?;

    let t0 = Instant::now();
    let geom = GeometryHints::from_generator(&cfg.generator);
    let mask = shards as u64 - 1;

    // Oracle mode pre-materializes the trace for next-use annotation (the
    // annotations carry *global* positions; within a set — and therefore
    // within a shard — their ordering is exactly the unsharded one).
    let (trace_vec, next_use) = if cfg.policy == "belady" {
        let tv = workload.generate(cfg.accesses);
        let nu = super::oracle::annotate_next_use(&tv);
        (Some(tv), Some(nu))
    } else {
        (None, None)
    };

    let mut pool = SHARD_POOL.with(|p| p.borrow_mut().take()).unwrap_or_else(ShardPool::new);
    pool.ensure(shards);

    let (res_tx, res_rx) = mpsc::channel::<(usize, ShardOut)>();
    let mut producers = Vec::with_capacity(shards);
    let mut returns = Vec::with_capacity(shards);
    for k in 0..shards {
        let (tx, rx) = spsc::channel::<Vec<Item>>(RING_CHUNKS);
        // Return ring: the worker pushes drained (cleared) chunk buffers
        // back; the producer reuses them instead of allocating per chunk.
        let (ret_tx, ret_rx) = spsc::channel::<Vec<Item>>(RING_CHUNKS);
        producers.push(tx);
        returns.push(ret_rx);
        pool.submit(
            k,
            shard_job(ShardArgs {
                cfg: cfg.clone(),
                k,
                shards,
                geom,
                rx,
                ret_tx,
                mk: Arc::clone(mk_predictor),
                reclaim: reclaim.cloned(),
                ccfg: ccfg.cloned(),
                publisher: bus.map(|b| b.publisher(SourceId::sim(k))),
                res_tx: res_tx.clone(),
            }),
        );
    }
    // Jobs hold clones; dropping the original lets a worker panic surface
    // as a receive error instead of a hang.
    drop(res_tx);

    // Producer: route the single ordered stream into per-shard chunks.
    let mut staging: Vec<Vec<Item>> = (0..shards).map(|_| Vec::with_capacity(CHUNK)).collect();
    for i in 0..cfg.accesses {
        let a = match &trace_vec {
            Some(tv) => tv[i],
            None => workload.next_access(),
        };
        let nu = next_use.as_ref().map(|v| v[i]).unwrap_or(u64::MAX);
        let k = (a.line() & mask) as usize;
        staging[k].push((a, nu));
        if staging[k].len() == CHUNK {
            let fresh = recycled_chunk(&mut returns[k]);
            let chunk = std::mem::replace(&mut staging[k], fresh);
            producers[k].push(chunk);
        }
    }
    for (k, st) in staging.into_iter().enumerate() {
        if !st.is_empty() {
            producers[k].push(st);
        }
    }
    for p in &mut producers {
        p.close();
    }

    let mut outs: Vec<Option<ShardOut>> = Vec::new();
    outs.resize_with(shards, || None);
    for _ in 0..shards {
        match res_rx.recv() {
            Ok((k, out)) => outs[k] = Some(out),
            Err(_) => {
                // A worker died without reporting: its thread is gone, so
                // the pool cannot be reused. Unblock any still-running
                // workers (closed rings), discard the pool (joins the
                // survivors) and surface the failure exactly like the old
                // scoped-thread implementation did.
                drop(producers);
                drop(returns);
                drop(pool);
                panic!("shard worker panicked");
            }
        }
    }
    SHARD_POOL.with(|p| *p.borrow_mut() = Some(pool));
    let outs: Vec<ShardOut> =
        outs.into_iter().map(|o| o.expect("every shard reported")).collect();

    // Traffic counters live in the producer-side workload, so they are
    // shard-count invariant by construction (single arrival history).
    Ok(merge_shards(
        cfg,
        outs,
        workload.tokens_done(),
        workload.traffic(),
        t0.elapsed().as_secs_f64(),
    ))
}

/// Pop a recycled chunk buffer off a shard's return ring (already cleared
/// by the worker), falling back to a fresh allocation when the ring is
/// momentarily empty.
fn recycled_chunk(ret: &mut spsc::Consumer<Vec<Item>>) -> Vec<Item> {
    ret.try_pop().unwrap_or_else(|| Vec::with_capacity(CHUNK))
}

/// Everything one shard's job needs, owned ('static: the job outlives the
/// call on a persistent worker thread).
struct ShardArgs {
    cfg: ExperimentConfig,
    k: usize,
    shards: usize,
    geom: GeometryHints,
    rx: spsc::Consumer<Vec<Item>>,
    ret_tx: spsc::Producer<Vec<Item>>,
    mk: PredictorFactory,
    reclaim: Option<PredictorReclaim>,
    ccfg: Option<ControllerConfig>,
    /// This shard's telemetry stream (source `sim/k`), created bus-side by
    /// the dispatcher so the per-source sequence counter has one owner.
    publisher: Option<TelemetryPublisher>,
    res_tx: mpsc::Sender<(usize, ShardOut)>,
}

/// One shard's work: drain the ring through the shared [`AccessDriver`]
/// loop body — identical to the single-threaded path — and report the
/// harvest.
fn shard_job(args: ShardArgs) -> ShardJob {
    Box::new(move || {
        let ShardArgs {
            cfg,
            k,
            shards,
            geom,
            mut rx,
            mut ret_tx,
            mk,
            reclaim,
            ccfg,
            publisher,
            res_tx,
        } = args;
        let hier = Hierarchy::new_sharded(cfg.hierarchy.clone(), &cfg.policy, k, shards);
        let mut predictor = mk(k);
        let pw = if predictor.is_some() { predictor.window().max(1) } else { 0 };
        let engine = Engine::with_hierarchy(hier, geom, pw);
        let mut controller = ccfg.map(|c| {
            let mut cc = c;
            cc.seed ^= (k as u64).wrapping_mul(SHARD_SEED_MIX);
            AdaptiveController::new(cc)
        });
        let mut driver =
            AccessDriver::new(&cfg, engine, &mut predictor, controller.as_mut(), publisher);
        while let Some(mut chunk) = rx.pop() {
            for (a, nu) in &chunk {
                driver.drive(a, (*nu != u64::MAX).then_some(*nu));
            }
            // Recycle the drained buffer (ring full ⇒ just drop it).
            chunk.clear();
            let _ = ret_tx.try_push(chunk);
        }
        let out = driver.finish();
        let (emu_acc, emu_samples) = out.engine.emu_parts();
        let steps = out.engine.steps();
        let (adapt, controller_steps, summary) = match controller {
            Some(c) => {
                let counters =
                    (c.windows(), c.drift_count(), c.swap_count(), c.throttled_windows());
                let steps = c.online_train_steps();
                (Some(counters), steps, Some(c.into_summary()))
            }
            None => (None, 0, None),
        };
        let predictor_name = predictor.name();
        if let Some(r) = &reclaim {
            r(k, predictor);
        }
        let _ = res_tx.send((
            k,
            ShardOut {
                hier: out.engine.hier,
                emu_acc,
                emu_samples,
                steps,
                prediction_batches: out.prediction_batches,
                train_steps: out.learner_steps + controller_steps,
                predictor_name,
                adapt,
                summary,
            },
        ));
    })
}

/// Exact merge of the per-shard outcomes into one [`SimResult`].
fn merge_shards(
    cfg: &ExperimentConfig,
    outs: Vec<ShardOut>,
    tokens: u64,
    traffic: Option<crate::traffic::TrafficSummary>,
    wall: f64,
) -> ShardedRun {
    debug_assert_eq!(
        outs.iter().map(|o| o.steps).sum::<u64>(),
        cfg.accesses as u64,
        "every access must be routed to exactly one shard"
    );
    let emu_acc: f64 = outs.iter().map(|o| o.emu_acc).sum();
    let emu_n: u64 = outs.iter().map(|o| o.emu_samples).sum();
    let emu = if emu_n > 0 { emu_acc / emu_n as f64 } else { f64::NAN };
    let hiers: Vec<&Hierarchy> = outs.iter().map(|o| &o.hier).collect();
    let report = MetricsReport::from_hierarchies(&cfg.name, &hiers, tokens, emu);
    let prediction_batches: u64 = outs.iter().map(|o| o.prediction_batches).sum();
    let online_train_steps: u64 = outs.iter().map(|o| o.train_steps).sum();
    let (mut aw, mut de, mut ps, mut tw) = (0u64, 0u64, 0u64, 0u64);
    for o in &outs {
        if let Some((w, d, p, t)) = o.adapt {
            aw += w;
            de += d;
            ps += p;
            tw += t;
        }
    }
    // Provenance: shards normally run the same predictor, but per-shard
    // artifact-load fallbacks can differ — report that honestly instead of
    // letting shard 0 speak for everyone.
    let mut names: Vec<String> = outs.iter().map(|o| o.predictor_name.clone()).collect();
    names.sort();
    names.dedup();
    let predictor = match names.len() {
        0 => "none".to_string(),
        1 => names.pop().expect("one name"),
        _ => format!("mixed({})", names.join("+")),
    };
    let controllers: Vec<ControllerSummary> =
        outs.into_iter().filter_map(|o| o.summary).collect();
    ShardedRun {
        result: SimResult {
            report,
            tokens,
            emu,
            predictor,
            prediction_batches,
            online_train_steps,
            wall_secs: wall,
            accesses_per_sec: cfg.accesses as f64 / wall,
            adapt_windows: aw,
            drift_events: de,
            predictor_swaps: ps,
            throttled_windows: tw,
            traffic,
        },
        controllers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PredictorKind;

    fn mk_none() -> PredictorFactory {
        Arc::new(|_| PredictorBox::None)
    }

    /// The persistent pool must survive (and stay correct across) repeated
    /// sharded runs from one thread, including a shard-count change.
    #[test]
    fn pool_reuse_is_deterministic_across_runs_and_shard_counts() {
        let mut cfg = ExperimentConfig::for_scenario(
            "decode-heavy",
            "lru",
            PredictorKind::None,
            0xBEEF,
        )
        .unwrap();
        cfg.accesses = 30_000;
        let mk = mk_none();
        let run = |shards: usize| {
            let mut w = cfg.workload();
            run_workload_sharded(&cfg, w.as_mut(), shards, &mk, None, None, None)
                .expect("sharded run")
        };
        let a = run(2);
        let b = run(2); // reuses the 2-worker pool
        let c = run(4); // grows the pool in place
        let d = run(4);
        assert_eq!(
            a.result.report.to_json().to_pretty(),
            b.result.report.to_json().to_pretty(),
            "pool reuse must not change results"
        );
        assert_eq!(
            c.result.report.to_json().to_pretty(),
            d.result.report.to_json().to_pretty()
        );
        assert_eq!(a.result.report.accesses, 30_000);
        assert_eq!(c.result.report.accesses, 30_000);
    }

    /// Chunk-buffer recycling must be transparent: results identical to the
    /// reference single-shard run for a set-local config.
    #[test]
    fn return_ring_preserves_exactness() {
        let mut cfg = ExperimentConfig::for_scenario(
            "decode-heavy",
            "srrip",
            PredictorKind::None,
            0x51AB,
        )
        .unwrap();
        cfg.accesses = 60_000;
        cfg.hierarchy.prefetcher = "none".into();
        cfg.hierarchy.l3_policy = "srrip".into();
        let mk = mk_none();
        let mut w1 = cfg.workload();
        let one = run_workload_sharded(&cfg, w1.as_mut(), 1, &mk, None, None, None).unwrap();
        let mut w8 = cfg.workload();
        let eight =
            run_workload_sharded(&cfg, w8.as_mut(), 8, &mk, None, None, None).unwrap();
        assert_eq!(
            one.result.report.to_json().to_pretty(),
            eight.result.report.to_json().to_pretty()
        );
    }
}
