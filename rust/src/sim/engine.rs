//! The unified experiment engine: one access-driving loop for every
//! consumer (CLI `simulate`/`table1`/`sweep`, the benches, and the serving
//! coordinator's workers), replacing the four divergent copies that used to
//! live in the simulator, the coordinator and the benches.
//!
//! [`Engine`] owns the cache [`Hierarchy`] plus the per-access bookkeeping
//! around it (feature extraction, EMU sampling, latency/metrics harvest)
//! and drives any [`Workload`] — it does not care whether accesses come
//! from a scenario generator, a materialized oracle trace, or a
//! router-admitted serving session. Not to be confused with the PJRT
//! [`crate::runtime::Engine`], which executes compiled HLO.
//!
//! Prediction is *asynchronous and batched*, mirroring the paper's pipeline
//! (§3.1): every L2-relevant access yields a feature row; rows accumulate
//! in a [`PredictionBatch`]; when the batch is full the predictor runs once
//! and the resulting utilities update (a) a bounded line→utility cache
//! consulted at fill time and (b) the utilities of still-resident L2 lines.
//! A fill therefore uses the *most recent completed* prediction for its
//! line — never a same-cycle oracle. In the serving coordinator the same
//! batch structure is shipped over a channel to the predictor service
//! thread instead of being flushed inline.
//!
//! The optional [`OnlineLearner`] implements §3.4: observed outcomes (was
//! the line actually reused within the horizon?) are turned into labeled
//! samples, and every `feedback_interval` accesses a few Adam steps run on
//! a replay buffer — the compiled train-step HLO, from rust. The learner
//! lives in [`crate::adapt`] now; [`run_workload_adaptive`] additionally
//! threads a full [`AdaptiveController`] (windowed telemetry + drift
//! detection + predictor hot-swap/throttle) through the loop.

use crate::adapt::{AdaptiveController, ControlDecision, OnlineLearner, PredictorAccess};
use crate::config::ExperimentConfig;
use crate::mem::{Hierarchy, HierarchyConfig, ServiceLevel};
use crate::metrics::MetricsReport;
use crate::obs::{Payload, TelemetryPublisher};
use crate::policy::AccessMeta;
use crate::predictor::{FeatureExtractor, GeometryHints, PredictorBox, FEATURE_DIM};
use crate::trace::{Access, Workload};
use std::time::Instant;

/// Outcome of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub report: MetricsReport,
    pub tokens: u64,
    pub emu: f64,
    pub predictor: String,
    pub prediction_batches: u64,
    pub online_train_steps: u64,
    pub wall_secs: f64,
    /// Accesses simulated per wall-clock second (L3 perf metric).
    pub accesses_per_sec: f64,
    /// Telemetry windows observed by the adaptive controller (0 without one).
    pub adapt_windows: u64,
    /// Drift-detector firings recorded by the controller.
    pub drift_events: u64,
    /// Weight hot-swaps (drift-triggered retrains); throttle/resume events
    /// bump the controller's handle version but are not counted here.
    pub predictor_swaps: u64,
    /// Windows spent with predictions throttled to policy-default inserts.
    pub throttled_windows: u64,
    /// Open-loop traffic counters when the workload models offered load
    /// (see [`crate::traffic`]); `None` for closed-loop workloads.
    pub traffic: Option<crate::traffic::TrafficSummary>,
}

/// Accumulates per-access feature rows until a predictor batch is ready.
/// Shared by the inline simulation loop (flushes into a [`PredictorBox`])
/// and the coordinator workers (ship the batch to the predictor service).
pub struct PredictionBatch {
    lines: Vec<u64>,
    x: Vec<f32>,
    row: usize,
    capacity: usize,
}

impl PredictionBatch {
    pub fn new(row: usize, capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            lines: Vec::with_capacity(capacity),
            x: Vec::with_capacity(capacity * row),
            row,
            capacity,
        }
    }

    /// Buffer one (line, features) pair; true when the batch is now full.
    pub fn push(&mut self, line: u64, features: &[f32]) -> bool {
        debug_assert_eq!(features.len(), self.row);
        self.lines.push(line);
        self.x.extend_from_slice(features);
        self.lines.len() >= self.capacity
    }

    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    /// Buffered lines (parallel to the rows of [`x`](Self::x)).
    pub fn lines(&self) -> &[u64] {
        &self.lines
    }

    /// Buffered feature rows, row-major.
    pub fn x(&self) -> &[f32] {
        &self.x
    }

    /// Reset the batch *in place*, keeping both buffers' capacity — the
    /// allocation-free alternative to [`take`](Self::take) for loops that
    /// consume the batch by reference ([`PredictorBox::predict_into`]).
    pub fn clear(&mut self) {
        self.lines.clear();
        self.x.clear();
    }

    /// Drain the buffered batch, leaving an empty queue with its capacity
    /// preallocated. Used where the batch contents must *move* (the serving
    /// coordinator ships them to the predictor service thread); in-process
    /// loops use [`clear`](Self::clear) + the accessors instead.
    pub fn take(&mut self) -> (Vec<u64>, Vec<f32>) {
        let lines = std::mem::replace(&mut self.lines, Vec::with_capacity(self.capacity));
        let x = std::mem::replace(&mut self.x, Vec::with_capacity(self.capacity * self.row));
        (lines, x)
    }
}

/// How often the engine samples L2 useful-fraction for the EMU metric.
const EMU_SAMPLE_PERIOD: u64 = 8192;

/// The shared access-driving core: hierarchy + feature extraction + metric
/// sampling. Every consumer calls [`Engine::step`] per access and harvests
/// a [`MetricsReport`] at the end; the crate-internal batch-mode entry
/// points (`run_experiment` / `run_workload`, delegates of
/// [`crate::api::Runner::run`]) wrap the loop.
pub struct Engine {
    /// The simulated memory system (public: consumers harvest raw stats).
    pub hier: Hierarchy,
    fx: FeatureExtractor,
    seq: Vec<f32>,
    window: usize,
    row: usize,
    features_on: bool,
    steps: u64,
    emu_acc: f64,
    emu_samples: u64,
}

impl Engine {
    /// `predictor_window` selects feature extraction: 0 = none (classic
    /// policies), 1 = flat per-access features (heuristic/DNN), >1 = the
    /// TCN's temporal window.
    pub fn new(
        hcfg: HierarchyConfig,
        policy: &str,
        geom: GeometryHints,
        predictor_window: usize,
    ) -> Self {
        Self::with_hierarchy(Hierarchy::new(hcfg, policy), geom, predictor_window)
    }

    /// Wrap an already-built hierarchy — the entry point for the sharded
    /// simulator, whose shards construct sub-hierarchies via
    /// [`Hierarchy::new_sharded`] and drive each through its own engine.
    pub fn with_hierarchy(hier: Hierarchy, geom: GeometryHints, predictor_window: usize) -> Self {
        let features_on = predictor_window > 0;
        let window = predictor_window.max(1);
        let row = if predictor_window <= 1 { FEATURE_DIM } else { window * FEATURE_DIM };
        Self {
            hier,
            fx: FeatureExtractor::new(window, geom),
            seq: vec![0.0f32; window * FEATURE_DIM],
            window,
            row,
            features_on,
            steps: 0,
            emu_acc: 0.0,
            emu_samples: 0,
        }
    }

    /// Feature-row width (elements) of the rows [`step`](Self::step) yields.
    pub fn row(&self) -> usize {
        self.row
    }

    /// Accesses driven so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Drive one access through the hierarchy. Returns the access's feature
    /// row when feature extraction is enabled (the caller batches it via
    /// [`PredictionBatch`]), `None` otherwise. `next_use` is the Belady
    /// oracle annotation (`u64::MAX` / `None` = never reused).
    pub fn step(&mut self, a: &Access, next_use: Option<u64>) -> Option<&[f32]> {
        let line = a.line();
        let meta = AccessMeta {
            line,
            pc: a.pc,
            kind: a.kind,
            is_prefetch: false,
            predicted_utility: None, // late-bound by the hierarchy's cache
            // Belady encoding: u64::MAX means "never" — keep as None.
            next_use: next_use.filter(|&t| t != u64::MAX),
        };
        self.hier.access(a, &meta);
        self.steps += 1;
        if self.steps % EMU_SAMPLE_PERIOD == 0 {
            let f = self.hier.l2.useful_fraction();
            if f.is_finite() {
                self.emu_acc += f;
                self.emu_samples += 1;
            }
        }
        if self.features_on {
            self.fx.push(a, &mut self.seq);
            Some(if self.row == FEATURE_DIM {
                &self.seq[(self.window - 1) * FEATURE_DIM..]
            } else {
                &self.seq[..]
            })
        } else {
            None
        }
    }

    /// Record a completed prediction (inline or from the predictor service).
    pub fn update_utility(&mut self, line: u64, utility: f32) -> bool {
        self.hier.update_utility(line, utility)
    }

    /// Time-averaged effective memory utilization sampled so far.
    pub fn emu(&self) -> f64 {
        if self.emu_samples > 0 {
            self.emu_acc / self.emu_samples as f64
        } else {
            f64::NAN
        }
    }

    /// Raw EMU accumulator (sum, sample count) for exact cross-shard
    /// averaging: merged EMU = Σ sums / Σ counts, not a mean of means.
    pub fn emu_parts(&self) -> (f64, u64) {
        (self.emu_acc, self.emu_samples)
    }

    pub fn latency_of(&self, lvl: ServiceLevel) -> u64 {
        self.hier.latency_of(lvl)
    }

    /// Harvest the run's metrics.
    pub fn report(&self, name: &str, tokens: u64) -> MetricsReport {
        MetricsReport::from_hierarchy(name, &self.hier, tokens, self.emu())
    }
}

/// Run one experiment on the workload the config describes (scenario or
/// profile). The predictor is taken by value inside `PredictorBox` so
/// learned runs can feed the online learner.
///
/// Crate-internal since the `RunSpec` API landed: external callers go
/// through [`crate::api::Runner::run`], for which this is a delegate.
pub(crate) fn run_experiment(cfg: &ExperimentConfig, predictor: &mut PredictorBox) -> SimResult {
    let mut workload = cfg.workload();
    run_workload(cfg, workload.as_mut(), predictor)
}

/// Run one experiment driving an explicit [`Workload`] through the shared
/// [`Engine`] — the single batch-mode access loop in the codebase.
/// Crate-internal delegate of [`crate::api::Runner::run`].
pub(crate) fn run_workload(
    cfg: &ExperimentConfig,
    workload: &mut dyn Workload,
    predictor: &mut PredictorBox,
) -> SimResult {
    run_workload_adaptive(cfg, workload, predictor, None, None)
}

/// The per-access pipeline around one [`Engine`]: feature observation,
/// prediction batching + flush, adaptive-controller windows and the legacy
/// §3.4 interval feedback. Extracted so the single-threaded batch path
/// ([`run_workload_adaptive`]) and each shard of the set-partitioned
/// simulator ([`super::shard`]) drive *the same* loop body — the sharded
/// run cannot diverge from the reference semantics.
///
/// Prediction flushes go through [`PredictorBox::predict_into`] with reused
/// line/feature/probability buffers: the steady-state predict path performs
/// no per-access heap allocation (asserted by `tests/alloc_predict.rs`).
pub(crate) struct AccessDriver<'a> {
    pub engine: Engine,
    batch: PredictionBatch,
    probs: Vec<f32>,
    predictor: &'a mut PredictorBox,
    controller: Option<&'a mut AdaptiveController>,
    learner: Option<OnlineLearner>,
    controller_learns: bool,
    feedback_interval: usize,
    prediction_batches: u64,
    pos: u64,
    /// Optional telemetry stream for this engine (one source per
    /// shard/run). Publishing is wait-free and allocation-free, and the
    /// emission points (window boundaries, fixed sample periods) are
    /// deterministic functions of the access stream — attaching a bus
    /// cannot perturb the simulation.
    publisher: Option<TelemetryPublisher>,
}

/// What an [`AccessDriver`] accumulated over its run.
pub(crate) struct DriverOutcome {
    pub engine: Engine,
    pub prediction_batches: u64,
    /// Legacy interval-feedback Adam steps (0 under a controller, which
    /// owns adaptation through its own replay learner).
    pub learner_steps: u64,
}

impl<'a> AccessDriver<'a> {
    pub(crate) fn new(
        cfg: &ExperimentConfig,
        engine: Engine,
        predictor: &'a mut PredictorBox,
        controller: Option<&'a mut AdaptiveController>,
        publisher: Option<TelemetryPublisher>,
    ) -> Self {
        // With a controller attached, its drift-triggered replay learner
        // owns online adaptation; running the legacy fixed-interval learner
        // as well would duplicate every feature row into a second replay
        // buffer and fine-tune the same weights from two uncoordinated
        // samplers.
        let learner = if cfg.feedback_interval > 0
            && predictor.model_mut().is_some()
            && controller.is_none()
        {
            Some(OnlineLearner::new(engine.row(), 4096, cfg.seed))
        } else {
            None
        };
        // The controller's replay buffer only pays off for trainable
        // predictors; heuristic runs adapt by throttling and skip the
        // per-access feature copies entirely.
        let controller_learns = predictor.model_mut().is_some();
        let batch = PredictionBatch::new(engine.row(), cfg.predict_batch);
        Self {
            engine,
            batch,
            probs: Vec::with_capacity(cfg.predict_batch.max(1)),
            predictor,
            controller,
            learner,
            controller_learns,
            feedback_interval: cfg.feedback_interval,
            prediction_batches: 0,
            pos: 0,
            publisher,
        }
    }

    /// Drive one access through the full pipeline.
    pub(crate) fn drive(&mut self, a: &Access, next_use: Option<u64>) {
        let i = self.pos;
        // Throttled controllers demote predictions to policy-default
        // insertion: rows are not even buffered (let alone inferred) while
        // throttled — the whole prediction pipeline is the cost the
        // back-off saves. Replay/telemetry observation continues so the
        // controller can still decide when to resume or retrain.
        let apply = self.controller.as_deref().map(|c| c.apply_predictions()).unwrap_or(true);
        // Touch the controller's unified last-touch map *before* feature
        // observation so the replay labeler sees the current access.
        if let Some(c) = self.controller.as_deref_mut() {
            c.observe_access(i, a.line());
        }
        let full = match self.engine.step(a, next_use) {
            Some(feats) => {
                if let Some(l) = self.learner.as_mut() {
                    l.observe(i, a.line(), feats);
                }
                if self.controller_learns {
                    if let Some(c) = self.controller.as_deref_mut() {
                        c.observe_features(i, a.line(), feats);
                    }
                }
                apply && self.batch.push(a.line(), feats)
            }
            None => false,
        };
        if full {
            self.predictor.predict_into(self.batch.x(), self.batch.len(), &mut self.probs);
            self.prediction_batches += 1;
            for (&l, &p) in self.batch.lines().iter().zip(&self.probs) {
                self.engine.update_utility(l, p);
            }
            self.batch.clear();
        }

        // Window boundary: telemetry harvest + drift detection + control.
        if let Some(c) = self.controller.as_deref_mut() {
            // Reborrow: the loop keeps using `predictor` afterwards.
            let access = if self.predictor.is_some() {
                PredictorAccess::Local(&mut *self.predictor)
            } else {
                PredictorAccess::None
            };
            let (windows_before, drifts_before, events_before) =
                (c.windows(), c.drift_count(), c.events().len());
            let decision = c.maybe_window(self.engine.steps(), &self.engine.hier, access);
            // Stream the boundary's outcomes before reacting to the
            // decision — events describe what the controller *observed*,
            // independent of how this driver applies it.
            if let Some(p) = self.publisher.as_mut() {
                let steps = self.engine.steps();
                if c.windows() > windows_before {
                    if let Some(w) = c.last_window() {
                        p.publish(steps, Payload::Window { stats: w, throttled: c.throttled() });
                        if c.drift_count() > drifts_before {
                            p.publish(steps, Payload::Drift { window: w.index });
                        }
                    }
                }
                for e in &c.events()[events_before..] {
                    p.publish(steps, Payload::Adaptation(*e));
                }
            }
            match decision {
                // Entering back-off: flush stale utilities so fills really
                // are policy-default from here on. A hot swap flushes too —
                // predictions from the pre-drift weights must not keep
                // steering evictions after the retrain. The partially-
                // filled batch is dropped for the same reason: its rows
                // were captured under the old regime and would re-stamp
                // stale predictions after a later resume/flush.
                // Throttling additionally turns prefetching conservative:
                // the hierarchy raises its prefetch-filter threshold until
                // the controller resumes or hot-swaps in fresh weights.
                Some(ControlDecision::Throttled) => {
                    self.engine.hier.clear_utilities();
                    self.engine.hier.set_prefetch_throttled(true);
                    self.batch.clear();
                }
                Some(ControlDecision::Retrained) => {
                    self.engine.hier.clear_utilities();
                    self.engine.hier.set_prefetch_throttled(false);
                    self.batch.clear();
                }
                Some(ControlDecision::Resumed) => {
                    self.engine.hier.set_prefetch_throttled(false);
                }
                None => {}
            }
        }

        // Periodic cache-health sample — the only event kind non-adaptive
        // runs produce. Cumulative counters, O(1) reads, zero allocation.
        if self.publisher.is_some() && self.engine.steps() % crate::obs::SAMPLE_PERIOD == 0 {
            let throttled =
                self.controller.as_deref().map(|c| c.throttled()).unwrap_or(false);
            let l2 = &self.engine.hier.l2;
            let sample = Payload::Sample {
                occupancy: l2.occupancy(),
                hit_rate: l2.stats.hit_rate(),
                pollution: l2.stats.pollution_ratio(),
                throttled,
            };
            if let Some(p) = self.publisher.as_mut() {
                p.publish(self.engine.steps(), sample);
            }
        }

        // Online feedback (§3.4).
        if self.feedback_interval > 0 && i > 0 && i as usize % self.feedback_interval == 0 {
            if let Some(l) = self.learner.as_mut() {
                if let Some(model) = self.predictor.model_mut() {
                    l.train(model, 2);
                }
            }
        }
        self.pos += 1;
    }

    pub(crate) fn finish(self) -> DriverOutcome {
        DriverOutcome {
            engine: self.engine,
            prediction_batches: self.prediction_batches,
            learner_steps: self.learner.map(|l| l.steps_run).unwrap_or(0),
        }
    }
}

/// [`run_workload`] with an optional [`AdaptiveController`] closing the
/// loop: per-access telemetry feeds the controller, predictions are only
/// applied while the controller allows them (throttle demotes fills to
/// policy-default insertion), and window boundaries run drift detection /
/// replay-buffer fine-tuning. `controller = None` is byte-identical to the
/// plain run. With a controller attached, the controller's drift-triggered
/// learner replaces the legacy fixed-interval §3.4 feedback
/// (`cfg.feedback_interval` is ignored).
///
/// `publisher` optionally streams window/drift/adaptation/sample events for
/// this engine onto a [`crate::obs::TelemetryBus`]; `None` skips every
/// telemetry branch and is byte-identical in outcome either way.
/// Crate-internal delegate of [`crate::api::Runner::run`].
pub(crate) fn run_workload_adaptive(
    cfg: &ExperimentConfig,
    workload: &mut dyn Workload,
    predictor: &mut PredictorBox,
    controller: Option<&mut AdaptiveController>,
    publisher: Option<TelemetryPublisher>,
) -> SimResult {
    let t0 = Instant::now();
    let geom = GeometryHints::from_generator(&cfg.generator);
    let pw = if predictor.is_some() { predictor.window().max(1) } else { 0 };
    let engine = Engine::new(cfg.hierarchy.clone(), &cfg.policy, geom, pw);

    // Oracle mode pre-materializes the trace for next-use annotation.
    let (trace_vec, next_use) = if cfg.policy == "belady" {
        let tv = workload.generate(cfg.accesses);
        let nu = super::oracle::annotate_next_use(&tv);
        (Some(tv), Some(nu))
    } else {
        (None, None)
    };

    let mut driver = AccessDriver::new(cfg, engine, predictor, controller, publisher);
    for i in 0..cfg.accesses {
        let a = match &trace_vec {
            Some(tv) => tv[i],
            None => workload.next_access(),
        };
        driver.drive(&a, next_use.as_ref().map(|nu| nu[i]));
    }

    let controller_stats = driver.controller.as_deref().map(|c| {
        (
            c.windows(),
            c.drift_count(),
            c.swap_count(),
            c.throttled_windows(),
            c.online_train_steps(),
        )
    });
    let out = driver.finish();

    let tokens = workload.tokens_done();
    let traffic = workload.traffic();
    let emu = out.engine.emu();
    let report = out.engine.report(&cfg.name, tokens);
    let wall = t0.elapsed().as_secs_f64();
    let (adapt_windows, drift_events, predictor_swaps, throttled_windows, controller_steps) =
        controller_stats.unwrap_or((0, 0, 0, 0, 0));
    SimResult {
        report,
        tokens,
        emu,
        predictor: predictor.name(),
        prediction_batches: out.prediction_batches,
        // Interval-feedback steps (legacy §3.4) or the controller's
        // drift-triggered replay steps — at most one learner exists.
        online_train_steps: out.learner_steps + controller_steps,
        wall_secs: wall,
        accesses_per_sec: cfg.accesses as f64 / wall,
        adapt_windows,
        drift_events,
        predictor_swaps,
        throttled_windows,
        traffic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::predictor::HeuristicPredictor;

    #[test]
    fn smoke_run_all_classic_policies() {
        for policy in ["lru", "srrip", "dip", "ship", "plru", "random"] {
            let cfg = ExperimentConfig::smoke(policy);
            let mut p = PredictorBox::None;
            let r = run_experiment(&cfg, &mut p);
            assert_eq!(r.report.accesses as usize, cfg.accesses, "{policy}");
            assert!(r.report.l2_hit_rate > 0.0 && r.report.l2_hit_rate < 1.0, "{policy}");
            assert!(r.tokens > 0);
            assert!(r.emu > 0.0 && r.emu <= 1.0, "{policy}: emu {}", r.emu);
        }
    }

    #[test]
    fn belady_upper_bounds_lru() {
        let lru = run_experiment(&ExperimentConfig::smoke("lru"), &mut PredictorBox::None);
        let bel = run_experiment(&ExperimentConfig::smoke("belady"), &mut PredictorBox::None);
        assert!(
            bel.report.l2_hit_rate >= lru.report.l2_hit_rate - 0.005,
            "belady {:.4} must dominate lru {:.4}",
            bel.report.l2_hit_rate,
            lru.report.l2_hit_rate
        );
    }

    #[test]
    fn heuristic_acpc_beats_lru_and_cuts_pollution() {
        let mut cfg = ExperimentConfig::smoke("acpc");
        cfg.accesses = 120_000;
        let mut p = PredictorBox::Heuristic(HeuristicPredictor);
        let acpc = run_experiment(&cfg, &mut p);

        let mut cfg_lru = ExperimentConfig::smoke("lru");
        cfg_lru.accesses = 120_000;
        let lru = run_experiment(&cfg_lru, &mut PredictorBox::None);

        assert!(acpc.prediction_batches > 0);
        assert!(
            acpc.report.l2_hit_rate > lru.report.l2_hit_rate,
            "acpc {:.4} vs lru {:.4}",
            acpc.report.l2_hit_rate,
            lru.report.l2_hit_rate
        );
        assert!(
            acpc.report.l2_pollution_ratio < lru.report.l2_pollution_ratio,
            "pollution acpc {:.4} vs lru {:.4}",
            acpc.report.l2_pollution_ratio,
            lru.report.l2_pollution_ratio
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ExperimentConfig::smoke("srrip");
        let a = run_experiment(&cfg, &mut PredictorBox::None);
        let b = run_experiment(&cfg, &mut PredictorBox::None);
        assert_eq!(a.report.l2_hit_rate, b.report.l2_hit_rate);
        assert_eq!(a.report.l2_miss_cycles, b.report.l2_miss_cycles);
    }

    #[test]
    fn engine_runs_any_scenario_workload() {
        use crate::trace::Scenario;
        let cfg = ExperimentConfig::smoke("lru");
        for sc in Scenario::all() {
            let mut w = sc.workload(5);
            let mut c = cfg.clone();
            c.accesses = 20_000;
            let r = run_workload(&c, w.as_mut(), &mut PredictorBox::None);
            assert_eq!(r.report.accesses, 20_000, "{}", sc.name);
            assert!(r.tokens > 0, "{}", sc.name);
        }
    }

    /// The throttle satellite: a controller entering back-off must also
    /// flip the hierarchy into the conservative prefetch regime (raised
    /// filter threshold), not just stop applying utilities.
    #[test]
    fn throttled_windows_raise_prefetch_filter_threshold() {
        use crate::adapt::{AdaptiveController, ControllerConfig};
        let mut cfg = ExperimentConfig::smoke("acpc");
        cfg.accesses = 12_000;
        // Rigged health test: every scored window after the EWMA seeds is
        // "unhealthy" (hit < ewma * 2.0), one such window throttles, and
        // recovery is unreachable — the run must end throttled.
        let ctl_cfg = ControllerConfig {
            window_accesses: 2048,
            warmup_windows: 1,
            cooldown_windows: 0,
            unhealthy_windows_to_throttle: 1,
            recover_windows: u64::MAX,
            throttle_hit_ratio: 2.0,
            ph_lambda: f64::INFINITY,
            ..ControllerConfig::default()
        };
        let mut controller = AdaptiveController::new(ctl_cfg);
        let mut predictor = PredictorBox::Heuristic(HeuristicPredictor);
        let geom = GeometryHints::from_generator(&cfg.generator);
        let engine =
            Engine::new(cfg.hierarchy.clone(), &cfg.policy, geom, predictor.window().max(1));
        let base = engine.hier.prefetch_filter_threshold;
        assert!(base.is_some(), "acpc runs filtered from the start");

        let mut workload = cfg.workload();
        let mut driver =
            AccessDriver::new(&cfg, engine, &mut predictor, Some(&mut controller), None);
        for _ in 0..cfg.accesses {
            let a = workload.next_access();
            driver.drive(&a, None);
        }
        let out = driver.finish();
        assert!(controller.throttled_windows() > 0, "rigged controller never throttled");
        assert!(out.engine.hier.prefetch_throttled());
        let raised = out.engine.hier.prefetch_filter_threshold.unwrap();
        assert!(
            raised > base.unwrap(),
            "throttle must raise the filter threshold ({raised} vs {:?})",
            base
        );
    }

    #[test]
    fn prediction_batch_fills_and_drains() {
        let mut b = PredictionBatch::new(2, 3);
        assert!(b.is_empty());
        assert!(!b.push(1, &[0.0, 1.0]));
        assert!(!b.push(2, &[2.0, 3.0]));
        assert!(b.push(3, &[4.0, 5.0]), "third push reaches capacity");
        let (lines, x) = b.take();
        assert_eq!(lines, vec![1, 2, 3]);
        assert_eq!(x.len(), 6);
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
    }
}
