//! Belady-oracle support: materialize the trace and annotate each access
//! with its line's next-use index so the `belady` policy can evict the
//! farthest-future line. Used only for upper-bound runs.

use crate::predictor::labeler;
use crate::trace::Access;

/// Per-access next-use time (u64::MAX = never reused).
pub fn annotate_next_use(trace: &[Access]) -> Vec<u64> {
    labeler::annotate(trace, 0).iter().map(|a| a.next_use.unwrap_or(u64::MAX)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    #[test]
    fn next_use_points_to_same_line() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(2)).generate(5_000);
        let nu = annotate_next_use(&trace);
        for (i, &j) in nu.iter().enumerate() {
            if j != u64::MAX {
                assert!(j as usize > i);
                assert_eq!(trace[j as usize].line(), trace[i].line());
            }
        }
    }
}
