//! End-to-end experiment driver: workload → feature extractor → (batched)
//! predictor → cache hierarchy (+prefetcher) → metrics. This is the module
//! the CLI, benches, coordinator and examples call into.
//!
//! - [`Engine`] — the shared per-access driving core (any [`crate::trace::Workload`]);
//! - [`run_experiment`] / [`run_workload`] — batch-mode runs producing a [`SimResult`];
//! - [`run_workload_adaptive`] — same loop with an [`crate::adapt::AdaptiveController`];
//! - [`shard`] — set-sharded single-cell simulation: one run split across
//!   N worker threads by cache-set partition, with exact stat merging;
//! - [`sweep`] — the multi-threaded policy×scenario×predictor grid runner;
//! - [`table1`] — the paper's Table 1 pipeline built on the above.

mod engine;
mod oracle;
pub mod shard;
pub mod sweep;
pub mod table1;

// `OnlineLearner` moved to `crate::adapt`; re-exported here for the
// historical `sim::OnlineLearner` path.
pub use crate::adapt::OnlineLearner;
pub use engine::{
    run_experiment, run_workload, run_workload_adaptive, Engine, PredictionBatch, SimResult,
};
pub use oracle::annotate_next_use;
pub use shard::{run_workload_sharded, ShardedRun};
pub use sweep::{cell_seed, run_sweep, SweepCell, SweepConfig};
pub use table1::{run_table1, Table1Output, Table1Scale};
