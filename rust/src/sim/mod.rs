//! End-to-end experiment driver: trace generator → feature extractor →
//! (batched) predictor → cache hierarchy (+prefetcher) → metrics. This is
//! the module the CLI, benches and examples call into.

mod oracle;
mod simulator;
pub mod table1;

pub use oracle::annotate_next_use;
pub use simulator::{run_experiment, OnlineLearner, SimResult};
pub use table1::{run_table1, Table1Output, Table1Scale};
