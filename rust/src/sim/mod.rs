//! End-to-end experiment driver: workload → feature extractor → (batched)
//! predictor → cache hierarchy (+prefetcher) → metrics.
//!
//! Since the `RunSpec` API landed, the public run entrypoint is
//! [`crate::api::Runner::run`] — this module provides the machinery under
//! it:
//!
//! - [`Engine`] — the shared per-access driving core (any [`crate::trace::Workload`]);
//! - `run_experiment` / `run_workload` / `run_workload_adaptive` —
//!   crate-internal batch-mode delegates producing a [`SimResult`];
//! - `shard` — set-sharded single-cell simulation: one run split across
//!   N worker threads by cache-set partition, with exact stat merging and
//!   a persistent per-thread worker pool;
//! - [`sweep`] — the multi-threaded policy×scenario×predictor grid runner
//!   (each cell executes through the [`crate::api::Runner`]);
//! - [`table1`] — the paper's Table 1 pipeline built on the above.

mod engine;
mod oracle;
pub(crate) mod shard;
pub mod sweep;
pub mod table1;

// `OnlineLearner` moved to `crate::adapt`; re-exported here for the
// historical `sim::OnlineLearner` path.
pub use crate::adapt::OnlineLearner;
pub use engine::{Engine, PredictionBatch, SimResult};
pub(crate) use engine::{run_experiment, run_workload, run_workload_adaptive};
pub use oracle::annotate_next_use;
pub use sweep::{cell_seed, run_sweep, SweepCell, SweepConfig};
pub use table1::{run_table1, Table1Output, Table1Scale};
