//! The experiment config: one struct, JSON-overridable, preset-seeded.

use crate::mem::HierarchyConfig;
use crate::trace::{GeneratorConfig, ModelProfile};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// Which learned predictor (if any) feeds the L2 policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorKind {
    /// No learned predictor (classic policies).
    None,
    /// Flattened-window MLP — the paper's ML-Predict baseline.
    Dnn,
    /// Temporal CNN — the paper's ACPC predictor.
    Tcn,
    /// Cheap frequency heuristic (tests / predictor-free ACPC ablation).
    Heuristic,
}

impl PredictorKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => Self::None,
            "dnn" => Self::Dnn,
            "tcn" => Self::Tcn,
            "heuristic" => Self::Heuristic,
            _ => bail!("unknown predictor '{s}' (none|dnn|tcn|heuristic)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Dnn => "dnn",
            Self::Tcn => "tcn",
            Self::Heuristic => "heuristic",
        }
    }
}

/// Everything needed to reproduce one simulation run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    /// L2 replacement policy under test (see `policy::POLICY_NAMES`).
    pub policy: String,
    pub predictor: PredictorKind,
    pub hierarchy: HierarchyConfig,
    pub generator: GeneratorConfig,
    /// Scenario-registry name when the generator came from a scenario
    /// (`trace::Scenario`); provenance only — `generator` is authoritative.
    pub scenario: Option<String>,
    /// Number of accesses to simulate.
    pub accesses: usize,
    /// Predictor batch size (accesses buffered before a model invocation).
    pub predict_batch: usize,
    /// Online-learning feedback: retrain every N accesses (0 = off).
    pub feedback_interval: usize,
    pub seed: u64,
}

impl ExperimentConfig {
    /// The Table 1 workload: GPT-style decode mix over the scaled hierarchy
    /// with the composite prefetcher.
    pub fn table1(policy: &str, predictor: PredictorKind) -> Self {
        let seed = 0xAC9C_2025;
        Self {
            name: format!("table1-{policy}"),
            policy: policy.into(),
            predictor,
            hierarchy: HierarchyConfig::scaled(),
            generator: GeneratorConfig::new(ModelProfile::gpt3ish(), seed),
            scenario: None,
            accesses: 2_000_000,
            predict_batch: 256,
            feedback_interval: 0,
            seed,
        }
    }

    /// Config for one scenario-registry workload (see `trace::scenario`).
    /// Errors on unknown scenario names.
    pub fn for_scenario(
        scenario: &str,
        policy: &str,
        predictor: PredictorKind,
        seed: u64,
    ) -> Result<Self> {
        let mut c = Self::table1(policy, predictor);
        c.name = format!("{scenario}-{policy}");
        c.seed = seed;
        c.generator.seed = seed;
        c.set_scenario(scenario)?;
        Ok(c)
    }

    /// Resolve `name` in the scenario registry and stamp its generator
    /// config (at the current seed) into `self`. The single scenario→config
    /// path shared by the CLI, JSON overrides and the sweep runner.
    pub fn set_scenario(&mut self, name: &str) -> Result<()> {
        let sc = crate::trace::Scenario::by_name(name)
            .ok_or_else(|| anyhow!("unknown scenario '{name}' (see `acpc policies`)"))?;
        self.generator = sc.config(self.generator.seed);
        self.scenario = Some(name.to_string());
        Ok(())
    }

    /// Build the workload this config describes, boxed behind the
    /// `Workload` trait the sim `Engine` drives. Scenario provenance
    /// decides the shape: traffic scenarios (`prefix-share`,
    /// `bursty-batch`) build their population / open-loop workloads, every
    /// other config the plain generator over `self.generator`.
    pub fn workload(&self) -> Box<dyn crate::trace::Workload> {
        if let Some(sc) =
            self.scenario.as_deref().and_then(crate::trace::Scenario::by_name)
        {
            return sc.workload_from(self.generator.clone());
        }
        Box::new(crate::trace::TraceGenerator::new(self.generator.clone()))
    }

    /// Fast config for tests.
    pub fn smoke(policy: &str) -> Self {
        let seed = 7;
        let mut c = Self::table1(policy, PredictorKind::None);
        c.name = format!("smoke-{policy}");
        c.generator = GeneratorConfig::tiny(seed);
        c.accesses = 50_000;
        c.seed = seed;
        c
    }

    /// Apply JSON overrides on top of `self`. Unknown keys are errors (typo
    /// protection); nested objects override field-wise.
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("config root must be an object"))?;
        for (k, v) in obj {
            match k.as_str() {
                "name" => self.name = v.as_str().ok_or_else(|| anyhow!("name"))?.to_string(),
                "policy" => {
                    let p = v.as_str().ok_or_else(|| anyhow!("policy"))?;
                    if crate::policy::make_policy(p, 2, 2, 0).is_none() {
                        bail!("unknown policy '{p}'");
                    }
                    self.policy = p.to_string();
                }
                "predictor" => {
                    self.predictor =
                        PredictorKind::parse(v.as_str().ok_or_else(|| anyhow!("predictor"))?)?
                }
                "accesses" => self.accesses = v.as_usize().ok_or_else(|| anyhow!("accesses"))?,
                "predict_batch" => {
                    self.predict_batch = v.as_usize().ok_or_else(|| anyhow!("predict_batch"))?
                }
                "feedback_interval" => {
                    self.feedback_interval = v.as_usize().ok_or_else(|| anyhow!("feedback_interval"))?
                }
                "seed" => {
                    self.seed = v.as_i64().ok_or_else(|| anyhow!("seed"))? as u64;
                    self.generator.seed = self.seed;
                }
                "hierarchy" => self.apply_hierarchy(v)?,
                "workload" => self.apply_workload(v)?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        Ok(())
    }

    fn apply_hierarchy(&mut self, j: &Json) -> Result<()> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("hierarchy must be an object"))?;
        for (k, v) in obj {
            match k.as_str() {
                "preset" => {
                    let name = v.as_str().ok_or_else(|| anyhow!("preset"))?;
                    self.hierarchy = HierarchyConfig::by_name(name)
                        .ok_or_else(|| anyhow!("unknown hierarchy preset '{name}'"))?;
                }
                "prefetcher" => {
                    let p = v.as_str().ok_or_else(|| anyhow!("prefetcher"))?;
                    if crate::mem::prefetch::make_prefetcher(p, 0).is_none() {
                        bail!("unknown prefetcher '{p}'");
                    }
                    self.hierarchy.prefetcher = p.to_string();
                }
                "l3_policy" => {
                    let p = v.as_str().ok_or_else(|| anyhow!("l3_policy"))?;
                    if crate::policy::make_policy(p, 2, 2, 0).is_none() {
                        bail!("unknown l3_policy '{p}'");
                    }
                    self.hierarchy.l3_policy = p.to_string();
                }
                "l1_kb" => self.hierarchy.l1.size_bytes = num(v, "l1_kb")? * 1024,
                "l2_kb" => self.hierarchy.l2.size_bytes = num(v, "l2_kb")? * 1024,
                "l3_kb" => self.hierarchy.l3.size_bytes = num(v, "l3_kb")? * 1024,
                "l1_assoc" => self.hierarchy.l1.assoc = num(v, "l1_assoc")? as usize,
                "l2_assoc" => self.hierarchy.l2.assoc = num(v, "l2_assoc")? as usize,
                "l3_assoc" => self.hierarchy.l3.assoc = num(v, "l3_assoc")? as usize,
                "dram_latency" => self.hierarchy.dram_latency = num(v, "dram_latency")?,
                other => bail!("unknown hierarchy key '{other}'"),
            }
        }
        // Config-time geometry validation: a bad size/assoc combination is a
        // user error surfaced here, not a panic deep in `Cache::new`.
        self.hierarchy.validate().map_err(|e| anyhow!(e))?;
        Ok(())
    }

    fn apply_workload(&mut self, j: &Json) -> Result<()> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("workload must be an object"))?;
        if obj.get("scenario").is_some() && obj.get("profile").is_some() {
            bail!("workload: 'scenario' and 'profile' are mutually exclusive");
        }
        // `scenario`/`profile` reset the whole generator, so they must apply
        // before any sibling keys regardless of JSON object order.
        if let Some(v) = obj.get("scenario") {
            let name = v.as_str().ok_or_else(|| anyhow!("scenario"))?;
            self.set_scenario(name)?;
        }
        if let Some(v) = obj.get("profile") {
            let name = v.as_str().ok_or_else(|| anyhow!("profile"))?;
            let profile = ModelProfile::by_name(name)
                .ok_or_else(|| anyhow!("unknown model profile '{name}'"))?;
            let seed = self.generator.seed;
            self.generator = GeneratorConfig::new(profile, seed);
            self.scenario = None;
        }
        for (k, v) in obj {
            match k.as_str() {
                "profile" | "scenario" => {}
                "max_live_sessions" => {
                    self.generator.max_live_sessions = num(v, "max_live_sessions")? as usize
                }
                "phase_period" => self.generator.phase_period = num(v, "phase_period")?,
                "max_ctx" => self.generator.max_ctx = num(v, "max_ctx")? as u32,
                "arrival_p_hot" => {
                    self.generator.arrival_p_hot = v.as_f64().ok_or_else(|| anyhow!("arrival_p_hot"))?
                }
                "arrival_p_cold" => {
                    self.generator.arrival_p_cold =
                        v.as_f64().ok_or_else(|| anyhow!("arrival_p_cold"))?
                }
                other => bail!("unknown workload key '{other}'"),
            }
        }
        Ok(())
    }

    /// Load from a JSON file over the table1 preset.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let base = j.get("preset").and_then(|p| p.as_str()).unwrap_or("table1");
        let mut cfg = match base {
            "table1" => Self::table1("lru", PredictorKind::None),
            "smoke" => Self::smoke("lru"),
            other => bail!("unknown preset '{other}'"),
        };
        // `preset` itself is consumed above.
        if let Json::Obj(mut m) = j {
            m.remove("preset");
            cfg.apply_json(&Json::Obj(m))?;
        }
        Ok(cfg)
    }

    /// Serialize the *effective* config for report provenance.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", Json::Str(self.name.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("predictor", Json::Str(self.predictor.label().into())),
            ("accesses", Json::Num(self.accesses as f64)),
            ("predict_batch", Json::Num(self.predict_batch as f64)),
            ("feedback_interval", Json::Num(self.feedback_interval as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("scenario", Json::Str(self.scenario.clone().unwrap_or_else(|| "-".into()))),
            ("profile", Json::Str(self.generator.profile.name.clone())),
            ("prefetcher", Json::Str(self.hierarchy.prefetcher.clone())),
            ("l2_kb", Json::Num(self.hierarchy.l2.size_bytes as f64 / 1024.0)),
        ])
    }
}

fn num(v: &Json, what: &str) -> Result<u64> {
    v.as_f64().map(|x| x as u64).ok_or_else(|| anyhow!("{what} must be a number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_construct() {
        let t = ExperimentConfig::table1("acpc", PredictorKind::Tcn);
        assert_eq!(t.policy, "acpc");
        assert_eq!(t.predictor, PredictorKind::Tcn);
        let s = ExperimentConfig::smoke("lru");
        assert!(s.accesses < t.accesses);
    }

    #[test]
    fn json_overrides_apply() {
        let mut c = ExperimentConfig::table1("lru", PredictorKind::None);
        let j = Json::parse(
            r#"{"policy": "srrip", "accesses": 1000,
                "hierarchy": {"l2_kb": 128, "prefetcher": "stride", "l3_policy": "srrip"},
                "workload": {"profile": "llama2", "max_ctx": 256}}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.policy, "srrip");
        assert_eq!(c.accesses, 1000);
        assert_eq!(c.hierarchy.l2.size_bytes, 128 * 1024);
        assert_eq!(c.hierarchy.prefetcher, "stride");
        assert_eq!(c.hierarchy.l3_policy, "srrip");
        // Unknown L3 policies are rejected at the config boundary.
        assert!(c
            .apply_json(&Json::parse(r#"{"hierarchy": {"l3_policy": "nope"}}"#).unwrap())
            .is_err());
        assert_eq!(c.generator.profile.name, "llama2ish");
        assert_eq!(c.generator.max_ctx, 256);
    }

    #[test]
    fn unknown_keys_rejected() {
        let mut c = ExperimentConfig::table1("lru", PredictorKind::None);
        assert!(c.apply_json(&Json::parse(r#"{"polcy": "lru"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"policy": "nope"}"#).unwrap()).is_err());
        assert!(c
            .apply_json(&Json::parse(r#"{"hierarchy": {"l9_kb": 1}}"#).unwrap())
            .is_err());
    }

    #[test]
    fn scenario_constructor_and_json_key() {
        let c = ExperimentConfig::for_scenario("rag-embedding", "lru", PredictorKind::None, 9)
            .unwrap();
        assert_eq!(c.scenario.as_deref(), Some("rag-embedding"));
        assert_eq!(c.generator.profile.name, "rag-embedding");
        assert_eq!(c.generator.seed, 9);
        assert!(ExperimentConfig::for_scenario("nope", "lru", PredictorKind::None, 9).is_err());

        let mut c = ExperimentConfig::table1("lru", PredictorKind::None);
        c.apply_json(&Json::parse(r#"{"workload": {"scenario": "long-context"}}"#).unwrap())
            .unwrap();
        assert_eq!(c.scenario.as_deref(), Some("long-context"));
        assert_eq!(c.generator.max_ctx, 2048);
        // scenario+profile together is ambiguous.
        let mut c2 = ExperimentConfig::table1("lru", PredictorKind::None);
        assert!(c2
            .apply_json(
                &Json::parse(r#"{"workload": {"scenario": "long-context", "profile": "t5"}}"#)
                    .unwrap()
            )
            .is_err());
    }

    #[test]
    fn invalid_hierarchy_geometry_is_a_config_error() {
        let mut c = ExperimentConfig::table1("lru", PredictorKind::None);
        // 96 KiB / 8-way / 64 B lines → 192 sets: not a power of two.
        let err = c.apply_json(&Json::parse(r#"{"hierarchy": {"l2_kb": 96}}"#).unwrap());
        assert!(err.is_err(), "non-power-of-two geometry must be rejected");
    }

    #[test]
    fn provenance_roundtrip() {
        let c = ExperimentConfig::table1("acpc", PredictorKind::Tcn);
        let j = c.to_json();
        assert_eq!(j.get("policy").unwrap().as_str(), Some("acpc"));
        assert_eq!(j.get("predictor").unwrap().as_str(), Some("tcn"));
    }
}
