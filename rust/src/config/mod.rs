//! Experiment configuration system: JSON config files + named presets.
//!
//! A single [`ExperimentConfig`] describes everything needed to reproduce a
//! run: workload (model profile + generator knobs), hierarchy, policy,
//! predictor integration, and trace length. Configs load from JSON
//! (`acpc simulate --config path.json`) with every field optional on top of
//! a named preset — the same mechanism the benches use, so bench rows and
//! CLI runs cannot drift apart.

mod experiment;

pub use experiment::{ExperimentConfig, PredictorKind};
