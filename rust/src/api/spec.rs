//! [`RunSpec`] — the serializable description of one simulation run, and
//! the single source of truth the [`super::Runner`] resolves and executes.
//!
//! A spec captures everything a run needs: policy, workload (scenario or
//! model profile plus generator overrides), predictor kind and artifact
//! override, hierarchy preset and geometry overrides, trace length,
//! set-shard count, the adaptive-controller configuration, and the seed.
//! Specs round-trip through JSON (schema [`SCHEMA`]) via the crate's own
//! [`Json`] — `acpc run --spec file.json` and the library build the exact
//! same run from the exact same bytes.
//!
//! Resolution ([`RunSpec::resolve`]) turns a spec into the concrete
//! [`ExperimentConfig`] + shard count + [`ControllerConfig`] the engine
//! consumes, validating everything at the boundary (unknown policies,
//! scenario/profile conflicts, bad cache geometry, unshardable hierarchies)
//! and deriving a *fully-resolved* copy of the spec — every defaulted
//! scalar made explicit — which [`super::RunReport`] embeds so any report
//! JSON reproduces its run bit-for-bit.

use crate::adapt::ControllerConfig;
use crate::config::{ExperimentConfig, PredictorKind};
use crate::predictor::Backend;
use crate::trace::ModelProfile;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// Schema identifier stamped into spec and report JSON.
pub const SCHEMA: &str = "acpc-run-v1";

/// Workload-generator overrides layered on top of the scenario/profile.
/// `None` = inherit whatever the resolved generator config says.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkloadSpec {
    pub max_live_sessions: Option<usize>,
    pub phase_period: Option<u64>,
    pub max_ctx: Option<u32>,
    pub arrival_p_hot: Option<f64>,
    pub arrival_p_cold: Option<f64>,
}

/// The spec's `traffic` block: either an open-loop arrival process laid
/// over the workload (offered rate decoupled from service rate, bounded
/// admission queue — see [`crate::traffic::arrivals`]) or `replay`, which
/// substitutes the whole workload with a captured `.acpctrace` played back
/// bit-for-bit. `None` fields take the open-loop defaults; `replay` is
/// mutually exclusive with every other knob in the block and with
/// `scenario`/`profile`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TrafficSpec {
    /// Arrival process: `poisson` (default), `diurnal`, or `bursty`.
    pub arrivals: Option<String>,
    /// Mean offered rate, requests per 1000 access ticks.
    pub rate: Option<f64>,
    /// Diurnal cycle length in ticks.
    pub period: Option<u64>,
    /// Diurnal swing as a fraction of the base rate, in `[0, 1]`.
    pub amplitude: Option<f64>,
    /// Hot-state rate multiplier of the bursty process.
    pub burst_factor: Option<f64>,
    /// Per-tick probability of toggling the bursty hidden state.
    pub burst_switch_p: Option<f64>,
    /// Admission-queue capacity; arrivals beyond it are shed.
    pub queue_depth: Option<usize>,
    /// Path to a captured `.acpctrace` to replay instead of generating.
    pub replay: Option<String>,
}

impl TrafficSpec {
    fn has_open_loop_fields(&self) -> bool {
        self.arrivals.is_some()
            || self.rate.is_some()
            || self.period.is_some()
            || self.amplitude.is_some()
            || self.burst_factor.is_some()
            || self.burst_switch_p.is_some()
            || self.queue_depth.is_some()
    }

    /// Spec view of a concrete open-loop config, every field explicit
    /// (the resolved-spec analogue of [`AdaptSpec::from_config`]).
    fn from_open_loop(c: &crate::traffic::OpenLoopConfig) -> Self {
        Self {
            arrivals: Some(c.kind.label().to_string()),
            rate: Some(c.rate),
            period: Some(c.period),
            amplitude: Some(c.amplitude),
            burst_factor: Some(c.burst_factor),
            burst_switch_p: Some(c.burst_switch_p),
            queue_depth: Some(c.queue_depth),
            replay: None,
        }
    }

    /// Concrete open-loop config; unset fields take the defaults, the RNG
    /// stream seeds from the run seed.
    fn resolve_open_loop(&self, run_seed: u64) -> Result<crate::traffic::OpenLoopConfig> {
        let kind =
            crate::traffic::ArrivalKind::parse(self.arrivals.as_deref().unwrap_or("poisson"))?;
        let mut ol = crate::traffic::OpenLoopConfig::new(kind, run_seed);
        if let Some(v) = self.rate {
            ol.rate = v;
        }
        if let Some(v) = self.period {
            ol.period = v;
        }
        if let Some(v) = self.amplitude {
            ol.amplitude = v;
        }
        if let Some(v) = self.burst_factor {
            ol.burst_factor = v;
        }
        if let Some(v) = self.burst_switch_p {
            ol.burst_switch_p = v;
        }
        if let Some(v) = self.queue_depth {
            ol.queue_depth = v;
        }
        ol.validate()?;
        Ok(ol)
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        if let Some(v) = &self.arrivals {
            j.set("arrivals", Json::Str(v.clone()));
        }
        if let Some(v) = self.rate {
            j.set("rate", f64_json(v));
        }
        if let Some(v) = self.period {
            j.set("period", Json::Num(v as f64));
        }
        if let Some(v) = self.amplitude {
            j.set("amplitude", f64_json(v));
        }
        if let Some(v) = self.burst_factor {
            j.set("burst_factor", f64_json(v));
        }
        if let Some(v) = self.burst_switch_p {
            j.set("burst_switch_p", f64_json(v));
        }
        if let Some(v) = self.queue_depth {
            j.set("queue_depth", Json::Num(v as f64));
        }
        if let Some(v) = &self.replay {
            j.set("replay", Json::Str(v.clone()));
        }
        j
    }

    fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("'traffic' must be an object"))?;
        let mut s = Self::default();
        for (k, v) in obj {
            match k.as_str() {
                "arrivals" => s.arrivals = Some(str_field(v, k)?),
                "rate" => s.rate = Some(f64_field(v, k)?),
                "period" => s.period = Some(u64_field(v, k)?),
                "amplitude" => s.amplitude = Some(f64_field(v, k)?),
                "burst_factor" => s.burst_factor = Some(f64_field(v, k)?),
                "burst_switch_p" => s.burst_switch_p = Some(f64_field(v, k)?),
                "queue_depth" => s.queue_depth = Some(u64_field(v, k)? as usize),
                "replay" => s.replay = Some(str_field(v, k)?),
                other => bail!("unknown traffic key '{other}'"),
            }
        }
        Ok(s)
    }
}

/// Hierarchy overrides layered on top of the preset. Sizes are in KiB
/// (matching the CLI/JSON config convention).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HierarchySpec {
    pub preset: Option<String>,
    pub prefetcher: Option<String>,
    pub l3_policy: Option<String>,
    pub l1_kb: Option<u64>,
    pub l2_kb: Option<u64>,
    pub l3_kb: Option<u64>,
    pub l1_assoc: Option<usize>,
    pub l2_assoc: Option<usize>,
    pub l3_assoc: Option<usize>,
    pub dram_latency: Option<u64>,
}

impl HierarchySpec {
    fn is_empty(&self) -> bool {
        *self == Self::default()
    }

    /// Layer these overrides onto `cfg` (preset swap first, then geometry),
    /// validating names and the final geometry. Shared by the run spec and
    /// the serve spec so the two cannot drift on hierarchy semantics.
    pub(crate) fn apply(&self, cfg: &mut crate::mem::HierarchyConfig) -> Result<()> {
        if let Some(name) = &self.preset {
            *cfg = crate::mem::HierarchyConfig::by_name(name)
                .ok_or_else(|| anyhow!("unknown hierarchy preset '{name}'"))?;
        }
        if let Some(p) = &self.prefetcher {
            if crate::mem::prefetch::make_prefetcher(p, 0).is_none() {
                bail!("unknown prefetcher '{p}'");
            }
            cfg.prefetcher = p.clone();
        }
        if let Some(p) = &self.l3_policy {
            if crate::policy::make_policy(p, 2, 2, 0).is_none() {
                bail!("unknown l3_policy '{p}'");
            }
            cfg.l3_policy = p.clone();
        }
        if let Some(v) = self.l1_kb {
            cfg.l1.size_bytes = v * 1024;
        }
        if let Some(v) = self.l2_kb {
            cfg.l2.size_bytes = v * 1024;
        }
        if let Some(v) = self.l3_kb {
            cfg.l3.size_bytes = v * 1024;
        }
        if let Some(v) = self.l1_assoc {
            cfg.l1.assoc = v;
        }
        if let Some(v) = self.l2_assoc {
            cfg.l2.assoc = v;
        }
        if let Some(v) = self.l3_assoc {
            cfg.l3.assoc = v;
        }
        if let Some(v) = self.dram_latency {
            cfg.dram_latency = v;
        }
        cfg.validate().map_err(|e| anyhow!("invalid hierarchy geometry: {e}"))
    }

    pub(crate) fn to_json(&self) -> Json {
        let mut hv = Json::obj();
        if let Some(v) = &self.preset {
            hv.set("preset", Json::Str(v.clone()));
        }
        if let Some(v) = &self.prefetcher {
            hv.set("prefetcher", Json::Str(v.clone()));
        }
        if let Some(v) = &self.l3_policy {
            hv.set("l3_policy", Json::Str(v.clone()));
        }
        if let Some(v) = self.l1_kb {
            hv.set("l1_kb", Json::Num(v as f64));
        }
        if let Some(v) = self.l2_kb {
            hv.set("l2_kb", Json::Num(v as f64));
        }
        if let Some(v) = self.l3_kb {
            hv.set("l3_kb", Json::Num(v as f64));
        }
        if let Some(v) = self.l1_assoc {
            hv.set("l1_assoc", Json::Num(v as f64));
        }
        if let Some(v) = self.l2_assoc {
            hv.set("l2_assoc", Json::Num(v as f64));
        }
        if let Some(v) = self.l3_assoc {
            hv.set("l3_assoc", Json::Num(v as f64));
        }
        if let Some(v) = self.dram_latency {
            hv.set("dram_latency", Json::Num(v as f64));
        }
        hv
    }

    pub(crate) fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("'hierarchy' must be an object"))?;
        let mut h = Self::default();
        for (k, v) in obj {
            match k.as_str() {
                "preset" => h.preset = Some(str_field(v, k)?),
                "prefetcher" => h.prefetcher = Some(str_field(v, k)?),
                "l3_policy" => h.l3_policy = Some(str_field(v, k)?),
                "l1_kb" => h.l1_kb = Some(u64_field(v, k)?),
                "l2_kb" => h.l2_kb = Some(u64_field(v, k)?),
                "l3_kb" => h.l3_kb = Some(u64_field(v, k)?),
                "l1_assoc" => h.l1_assoc = Some(u64_field(v, k)? as usize),
                "l2_assoc" => h.l2_assoc = Some(u64_field(v, k)? as usize),
                "l3_assoc" => h.l3_assoc = Some(u64_field(v, k)? as usize),
                "dram_latency" => h.dram_latency = Some(u64_field(v, k)?),
                other => bail!("unknown hierarchy key '{other}'"),
            }
        }
        Ok(h)
    }
}

/// Adaptive-controller configuration as spec fields: `None` = the
/// [`ControllerConfig`] default, except `seed`, which defaults to the
/// *run* seed at resolution time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdaptSpec {
    pub window_accesses: Option<u64>,
    pub ph_delta: Option<f64>,
    pub ph_lambda: Option<f64>,
    pub warmup_windows: Option<u64>,
    pub cooldown_windows: Option<u64>,
    pub unhealthy_windows_to_throttle: Option<u64>,
    pub recover_windows: Option<u64>,
    pub throttle_hit_ratio: Option<f64>,
    pub pollution_margin: Option<f64>,
    pub train_steps_on_drift: Option<usize>,
    pub replay_horizon: Option<u64>,
    pub seed: Option<u64>,
}

impl AdaptSpec {
    /// Spec view of a concrete controller config (every field explicit) —
    /// e.g. `AdaptSpec::from_config(&ControllerConfig::passive())`.
    pub fn from_config(c: &ControllerConfig) -> Self {
        Self {
            window_accesses: Some(c.window_accesses),
            ph_delta: Some(c.ph_delta),
            ph_lambda: Some(c.ph_lambda),
            warmup_windows: Some(c.warmup_windows),
            cooldown_windows: Some(c.cooldown_windows),
            unhealthy_windows_to_throttle: Some(c.unhealthy_windows_to_throttle),
            recover_windows: Some(c.recover_windows),
            throttle_hit_ratio: Some(c.throttle_hit_ratio),
            pollution_margin: Some(c.pollution_margin),
            train_steps_on_drift: Some(c.train_steps_on_drift),
            replay_horizon: Some(c.replay_horizon),
            seed: Some(c.seed),
        }
    }

    /// Concrete controller config; unset fields take defaults, the seed
    /// takes the run seed.
    pub fn resolve(&self, run_seed: u64) -> ControllerConfig {
        let d = ControllerConfig::default();
        ControllerConfig {
            window_accesses: self.window_accesses.unwrap_or(d.window_accesses),
            ph_delta: self.ph_delta.unwrap_or(d.ph_delta),
            ph_lambda: self.ph_lambda.unwrap_or(d.ph_lambda),
            warmup_windows: self.warmup_windows.unwrap_or(d.warmup_windows),
            cooldown_windows: self.cooldown_windows.unwrap_or(d.cooldown_windows),
            unhealthy_windows_to_throttle: self
                .unhealthy_windows_to_throttle
                .unwrap_or(d.unhealthy_windows_to_throttle),
            recover_windows: self.recover_windows.unwrap_or(d.recover_windows),
            throttle_hit_ratio: self.throttle_hit_ratio.unwrap_or(d.throttle_hit_ratio),
            pollution_margin: self.pollution_margin.unwrap_or(d.pollution_margin),
            train_steps_on_drift: self.train_steps_on_drift.unwrap_or(d.train_steps_on_drift),
            replay_horizon: self.replay_horizon.unwrap_or(d.replay_horizon),
            seed: self.seed.unwrap_or(run_seed),
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        if let Some(v) = self.window_accesses {
            j.set("window_accesses", Json::Num(v as f64));
        }
        if let Some(v) = self.ph_delta {
            j.set("ph_delta", f64_json(v));
        }
        if let Some(v) = self.ph_lambda {
            j.set("ph_lambda", f64_json(v));
        }
        if let Some(v) = self.warmup_windows {
            j.set("warmup_windows", Json::Num(v as f64));
        }
        if let Some(v) = self.cooldown_windows {
            j.set("cooldown_windows", Json::Num(v as f64));
        }
        if let Some(v) = self.unhealthy_windows_to_throttle {
            j.set("unhealthy_windows_to_throttle", Json::Num(v as f64));
        }
        if let Some(v) = self.recover_windows {
            j.set("recover_windows", Json::Num(v as f64));
        }
        if let Some(v) = self.throttle_hit_ratio {
            j.set("throttle_hit_ratio", f64_json(v));
        }
        if let Some(v) = self.pollution_margin {
            j.set("pollution_margin", f64_json(v));
        }
        if let Some(v) = self.train_steps_on_drift {
            j.set("train_steps_on_drift", Json::Num(v as f64));
        }
        if let Some(v) = self.replay_horizon {
            j.set("replay_horizon", Json::Num(v as f64));
        }
        if let Some(v) = self.seed {
            j.set("seed", Json::Str(v.to_string()));
        }
        j
    }

    fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("'adaptive' must be an object or bool"))?;
        let mut s = Self::default();
        for (k, v) in obj {
            match k.as_str() {
                "window_accesses" => s.window_accesses = Some(u64_field(v, k)?),
                "ph_delta" => s.ph_delta = Some(f64_field(v, k)?),
                "ph_lambda" => s.ph_lambda = Some(f64_field(v, k)?),
                "warmup_windows" => s.warmup_windows = Some(u64_field(v, k)?),
                "cooldown_windows" => s.cooldown_windows = Some(u64_field(v, k)?),
                "unhealthy_windows_to_throttle" => {
                    s.unhealthy_windows_to_throttle = Some(u64_field(v, k)?)
                }
                "recover_windows" => s.recover_windows = Some(u64_field(v, k)?),
                "throttle_hit_ratio" => s.throttle_hit_ratio = Some(f64_field(v, k)?),
                "pollution_margin" => s.pollution_margin = Some(f64_field(v, k)?),
                "train_steps_on_drift" => {
                    s.train_steps_on_drift = Some(u64_field(v, k)? as usize)
                }
                "replay_horizon" => s.replay_horizon = Some(u64_field(v, k)?),
                "seed" => s.seed = Some(u64_field(v, k)?),
                other => bail!("unknown adaptive key '{other}'"),
            }
        }
        Ok(s)
    }
}

/// Everything needed to reproduce one run — the public front door's input.
/// Build with [`RunSpec::builder`], load with [`RunSpec::from_file`] /
/// [`RunSpec::from_json`], execute with [`super::Runner`].
///
/// ```
/// use acpc::api::{Runner, RunSpec};
/// use acpc::config::PredictorKind;
///
/// let spec = RunSpec::builder()
///     .scenario("decode-heavy")
///     .policy("acpc")
///     .predictor(PredictorKind::Heuristic)
///     .accesses(50_000)
///     .seed(7)
///     .build()
///     .unwrap();
/// let report = Runner::new(spec).unwrap().run().unwrap();
/// assert_eq!(report.result.report.accesses, 50_000);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct RunSpec {
    /// Base preset the spec layers onto: `table1` (the paper's full-scale
    /// defaults) or `smoke` (tiny generator for tests).
    pub preset: String,
    /// Run name; `None` derives `{scenario}-{policy}` / `{preset}-{policy}`.
    pub name: Option<String>,
    /// L2 replacement policy under test.
    pub policy: String,
    pub predictor: PredictorKind,
    /// Artifact-model override for learned predictors (`tcn_flat`, ...).
    pub model: Option<String>,
    /// Inference engine for learned predictors: the native kernel
    /// (default) or the PJRT escape hatch. Resolution makes it explicit for
    /// learned predictors and rejects it otherwise.
    pub backend: Option<Backend>,
    /// Scenario-registry workload (mutually exclusive with `profile`).
    pub scenario: Option<String>,
    /// Model-profile workload (mutually exclusive with `scenario`).
    pub profile: Option<String>,
    pub workload: WorkloadSpec,
    pub hierarchy: HierarchySpec,
    pub accesses: Option<usize>,
    pub predict_batch: Option<usize>,
    /// Legacy §3.4 interval feedback (ignored when `adaptive` is set).
    pub feedback_interval: Option<usize>,
    /// Set-shard count (power of two; 1 = single-threaded).
    pub shards: usize,
    /// Attach an adaptive controller (`Some`), optionally overriding its
    /// thresholds.
    pub adaptive: Option<AdaptSpec>,
    /// Open-loop arrival process or capture replay (see [`TrafficSpec`]).
    pub traffic: Option<TrafficSpec>,
    pub seed: Option<u64>,
}

impl Default for RunSpec {
    fn default() -> Self {
        Self {
            preset: "table1".into(),
            name: None,
            policy: "acpc".into(),
            predictor: PredictorKind::Heuristic,
            model: None,
            backend: None,
            scenario: None,
            profile: None,
            workload: WorkloadSpec::default(),
            hierarchy: HierarchySpec::default(),
            accesses: None,
            predict_batch: None,
            feedback_interval: None,
            shards: 1,
            adaptive: None,
            traffic: None,
            seed: None,
        }
    }
}

/// How the run's workload is shaped by the spec's `traffic` block.
pub(crate) enum ResolvedTraffic {
    /// Replay this capture instead of generating.
    Replay(std::path::PathBuf),
    /// Wrap the configured workload in an open-loop arrival process.
    OpenLoop(crate::traffic::OpenLoopConfig),
}

/// A spec resolved against presets/registries: what the [`super::Runner`]
/// actually executes.
pub(crate) struct Resolved {
    pub cfg: ExperimentConfig,
    pub shards: usize,
    pub controller: Option<ControllerConfig>,
    pub traffic: Option<ResolvedTraffic>,
    pub model: Option<String>,
    /// Predict engine for learned predictors (`Backend::default()` = native
    /// unless the spec says otherwise; irrelevant for other predictors).
    pub backend: Backend,
    /// The input spec with every defaulted scalar made explicit — embedded
    /// in reports so they re-run bit-for-bit.
    pub spec: RunSpec,
}

impl RunSpec {
    pub fn builder() -> RunSpecBuilder {
        RunSpecBuilder { spec: RunSpec::default() }
    }

    /// Validate without running (resolution side effects discarded).
    pub fn validate(&self) -> Result<()> {
        self.resolve().map(|_| ())
    }

    /// Resolve against the presets and registries into the concrete
    /// experiment configuration (+ shards + controller), validating at the
    /// boundary.
    pub(crate) fn resolve(&self) -> Result<Resolved> {
        if crate::policy::make_policy(&self.policy, 2, 2, 0).is_none() {
            bail!("unknown policy '{}' (see `acpc policies`)", self.policy);
        }
        if self.scenario.is_some() && self.profile.is_some() {
            bail!("'scenario' and 'profile' are mutually exclusive");
        }
        let learned = matches!(self.predictor, PredictorKind::Dnn | PredictorKind::Tcn);
        if self.model.is_some() && !learned {
            bail!(
                "'model' overrides the artifact of a learned predictor — predictor '{}' \
                 does not load one",
                self.predictor.label()
            );
        }
        if self.backend.is_some() && !learned {
            bail!(
                "'backend' selects the inference engine of a learned predictor — predictor \
                 '{}' does not run one",
                self.predictor.label()
            );
        }
        let mut cfg = match self.preset.as_str() {
            "table1" => ExperimentConfig::table1(&self.policy, self.predictor),
            "smoke" => {
                let mut c = ExperimentConfig::smoke(&self.policy);
                c.predictor = self.predictor;
                c
            }
            other => bail!("unknown preset '{other}' (table1|smoke)"),
        };

        // Seed first: scenario/profile resolution stamps it into the
        // generator they build.
        if let Some(seed) = self.seed {
            cfg.seed = seed;
            cfg.generator.seed = seed;
        }
        if let Some(sc) = &self.scenario {
            cfg.set_scenario(sc)?;
        }
        if let Some(p) = &self.profile {
            let profile = ModelProfile::by_name(p)
                .ok_or_else(|| anyhow!("unknown model profile '{p}'"))?;
            cfg.generator = crate::trace::GeneratorConfig::new(profile, cfg.seed);
            cfg.scenario = None;
        }
        let w = &self.workload;
        if let Some(v) = w.max_live_sessions {
            cfg.generator.max_live_sessions = v;
        }
        if let Some(v) = w.phase_period {
            cfg.generator.phase_period = v;
        }
        if let Some(v) = w.max_ctx {
            cfg.generator.max_ctx = v;
        }
        if let Some(v) = w.arrival_p_hot {
            cfg.generator.arrival_p_hot = v;
        }
        if let Some(v) = w.arrival_p_cold {
            cfg.generator.arrival_p_cold = v;
        }

        self.hierarchy.apply(&mut cfg.hierarchy)?;

        if let Some(n) = self.accesses {
            if n == 0 {
                bail!("accesses must be > 0");
            }
            cfg.accesses = n;
        }
        if let Some(n) = self.predict_batch {
            cfg.predict_batch = n;
        }
        if let Some(n) = self.feedback_interval {
            cfg.feedback_interval = n;
        }
        cfg.name = self.name.clone().unwrap_or_else(|| match &self.scenario {
            Some(sc) => format!("{sc}-{}", self.policy),
            None => format!("{}-{}", self.preset, self.policy),
        });

        if self.shards == 0 {
            bail!("shards must be ≥ 1");
        }
        if self.shards > 1 {
            cfg.hierarchy
                .validate_shards(self.shards)
                .map_err(|e| anyhow!("shards: {e}"))?;
        }

        let controller = match &self.adaptive {
            Some(a) => {
                if self.predictor == PredictorKind::None {
                    bail!(
                        "an adaptive run needs a predictor (got 'none'): the controller \
                         has no predictions to throttle and no model to retrain"
                    );
                }
                Some(a.resolve(cfg.seed))
            }
            None => None,
        };

        // Traffic block: replay substitutes the workload wholesale; an
        // open-loop block wraps it, taking over all session admission.
        let mut traffic_spec = None;
        let traffic = match &self.traffic {
            Some(t) if t.replay.is_some() => {
                if t.has_open_loop_fields() {
                    bail!("'replay' is mutually exclusive with the other traffic knobs");
                }
                if self.scenario.is_some() || self.profile.is_some() {
                    bail!("'replay' substitutes the workload — drop 'scenario'/'profile'");
                }
                let path = std::path::PathBuf::from(t.replay.as_deref().expect("replay set"));
                let reader = crate::trace::file::TraceReader::open(&path)
                    .map_err(|e| anyhow!("traffic.replay: {e}"))?;
                if reader.count() == 0 {
                    bail!("traffic.replay: {} holds no records", path.display());
                }
                // Default to exactly one pass of the capture.
                if self.accesses.is_none() {
                    cfg.accesses = reader.count() as usize;
                }
                cfg.name = self.name.clone().unwrap_or_else(|| {
                    format!("replay-{}", self.policy)
                });
                traffic_spec = Some(t.clone());
                Some(ResolvedTraffic::Replay(path))
            }
            Some(t) => {
                if let Some(sc) =
                    self.scenario.as_deref().and_then(crate::trace::Scenario::by_name)
                {
                    if sc.is_traffic() {
                        bail!(
                            "scenario '{}' already models traffic — drop the 'traffic' block",
                            sc.name
                        );
                    }
                }
                let ol = t.resolve_open_loop(cfg.seed)?;
                // All admission flows through the bounded queue: disable the
                // generator's autonomous arrivals.
                cfg.generator.arrival_p_hot = 0.0;
                cfg.generator.arrival_p_cold = 0.0;
                traffic_spec = Some(TrafficSpec::from_open_loop(&ol));
                Some(ResolvedTraffic::OpenLoop(ol))
            }
            None => None,
        };

        // Make the backend explicit for learned predictors (the report
        // must say who ran predict); leave it unset otherwise so
        // non-learned spec JSON is byte-identical to before the field
        // existed (schema-compatible default).
        let backend = self.backend.unwrap_or_default();

        let mut spec = self.clone();
        spec.name = Some(cfg.name.clone());
        spec.seed = Some(cfg.seed);
        spec.accesses = Some(cfg.accesses);
        spec.predict_batch = Some(cfg.predict_batch);
        spec.feedback_interval = Some(cfg.feedback_interval);
        spec.adaptive = controller.as_ref().map(AdaptSpec::from_config);
        spec.traffic = traffic_spec;
        spec.backend = learned.then_some(backend);

        Ok(Resolved {
            cfg,
            shards: self.shards,
            controller,
            traffic,
            model: self.model.clone(),
            backend,
            spec,
        })
    }

    // ---- JSON ----------------------------------------------------------

    /// Serialize (schema-stamped). Unset optional fields are omitted; a
    /// resolved spec (as embedded in reports) has its scalars explicit.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", Json::Str(SCHEMA.into()));
        j.set("preset", Json::Str(self.preset.clone()));
        if let Some(n) = &self.name {
            j.set("name", Json::Str(n.clone()));
        }
        j.set("policy", Json::Str(self.policy.clone()));
        j.set("predictor", Json::Str(self.predictor.label().into()));
        if let Some(m) = &self.model {
            j.set("model", Json::Str(m.clone()));
        }
        if let Some(b) = self.backend {
            j.set("backend", Json::Str(b.label().into()));
        }
        if let Some(n) = self.accesses {
            j.set("accesses", Json::Num(n as f64));
        }
        if let Some(n) = self.predict_batch {
            j.set("predict_batch", Json::Num(n as f64));
        }
        if let Some(n) = self.feedback_interval {
            j.set("feedback_interval", Json::Num(n as f64));
        }
        // String, not Num: u64 seeds exceed f64's exact-integer range.
        if let Some(s) = self.seed {
            j.set("seed", Json::Str(s.to_string()));
        }
        j.set("shards", Json::Num(self.shards as f64));
        if let Some(a) = &self.adaptive {
            j.set("adaptive", a.to_json());
        }
        if let Some(t) = &self.traffic {
            j.set("traffic", t.to_json());
        }
        let mut workload = Json::obj();
        if let Some(sc) = &self.scenario {
            workload.set("scenario", Json::Str(sc.clone()));
        }
        if let Some(p) = &self.profile {
            workload.set("profile", Json::Str(p.clone()));
        }
        let w = &self.workload;
        if let Some(v) = w.max_live_sessions {
            workload.set("max_live_sessions", Json::Num(v as f64));
        }
        if let Some(v) = w.phase_period {
            workload.set("phase_period", Json::Num(v as f64));
        }
        if let Some(v) = w.max_ctx {
            workload.set("max_ctx", Json::Num(v as f64));
        }
        if let Some(v) = w.arrival_p_hot {
            workload.set("arrival_p_hot", f64_json(v));
        }
        if let Some(v) = w.arrival_p_cold {
            workload.set("arrival_p_cold", f64_json(v));
        }
        if workload != Json::obj() {
            j.set("workload", workload);
        }
        if !self.hierarchy.is_empty() {
            j.set("hierarchy", self.hierarchy.to_json());
        }
        j
    }

    /// Parse a spec. Unknown keys are errors (typo protection). The legacy
    /// `acpc simulate --config` JSON format uses the same keys, so old
    /// config files parse — but note the *defaults for omitted keys*
    /// changed: a file that names no `policy`/`predictor` now runs
    /// `acpc`+`heuristic` (the spec default), where the pre-API loader
    /// defaulted to `lru` with no predictor.
    pub fn from_json(j: &Json) -> Result<RunSpec> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("run spec root must be an object"))?;
        let mut spec = RunSpec::default();
        for (k, v) in obj {
            match k.as_str() {
                "schema" => {
                    let s = v.as_str().ok_or_else(|| anyhow!("schema must be a string"))?;
                    if s != SCHEMA {
                        bail!("unsupported spec schema '{s}' (expected '{SCHEMA}')");
                    }
                }
                "preset" => {
                    spec.preset =
                        v.as_str().ok_or_else(|| anyhow!("preset"))?.to_string()
                }
                "name" => spec.name = Some(str_field(v, k)?),
                "policy" => spec.policy = str_field(v, k)?,
                "predictor" => {
                    spec.predictor =
                        PredictorKind::parse(v.as_str().ok_or_else(|| anyhow!("predictor"))?)?
                }
                "model" => spec.model = Some(str_field(v, k)?),
                "backend" => {
                    spec.backend =
                        Some(Backend::parse(v.as_str().ok_or_else(|| anyhow!("backend"))?)?)
                }
                "accesses" => spec.accesses = Some(u64_field(v, k)? as usize),
                "predict_batch" => spec.predict_batch = Some(u64_field(v, k)? as usize),
                "feedback_interval" => {
                    spec.feedback_interval = Some(u64_field(v, k)? as usize)
                }
                "seed" => spec.seed = Some(u64_field(v, k)?),
                "shards" => spec.shards = u64_field(v, k)? as usize,
                "adaptive" => {
                    spec.adaptive = match v {
                        Json::Bool(true) => Some(AdaptSpec::default()),
                        Json::Bool(false) => None,
                        other => Some(AdaptSpec::from_json(other)?),
                    }
                }
                "traffic" => spec.traffic = Some(TrafficSpec::from_json(v)?),
                "workload" => parse_workload(&mut spec, v)?,
                "hierarchy" => spec.hierarchy = HierarchySpec::from_json(v)?,
                other => bail!("unknown run-spec key '{other}'"),
            }
        }
        Ok(spec)
    }

    /// Load a spec from a JSON file.
    pub fn from_file(path: &std::path::Path) -> Result<RunSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&j).map_err(|e| anyhow!("{}: {e}", path.display()))
    }
}

fn parse_workload(spec: &mut RunSpec, j: &Json) -> Result<()> {
    let obj = j.as_obj().ok_or_else(|| anyhow!("'workload' must be an object"))?;
    for (k, v) in obj {
        match k.as_str() {
            "scenario" => spec.scenario = Some(str_field(v, k)?),
            "profile" => spec.profile = Some(str_field(v, k)?),
            "max_live_sessions" => {
                spec.workload.max_live_sessions = Some(u64_field(v, k)? as usize)
            }
            "phase_period" => spec.workload.phase_period = Some(u64_field(v, k)?),
            "max_ctx" => spec.workload.max_ctx = Some(u64_field(v, k)? as u32),
            "arrival_p_hot" => spec.workload.arrival_p_hot = Some(f64_field(v, k)?),
            "arrival_p_cold" => spec.workload.arrival_p_cold = Some(f64_field(v, k)?),
            other => bail!("unknown workload key '{other}'"),
        }
    }
    Ok(())
}

// ---- field helpers (shared with the serve spec) ------------------------

pub(crate) fn str_field(v: &Json, what: &str) -> Result<String> {
    v.as_str().map(|s| s.to_string()).ok_or_else(|| anyhow!("'{what}' must be a string"))
}

/// u64 from a JSON number *or* decimal string (u64 seeds exceed f64's 2^53
/// exact range, so seeds round-trip as strings). Fractional values and
/// numbers past f64's exact-integer range are rejected, not truncated —
/// a spec must mean exactly what it says.
pub(crate) fn u64_field(v: &Json, what: &str) -> Result<u64> {
    const F64_EXACT_MAX: f64 = 9_007_199_254_740_992.0; // 2^53
    match v {
        Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= F64_EXACT_MAX => Ok(*x as u64),
        Json::Num(x) => bail!(
            "'{what}' must be a non-negative integer exactly representable in JSON \
             (got {x}; write values beyond 2^53 as strings)"
        ),
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|_| anyhow!("'{what}' must be a non-negative integer, got '{s}'")),
        _ => bail!("'{what}' must be a non-negative integer"),
    }
}

pub(crate) fn f64_field(v: &Json, what: &str) -> Result<f64> {
    match v {
        Json::Num(x) => Ok(*x),
        // JSON has no Infinity token; passive-controller thresholds
        // round-trip as the strings "inf"/"-inf".
        Json::Str(s) if s == "inf" => Ok(f64::INFINITY),
        Json::Str(s) if s == "-inf" => Ok(f64::NEG_INFINITY),
        _ => bail!("'{what}' must be a number"),
    }
}

pub(crate) fn f64_json(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

// ---- builder -----------------------------------------------------------

/// Fluent construction of a [`RunSpec`]; [`build`](Self::build) validates
/// by resolving against the presets/registries.
#[derive(Debug, Clone)]
pub struct RunSpecBuilder {
    spec: RunSpec,
}

impl RunSpecBuilder {
    pub fn preset(mut self, preset: &str) -> Self {
        self.spec.preset = preset.to_string();
        self
    }

    pub fn name(mut self, name: &str) -> Self {
        self.spec.name = Some(name.to_string());
        self
    }

    pub fn policy(mut self, policy: &str) -> Self {
        self.spec.policy = policy.to_string();
        self
    }

    pub fn predictor(mut self, kind: PredictorKind) -> Self {
        self.spec.predictor = kind;
        self
    }

    pub fn model(mut self, model: &str) -> Self {
        self.spec.model = Some(model.to_string());
        self
    }

    /// Predict engine for learned predictors (`Backend::Native` is the
    /// default without this call).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.spec.backend = Some(backend);
        self
    }

    pub fn scenario(mut self, scenario: &str) -> Self {
        self.spec.scenario = Some(scenario.to_string());
        self
    }

    pub fn profile(mut self, profile: &str) -> Self {
        self.spec.profile = Some(profile.to_string());
        self
    }

    pub fn accesses(mut self, n: usize) -> Self {
        self.spec.accesses = Some(n);
        self
    }

    pub fn predict_batch(mut self, n: usize) -> Self {
        self.spec.predict_batch = Some(n);
        self
    }

    pub fn feedback_interval(mut self, n: usize) -> Self {
        self.spec.feedback_interval = Some(n);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seed = Some(seed);
        self
    }

    pub fn shards(mut self, shards: usize) -> Self {
        self.spec.shards = shards;
        self
    }

    /// Attach (or detach) an adaptive controller with default thresholds.
    pub fn adaptive(mut self, on: bool) -> Self {
        self.spec.adaptive = if on { Some(AdaptSpec::default()) } else { None };
        self
    }

    /// Attach an adaptive controller with an explicit configuration.
    pub fn controller(mut self, cfg: ControllerConfig) -> Self {
        self.spec.adaptive = Some(AdaptSpec::from_config(&cfg));
        self
    }

    /// Attach an adaptive controller from partial spec fields.
    pub fn adaptive_spec(mut self, a: AdaptSpec) -> Self {
        self.spec.adaptive = Some(a);
        self
    }

    /// Attach an open-loop / replay traffic block from partial spec fields.
    pub fn traffic(mut self, t: TrafficSpec) -> Self {
        self.spec.traffic = Some(t);
        self
    }

    /// Replay a captured `.acpctrace` instead of generating a workload.
    /// Validation opens the file, so it must exist when `build` runs.
    pub fn replay(mut self, path: &str) -> Self {
        self.spec.traffic =
            Some(TrafficSpec { replay: Some(path.to_string()), ..TrafficSpec::default() });
        self
    }

    pub fn hierarchy_preset(mut self, preset: &str) -> Self {
        self.spec.hierarchy.preset = Some(preset.to_string());
        self
    }

    pub fn prefetcher(mut self, prefetcher: &str) -> Self {
        self.spec.hierarchy.prefetcher = Some(prefetcher.to_string());
        self
    }

    pub fn l3_policy(mut self, policy: &str) -> Self {
        self.spec.hierarchy.l3_policy = Some(policy.to_string());
        self
    }

    pub fn l2_kb(mut self, kb: u64) -> Self {
        self.spec.hierarchy.l2_kb = Some(kb);
        self
    }

    pub fn max_live_sessions(mut self, n: usize) -> Self {
        self.spec.workload.max_live_sessions = Some(n);
        self
    }

    pub fn phase_period(mut self, period: u64) -> Self {
        self.spec.workload.phase_period = Some(period);
        self
    }

    pub fn max_ctx(mut self, ctx: u32) -> Self {
        self.spec.workload.max_ctx = Some(ctx);
        self
    }

    /// Validate (full resolution against presets/registries) and return
    /// the spec.
    pub fn build(self) -> Result<RunSpec> {
        self.spec.validate()?;
        Ok(self.spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_and_roundtrips() {
        let spec = RunSpec::builder()
            .scenario("decode-heavy")
            .policy("acpc")
            .predictor(PredictorKind::Heuristic)
            .accesses(10_000)
            .seed(0xFFFF_FFFF_FFFF_FFF1) // > 2^53: must survive JSON
            .shards(2)
            .adaptive(true)
            .prefetcher("stride")
            .max_ctx(256)
            .build()
            .unwrap();
        let back = RunSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        assert_eq!(back.seed, Some(0xFFFF_FFFF_FFFF_FFF1));
    }

    #[test]
    fn builder_rejects_invalid_specs() {
        assert!(RunSpec::builder().policy("nope").build().is_err());
        assert!(RunSpec::builder().scenario("no-such-scenario").build().is_err());
        assert!(RunSpec::builder()
            .scenario("decode-heavy")
            .profile("gpt3ish")
            .build()
            .is_err(), "scenario+profile is ambiguous");
        assert!(RunSpec::builder().shards(3).build().is_err(), "non-power-of-two shards");
        assert!(RunSpec::builder().shards(0).build().is_err());
        assert!(RunSpec::builder().accesses(0).build().is_err());
        assert!(RunSpec::builder()
            .predictor(PredictorKind::None)
            .adaptive(true)
            .build()
            .is_err(), "adaptive needs a predictor");
        assert!(RunSpec::builder().hierarchy_preset("nope").build().is_err());
        assert!(RunSpec::builder().prefetcher("warp-drive").build().is_err());
        assert!(RunSpec::builder().l3_policy("nope").build().is_err());
        assert!(RunSpec::builder().model("tcn_flat").build().is_err(),
            "model override without a learned predictor");
        assert!(RunSpec::builder().backend(Backend::Pjrt).build().is_err(),
            "backend selection without a learned predictor");
        // 96 KiB / 8-way / 64 B lines → 192 sets: not a power of two.
        assert!(RunSpec::builder().l2_kb(96).build().is_err());
    }

    #[test]
    fn backend_roundtrips_and_resolves_explicitly() {
        // Explicit pjrt escape hatch survives JSON.
        let spec = RunSpec::builder()
            .scenario("decode-heavy")
            .predictor(PredictorKind::Tcn)
            .backend(Backend::Pjrt)
            .build()
            .unwrap();
        let back = RunSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        assert_eq!(back.backend, Some(Backend::Pjrt));
        assert_eq!(back.resolve().unwrap().backend, Backend::Pjrt);

        // Learned predictor without a backend: resolution defaults to
        // native and makes it explicit in the resolved spec.
        let spec =
            RunSpec::builder().scenario("decode-heavy").predictor(PredictorKind::Tcn).build().unwrap();
        assert_eq!(spec.backend, None);
        let r = spec.resolve().unwrap();
        assert_eq!(r.backend, Backend::Native);
        assert_eq!(r.spec.backend, Some(Backend::Native));

        // Non-learned predictors: no backend key, before or after
        // resolution — old spec/report JSON is byte-identical.
        let spec = RunSpec::builder()
            .scenario("decode-heavy")
            .predictor(PredictorKind::Heuristic)
            .build()
            .unwrap();
        let r = spec.resolve().unwrap();
        assert_eq!(r.spec.backend, None);
        assert!(!r.spec.to_json().to_string().contains("backend"));

        // Unknown backend values are rejected.
        let j = Json::parse(r#"{"predictor": "tcn", "backend": "warp"}"#).unwrap();
        assert!(RunSpec::from_json(&j).is_err());
    }

    #[test]
    fn unknown_keys_rejected() {
        for text in [
            r#"{"polcy": "lru"}"#,
            r#"{"workload": {"scneario": "decode-heavy"}}"#,
            r#"{"hierarchy": {"l9_kb": 1}}"#,
            r#"{"adaptive": {"window": 1}}"#,
            r#"{"schema": "acpc-run-v0"}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(RunSpec::from_json(&j).is_err(), "{text}");
        }
    }

    #[test]
    fn imprecise_numbers_rejected_not_truncated() {
        // Fractional counts and numeric seeds past 2^53 silently losing
        // precision would make a spec mean something other than it says.
        for text in [
            r#"{"accesses": 2.5}"#,
            r#"{"seed": 18446744073709551615}"#,
            r#"{"shards": -1}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(RunSpec::from_json(&j).is_err(), "{text}");
        }
        // The same seed as a string is exact and accepted.
        let j = Json::parse(r#"{"seed": "18446744073709551615"}"#).unwrap();
        assert_eq!(RunSpec::from_json(&j).unwrap().seed, Some(u64::MAX));
    }

    #[test]
    fn resolution_derives_names_and_seeds() {
        let spec = RunSpec::builder()
            .scenario("rag-embedding")
            .policy("lru")
            .predictor(PredictorKind::None)
            .seed(9)
            .build()
            .unwrap();
        let r = spec.resolve().unwrap();
        assert_eq!(r.cfg.name, "rag-embedding-lru");
        assert_eq!(r.cfg.seed, 9);
        assert_eq!(r.cfg.generator.seed, 9);
        assert_eq!(r.cfg.scenario.as_deref(), Some("rag-embedding"));
        // The resolved copy makes the derived scalars explicit.
        assert_eq!(r.spec.name.as_deref(), Some("rag-embedding-lru"));
        assert_eq!(r.spec.accesses, Some(r.cfg.accesses));
        assert_eq!(r.spec.seed, Some(9));

        let plain = RunSpec::builder().policy("lru").predictor(PredictorKind::None).build().unwrap();
        assert_eq!(plain.resolve().unwrap().cfg.name, "table1-lru");
        let smoke = RunSpec::builder()
            .preset("smoke")
            .policy("lru")
            .predictor(PredictorKind::None)
            .build()
            .unwrap();
        let rs = smoke.resolve().unwrap();
        assert_eq!(rs.cfg.name, "smoke-lru");
        assert_eq!(rs.cfg.accesses, 50_000);
    }

    #[test]
    fn resolved_spec_reresolves_identically() {
        let spec = RunSpec::builder()
            .scenario("multi-tenant-mix")
            .policy("acpc")
            .predictor(PredictorKind::Heuristic)
            .accesses(30_000)
            .shards(2)
            .adaptive(true)
            .build()
            .unwrap();
        let r1 = spec.resolve().unwrap();
        // Round-trip the resolved copy through JSON and re-resolve.
        let back = RunSpec::from_json(&r1.spec.to_json()).unwrap();
        let r2 = back.resolve().unwrap();
        assert_eq!(format!("{:?}", r1.cfg), format!("{:?}", r2.cfg));
        assert_eq!(format!("{:?}", r1.controller), format!("{:?}", r2.controller));
        assert_eq!(r1.shards, r2.shards);
    }

    #[test]
    fn passive_controller_thresholds_survive_json() {
        let spec = RunSpec::builder()
            .scenario("decode-heavy")
            .controller(ControllerConfig::passive())
            .build()
            .unwrap();
        let back = RunSpec::from_json(&spec.to_json()).unwrap();
        let cc = back.adaptive.as_ref().unwrap().resolve(1);
        assert!(cc.ph_lambda.is_infinite());
        assert!(cc.pollution_margin.is_infinite());
        assert_eq!(cc.throttle_hit_ratio, 0.0);
    }

    #[test]
    fn traffic_block_roundtrips_and_validates() {
        let spec = RunSpec::builder()
            .scenario("decode-heavy")
            .policy("lru")
            .predictor(PredictorKind::None)
            .traffic(TrafficSpec {
                arrivals: Some("bursty".into()),
                rate: Some(6.0),
                queue_depth: Some(16),
                ..TrafficSpec::default()
            })
            .build()
            .unwrap();
        let back = RunSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        // Resolution zeroes autonomous arrivals and makes the block
        // explicit in the resolved copy.
        let r = spec.resolve().unwrap();
        assert_eq!(r.cfg.generator.arrival_p_hot, 0.0);
        assert_eq!(r.cfg.generator.arrival_p_cold, 0.0);
        let t = r.spec.traffic.as_ref().unwrap();
        assert_eq!(t.arrivals.as_deref(), Some("bursty"));
        assert_eq!(t.rate, Some(6.0));
        assert_eq!(t.period, Some(20_000), "defaults made explicit");
        assert!(matches!(r.traffic, Some(ResolvedTraffic::OpenLoop(_))));

        // Invalid knobs and unknown keys are rejected.
        assert!(RunSpec::builder()
            .traffic(TrafficSpec { arrivals: Some("tsunami".into()), ..TrafficSpec::default() })
            .build()
            .is_err());
        assert!(RunSpec::builder()
            .traffic(TrafficSpec { rate: Some(-1.0), ..TrafficSpec::default() })
            .build()
            .is_err());
        let j = Json::parse(r#"{"traffic": {"rat": 4}}"#).unwrap();
        assert!(RunSpec::from_json(&j).is_err());
        // Traffic scenarios already model traffic.
        assert!(RunSpec::builder()
            .scenario("bursty-batch")
            .traffic(TrafficSpec { rate: Some(4.0), ..TrafficSpec::default() })
            .build()
            .is_err());
        // replay excludes other traffic knobs and scenario/profile.
        assert!(RunSpec::builder()
            .traffic(TrafficSpec {
                replay: Some("/tmp/x.acpctrace".into()),
                rate: Some(4.0),
                ..TrafficSpec::default()
            })
            .build()
            .is_err());
        assert!(RunSpec::builder()
            .scenario("decode-heavy")
            .replay("/tmp/x.acpctrace")
            .build()
            .is_err());
        // replay of a missing file fails at resolution.
        assert!(RunSpec::builder().replay("/definitely/not/here.acpctrace").build().is_err());
    }

    #[test]
    fn replay_spec_resolves_against_a_real_capture() {
        let trace = crate::trace::TraceGenerator::new(crate::trace::GeneratorConfig::tiny(6))
            .generate(800);
        let dir = std::env::temp_dir().join("acpc_spec_replay_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spec.acpctrace");
        crate::trace::file::write_trace(&path, &trace).unwrap();
        let spec = RunSpec::builder()
            .policy("lru")
            .predictor(PredictorKind::None)
            .replay(path.to_str().unwrap())
            .build()
            .unwrap();
        let r = spec.resolve().unwrap();
        assert_eq!(r.cfg.accesses, 800, "accesses default to one pass");
        assert_eq!(r.cfg.name, "replay-lru");
        assert!(matches!(r.traffic, Some(ResolvedTraffic::Replay(_))));
        let back = RunSpec::from_json(&r.spec.to_json()).unwrap();
        assert_eq!(back.resolve().unwrap().cfg.accesses, 800);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn legacy_config_format_parses() {
        // The pre-API `acpc simulate --config` format is a subset.
        let j = Json::parse(
            r#"{"preset": "smoke", "policy": "srrip", "accesses": 30000,
                "hierarchy": {"prefetcher": "stride"},
                "workload": {"profile": "t5", "max_ctx": 128}}"#,
        )
        .unwrap();
        let spec = RunSpec::from_json(&j).unwrap();
        let r = spec.resolve().unwrap();
        assert_eq!(r.cfg.policy, "srrip");
        assert_eq!(r.cfg.accesses, 30_000);
        assert_eq!(r.cfg.generator.profile.name, "t5ish");
        assert_eq!(r.cfg.generator.max_ctx, 128);
        assert_eq!(r.cfg.hierarchy.prefetcher, "stride");
    }
}
