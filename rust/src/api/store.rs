//! Content-addressed report store: spec-hash → cached [`RunReport`].
//!
//! PR 4 made `RunSpec` → `RunReport` bit-for-bit reproducible, which means
//! a report is fully determined by its *resolved* spec — so re-simulating a
//! cell whose spec we have already run is pure waste. This module turns
//! that determinism into a cache: [`spec_hash`] derives a stable SHA-256
//! key from the canonical JSON of the resolved spec, and [`ReportStore`]
//! maps that key to the serialized report on disk. [`crate::api::Runner`]
//! consults the store behind a [`CacheMode`]; the sweep and the manifest
//! farm route every cell through it, so a warm second run does **zero**
//! simulation.
//!
//! ## Key derivation (cache invalidation rules)
//!
//! The hashed material is, line by line:
//!
//! 1. the hash-schema tag (`acpc-spec-hash-v1`) — bumping it invalidates
//!    every existing entry at once;
//! 2. the crate version — a new release never trusts an old store;
//! 3. the compact canonical JSON of the **resolved** spec. Resolution makes
//!    every defaulted scalar explicit, so a spec that omits `predict_batch`
//!    and one that spells out the default hash identically; the JSON
//!    object is a `BTreeMap`, so key order never varies;
//! 4. for learned predictors (`tcn`/`dnn` or a `model` override): a
//!    fingerprint of the AOT artifact manifest (`artifacts:<sha256>`, or
//!    `artifacts:absent`). Retraining a model rewrites the manifest and
//!    therefore misses; so does installing artifacts where there were none
//!    (the fallback-to-heuristic run stops being representative).
//!
//! What the key deliberately does **not** cover: engine code changes within
//! one crate version. A development workflow that edits the simulator must
//! clear the store (`rm -rf .acpc-store`) or run with `CacheMode::Off`;
//! CI sidesteps the issue by keying its cached store on the source tree.
//!
//! ## On-disk layout
//!
//! ```text
//! <root>/                   # $ACPC_STORE, default ./.acpc-store
//!   ab/                     # first two hex digits of the key
//!     ab3f…e2.json          # full 64-hex-digit key, pretty-printed report
//! ```
//!
//! Entries are written atomically (temp file + rename), so a crashed run
//! never leaves a half-written entry under its final name. Reads are
//! paranoid: a corrupt, truncated, schema-mismatched, or wrongly-addressed
//! entry is a **miss, never an error** — the runner falls back to
//! simulation and overwrites the bad entry on the way out.

use super::runner::RunReport;
use super::spec::RunSpec;
use crate::util::hash::sha256_hex;
use crate::util::json::Json;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Version tag mixed into every key; bump to invalidate all entries.
const HASH_SCHEMA: &str = "acpc-spec-hash-v1";

/// How a [`crate::api::Runner`] uses its attached [`ReportStore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    /// Ignore the store entirely: always simulate, never read or write.
    Off,
    /// Serve hits from the store but never write new entries (useful
    /// against a read-only shared store).
    Read,
    /// Serve hits and persist every fresh result — the farm default.
    ReadWrite,
}

impl CacheMode {
    /// Parse a CLI-facing label: `off`, `read`, `read-write` (or `rw`).
    pub fn parse(s: &str) -> Result<CacheMode> {
        match s {
            "off" => Ok(CacheMode::Off),
            "read" => Ok(CacheMode::Read),
            "read-write" | "rw" => Ok(CacheMode::ReadWrite),
            other => anyhow::bail!(
                "unknown cache mode '{other}' (expected off, read, or read-write)"
            ),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            CacheMode::Off => "off",
            CacheMode::Read => "read",
            CacheMode::ReadWrite => "read-write",
        }
    }

    /// May cached entries satisfy a run?
    pub fn reads(self) -> bool {
        !matches!(self, CacheMode::Off)
    }

    /// Are fresh results persisted?
    pub fn writes(self) -> bool {
        matches!(self, CacheMode::ReadWrite)
    }
}

/// Stable content address of a spec: resolves it (validating on the way),
/// then hashes the canonical resolved JSON per the module-level rules.
/// Two specs that resolve identically — regardless of field order, or of
/// spelling out vs omitting defaults — share a hash.
pub fn spec_hash(spec: &RunSpec) -> Result<String> {
    Ok(resolved_spec_hash(&spec.resolve()?.spec))
}

/// Hash of an already-resolved spec (the runner calls this to avoid a
/// second resolution; `get` calls it to verify an entry's address).
pub(crate) fn resolved_spec_hash(resolved: &RunSpec) -> String {
    let mut material = format!(
        "{HASH_SCHEMA}\n{}\n{}\n",
        env!("CARGO_PKG_VERSION"),
        resolved.to_json().to_string()
    );
    use crate::config::PredictorKind;
    if matches!(resolved.predictor, PredictorKind::Tcn | PredictorKind::Dnn)
        || resolved.model.is_some()
    {
        material.push_str(&artifact_fingerprint());
        material.push('\n');
    }
    sha256_hex(material.as_bytes())
}

/// Content digest of the AOT artifact manifest, or `artifacts:absent` when
/// no artifacts directory is configured/readable. Learned-predictor specs
/// mix this into their key so retrained weights (or newly installed
/// artifacts) invalidate cached runs.
fn artifact_fingerprint() -> String {
    let manifest = crate::runtime::artifacts_dir().map(|d| d.join("manifest.json"));
    match manifest.and_then(|p| std::fs::read(p).ok()) {
        Some(bytes) => format!("artifacts:{}", sha256_hex(&bytes)),
        None => "artifacts:absent".to_string(),
    }
}

/// One on-disk report as surfaced by [`ReportStore::entries`] (`acpc store
/// ls` / `gc`): identity, location, size, age, and the schema + spec name
/// read from the entry (`-` when unreadable).
#[derive(Debug, Clone)]
pub struct StoreEntry {
    pub hash: String,
    pub path: PathBuf,
    pub bytes: u64,
    pub age_days: f64,
    pub schema: String,
    pub label: String,
}

/// A directory of content-addressed [`RunReport`]s (see the module docs
/// for layout and invalidation semantics). Cloning is cheap — the store is
/// just a root path; all state lives on disk.
#[derive(Debug, Clone)]
pub struct ReportStore {
    root: PathBuf,
}

impl ReportStore {
    /// Open (lazily — nothing is created until the first write) a store
    /// rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> ReportStore {
        ReportStore { root: root.into() }
    }

    /// The default root: `$ACPC_STORE` when set, else `.acpc-store` under
    /// the current directory.
    pub fn default_root() -> PathBuf {
        match std::env::var_os("ACPC_STORE") {
            Some(p) if !p.is_empty() => PathBuf::from(p),
            _ => PathBuf::from(".acpc-store"),
        }
    }

    /// [`ReportStore::open`] at [`ReportStore::default_root`].
    pub fn open_default() -> ReportStore {
        Self::open(Self::default_root())
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Where an entry for `hash` lives: `<root>/<hash[..2]>/<hash>.json`.
    pub fn entry_path(&self, hash: &str) -> PathBuf {
        let shard = hash.get(..2).unwrap_or("__");
        self.root.join(shard).join(format!("{hash}.json"))
    }

    /// Fetch and validate the entry for `hash`. Any defect — unreadable
    /// file, truncated/corrupt JSON, wrong report schema, or an embedded
    /// spec that no longer hashes to `hash` (tampering, or artifacts that
    /// changed since the entry was written) — is a miss (`None`), never an
    /// error.
    pub fn get(&self, hash: &str) -> Option<RunReport> {
        let text = std::fs::read_to_string(self.entry_path(hash)).ok()?;
        let j = Json::parse(&text).ok()?;
        let report = RunReport::from_json(&j).ok()?;
        if resolved_spec_hash(&report.spec) != hash {
            return None;
        }
        Some(report)
    }

    /// Persist `report` under `hash`, atomically (temp file + rename).
    pub fn put(&self, hash: &str, report: &RunReport) -> std::io::Result<PathBuf> {
        let path = self.entry_path(hash);
        let dir = path.parent().expect("entry path has a parent");
        std::fs::create_dir_all(dir)?;
        let tmp = dir.join(format!(".{hash}.tmp{}", std::process::id()));
        std::fs::write(&tmp, report.to_json().to_pretty())?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    /// All entry hashes currently in the store (sorted).
    pub fn hashes(&self) -> Vec<String> {
        let mut out = Vec::new();
        let Ok(shards) = std::fs::read_dir(&self.root) else { return out };
        for shard in shards.flatten() {
            let Ok(files) = std::fs::read_dir(shard.path()) else { continue };
            for f in files.flatten() {
                let name = f.file_name();
                let name = name.to_string_lossy();
                if let Some(stem) = name.strip_suffix(".json") {
                    if stem.len() == 64 && stem.bytes().all(|b| b.is_ascii_hexdigit()) {
                        out.push(stem.to_string());
                    }
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Number of entries on disk.
    pub fn len(&self) -> usize {
        self.hashes().len()
    }

    pub fn is_empty(&self) -> bool {
        self.hashes().is_empty()
    }

    /// Everything on disk, one [`StoreEntry`] per report, sorted by hash
    /// (`acpc store ls`). Unreadable or corrupt entries still appear —
    /// with `-` placeholders — so `gc` can reclaim them.
    pub fn entries(&self) -> Vec<StoreEntry> {
        let now = std::time::SystemTime::now();
        self.hashes()
            .into_iter()
            .map(|hash| {
                let path = self.entry_path(&hash);
                let meta = std::fs::metadata(&path).ok();
                let bytes = meta.as_ref().map(|m| m.len()).unwrap_or(0);
                let age_days = meta
                    .and_then(|m| m.modified().ok())
                    .and_then(|t| now.duration_since(t).ok())
                    .map(|d| d.as_secs_f64() / 86_400.0)
                    .unwrap_or(0.0);
                let parsed = std::fs::read_to_string(&path)
                    .ok()
                    .and_then(|text| Json::parse(&text).ok());
                let field = |keys: &[&str]| -> String {
                    let mut j = parsed.as_ref();
                    for k in keys {
                        j = j.and_then(|j| j.get(k));
                    }
                    j.and_then(Json::as_str).unwrap_or("-").to_string()
                };
                let schema = field(&["schema"]);
                let label = field(&["spec", "name"]);
                StoreEntry { hash, path, bytes, age_days, schema, label }
            })
            .collect()
    }

    /// Entries last written more than `keep_days` ago. With `apply` false
    /// (the `acpc store gc` default) this is a dry run: nothing is deleted,
    /// the doomed entries are only returned. With `apply` true they are
    /// removed (and emptied shard directories pruned).
    pub fn gc(&self, keep_days: f64, apply: bool) -> std::io::Result<Vec<StoreEntry>> {
        let doomed: Vec<StoreEntry> =
            self.entries().into_iter().filter(|e| e.age_days > keep_days).collect();
        if apply {
            for e in &doomed {
                std::fs::remove_file(&e.path)?;
                if let Some(dir) = e.path.parent() {
                    // Succeeds only once the shard directory is empty.
                    let _ = std::fs::remove_dir(dir);
                }
            }
        }
        Ok(doomed)
    }

    /// Resolve a (possibly abbreviated) hex hash to the unique stored
    /// entry it prefixes. `None` when nothing — or more than one entry —
    /// matches (`acpc diff` uses this for git-style short hashes).
    pub fn find(&self, prefix: &str) -> Option<String> {
        let mut matches = self.hashes().into_iter().filter(|h| h.starts_with(prefix));
        let first = matches.next()?;
        if matches.next().is_some() {
            return None;
        }
        Some(first)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Runner;
    use crate::config::PredictorKind;

    fn tmp_store(name: &str) -> ReportStore {
        let dir = std::env::temp_dir().join("acpc_store_unit").join(name);
        std::fs::remove_dir_all(&dir).ok();
        ReportStore::open(dir)
    }

    fn tiny_spec(seed: u64) -> RunSpec {
        RunSpec::builder()
            .preset("smoke")
            .policy("lru")
            .predictor(PredictorKind::None)
            .accesses(5_000)
            .seed(seed)
            .build()
            .unwrap()
    }

    #[test]
    fn cache_mode_parses_and_labels() {
        assert_eq!(CacheMode::parse("off").unwrap(), CacheMode::Off);
        assert_eq!(CacheMode::parse("read").unwrap(), CacheMode::Read);
        assert_eq!(CacheMode::parse("read-write").unwrap(), CacheMode::ReadWrite);
        assert_eq!(CacheMode::parse("rw").unwrap(), CacheMode::ReadWrite);
        assert!(CacheMode::parse("sometimes").is_err());
        assert!(!CacheMode::Off.reads() && !CacheMode::Off.writes());
        assert!(CacheMode::Read.reads() && !CacheMode::Read.writes());
        assert!(CacheMode::ReadWrite.reads() && CacheMode::ReadWrite.writes());
        for m in [CacheMode::Off, CacheMode::Read, CacheMode::ReadWrite] {
            assert_eq!(CacheMode::parse(m.label()).unwrap(), m);
        }
    }

    /// Key-order independence and omitted-vs-explicit defaults: all three
    /// spellings resolve identically and therefore share one hash.
    #[test]
    fn spec_hash_is_stable_across_field_order_and_defaults() {
        let a = RunSpec::from_json(
            &Json::parse(r#"{"policy": "lru", "predictor": "none", "accesses": 5000, "seed": "7"}"#)
                .unwrap(),
        )
        .unwrap();
        let b = RunSpec::from_json(
            &Json::parse(r#"{"seed": "7", "accesses": 5000, "predictor": "none", "policy": "lru"}"#)
                .unwrap(),
        )
        .unwrap();
        let c = RunSpec::from_json(
            &Json::parse(
                r#"{"policy": "lru", "predictor": "none", "accesses": 5000, "seed": "7",
                    "shards": 1}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let ha = spec_hash(&a).unwrap();
        assert_eq!(ha.len(), 64);
        assert_eq!(ha, spec_hash(&b).unwrap());
        assert_eq!(ha, spec_hash(&c).unwrap(), "explicit default shards must not change the key");
        // And a genuinely different spec gets a different key.
        let mut d = a.clone();
        d.seed = Some(8);
        assert_ne!(ha, spec_hash(&d).unwrap());
    }

    #[test]
    fn put_get_roundtrip_and_addressing() {
        let store = tmp_store("roundtrip");
        let runner = Runner::new(tiny_spec(3)).unwrap();
        let report = runner.run().unwrap();
        let hash = runner.spec_hash();
        assert!(store.get(&hash).is_none(), "empty store must miss");
        let path = store.put(&hash, &report).unwrap();
        assert!(path.starts_with(store.root()));
        assert_eq!(store.len(), 1);
        let back = store.get(&hash).expect("stored entry must hit");
        assert_eq!(back.to_json().to_pretty(), report.to_json().to_pretty());
        // Short-hash resolution.
        assert_eq!(store.find(&hash[..8]).as_deref(), Some(hash.as_str()));
        assert_eq!(store.find("zz"), None);
    }

    #[test]
    fn entries_list_and_gc_dry_run_vs_apply() {
        let store = tmp_store("gc");
        let runner = Runner::new(tiny_spec(11)).unwrap();
        let report = runner.run().unwrap();
        let hash = runner.spec_hash();
        store.put(&hash, &report).unwrap();

        let entries = store.entries();
        assert_eq!(entries.len(), 1);
        let e = &entries[0];
        assert_eq!(e.hash, hash);
        assert!(e.bytes > 0);
        assert!(e.age_days >= 0.0 && e.age_days < 1.0, "freshly written: {}", e.age_days);
        assert_eq!(e.schema, "acpc-run-v1");

        // Dry run never deletes, even with keep_days < age.
        let doomed = store.gc(-1.0, false).unwrap();
        assert_eq!(doomed.len(), 1);
        assert_eq!(store.len(), 1, "dry run must not delete");
        // Young entries survive an applied gc with a generous window…
        assert_eq!(store.gc(7.0, true).unwrap().len(), 0);
        assert_eq!(store.len(), 1);
        // …and fall to one with keep_days in the past.
        assert_eq!(store.gc(-1.0, true).unwrap().len(), 1);
        assert_eq!(store.len(), 0);
        assert!(!store.entry_path(&hash).exists());
    }

    /// Corruption in every flavor is a miss, never an error.
    #[test]
    fn corrupt_entries_are_misses() {
        let store = tmp_store("corrupt");
        let runner = Runner::new(tiny_spec(5)).unwrap();
        let report = runner.run().unwrap();
        let hash = runner.spec_hash();
        store.put(&hash, &report).unwrap();

        let path = store.entry_path(&hash);
        let good = std::fs::read_to_string(&path).unwrap();

        // Truncated JSON.
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(store.get(&hash).is_none());
        // Valid JSON, wrong schema.
        std::fs::write(&path, r#"{"schema": "acpc-run-v0"}"#).unwrap();
        assert!(store.get(&hash).is_none());
        // Valid report stored at the wrong address.
        let other = Runner::new(tiny_spec(6)).unwrap();
        store.put(&hash, &other.run().unwrap()).unwrap();
        assert!(store.get(&hash).is_none(), "entry must hash to its own address");
        // Restore → hit again.
        std::fs::write(&path, &good).unwrap();
        assert!(store.get(&hash).is_some());
    }
}
