//! The crate's public run API — one front door for every experiment.
//!
//! ```text
//!   RunSpec ──resolve──▶ Runner ──run()──▶ RunReport (schema acpc-run-v1)
//!   (JSON-round-trippable)                  └─ embeds the resolved spec
//! ```
//!
//! - [`RunSpec`] describes a run completely (policy, scenario/profile,
//!   predictor + artifact override, inference [`Backend`], hierarchy,
//!   accesses, shards, adaptive controller, seed) and round-trips through
//!   JSON;
//! - [`Runner`] owns all resolution — registry lookups, predictor loading
//!   with heuristic fallback (one process-wide native weight snapshot
//!   shared across shards and sweep cells; a per-thread PJRT cache for the
//!   `backend: pjrt` escape hatch), single vs set-sharded dispatch,
//!   controller construction — behind exactly one entrypoint,
//!   [`Runner::run`];
//! - [`RunReport`] is the versioned result; its embedded resolved spec
//!   re-runs to identical stats (`acpc run --spec <(jq .spec report.json)`).
//!
//! The CLI (`simulate`, `adapt`, per-cell `sweep`, `run`), the examples
//! and the benches all execute through this module; the former
//! `sim::run_experiment` / `run_workload` / `run_workload_adaptive` /
//! `run_workload_sharded` functions are crate-internal delegates now.

pub mod farm;
mod runner;
pub(crate) mod spec;
pub mod store;

pub use farm::{
    cells_to_json, load_manifest, run_farm, FarmCell, FarmConfig, FarmEntry, FARM_BASE_SEED,
};
pub use crate::predictor::Backend;
pub use runner::{PredictorFactory, RunReport, Runner};
pub use spec::{AdaptSpec, HierarchySpec, RunSpec, RunSpecBuilder, TrafficSpec, WorkloadSpec, SCHEMA};
pub use store::{spec_hash, CacheMode, ReportStore, StoreEntry};

use crate::adapt::{CompareOutput, ControllerSummary};
use anyhow::Result;

/// Replay the run a spec describes twice on identical seeds — once plain,
/// once with the adaptive controller — and report both arms plus the
/// controller's event log (`acpc adapt`). The spec's `adaptive` block
/// configures the controller of the second arm (attached with defaults
/// when absent); the baseline arm runs with it stripped. Each arm gets a
/// fresh predictor, so fine-tuning in the adaptive arm cannot leak into
/// the baseline.
pub fn run_compare(spec: &RunSpec) -> Result<CompareOutput> {
    let mut baseline_spec = spec.clone();
    baseline_spec.adaptive = None;
    let mut adaptive_spec = spec.clone();
    if adaptive_spec.adaptive.is_none() {
        adaptive_spec.adaptive = Some(AdaptSpec::default());
    }
    let baseline = Runner::new(baseline_spec)?.run()?;
    let adaptive = Runner::new(adaptive_spec)?.run()?;
    Ok(CompareOutput {
        baseline: baseline.result,
        adaptive: adaptive.result,
        summary: ControllerSummary::merge(adaptive.controllers),
        predictor_effective_baseline: baseline.predictor_effective,
        predictor_effective_adaptive: adaptive.predictor_effective,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PredictorKind;

    #[test]
    fn compare_runs_both_arms_on_one_seed() {
        let spec = RunSpec::builder()
            .scenario("multi-tenant-mix")
            .policy("acpc")
            .predictor(PredictorKind::Heuristic)
            .accesses(60_000)
            .seed(42)
            .adaptive_spec(AdaptSpec {
                window_accesses: Some(2048),
                warmup_windows: Some(2),
                cooldown_windows: Some(2),
                recover_windows: Some(2),
                ..AdaptSpec::default()
            })
            .build()
            .unwrap();
        let out = run_compare(&spec).unwrap();
        assert_eq!(out.baseline.report.accesses, 60_000);
        assert_eq!(out.adaptive.report.accesses, 60_000);
        assert!(out.summary.windows_observed > 0);
        let j = out.to_json();
        for key in ["baseline", "adaptive", "adaptation", "deltas"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert!(j.get("deltas").unwrap().get("hit_rate").unwrap().as_f64().is_some());
    }
}
