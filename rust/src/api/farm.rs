//! The experiment farm: execute many [`RunSpec`]s on the sweep thread
//! pool, every cell routed through the content-addressed report store.
//!
//! `acpc run --manifest <dir-or-file>` and `sim::run_sweep` both lower to
//! [`run_farm`]: label the specs, hash them, dedupe identical cells,
//! simulate only the misses (in parallel, on the persistent per-thread
//! shard pools), and fan the reports back out in input order with per-cell
//! hit provenance. A warm second invocation of the same manifest performs
//! **zero** simulation.
//!
//! ## Manifest format
//!
//! A manifest is either a directory of `*.json` spec files (processed in
//! name order) or a single file. Each file may contain:
//!
//! - one spec object (`{"policy": "acpc", ...}`),
//! - an array of spec objects, or
//! - `{"runs": [ <spec>, ... ]}`.
//!
//! Entries are labeled by the spec's `name` when present, else by the file
//! stem (suffixed `#k` for the k-th spec of a multi-spec file). Specs
//! without a `seed` get a deterministic one derived from the farm's base
//! seed and the entry's label+position — repeat invocations therefore hash
//! (and cache) identically.

use super::runner::{RunReport, Runner};
use super::spec::RunSpec;
use super::store::{CacheMode, ReportStore};
use crate::util::json::Json;
use crate::util::pool::{default_threads, run_parallel};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Default base seed for manifest entries that specify none.
pub const FARM_BASE_SEED: u64 = 0xFA23_5EED;

/// One labeled spec in a farm invocation.
#[derive(Debug, Clone)]
pub struct FarmEntry {
    pub label: String,
    pub spec: RunSpec,
}

/// How [`run_farm`] executes: parallelism, store attachment, and the base
/// seed for seedless specs.
#[derive(Debug, Clone)]
pub struct FarmConfig {
    /// Worker threads (each cell may additionally shard internally).
    pub threads: usize,
    /// Report store consulted per `cache`; `None` disables caching.
    pub store: Option<ReportStore>,
    pub cache: CacheMode,
    /// Base seed mixed into derived per-entry seeds.
    pub base_seed: u64,
}

impl Default for FarmConfig {
    fn default() -> Self {
        Self {
            threads: default_threads(),
            store: None,
            cache: CacheMode::Off,
            base_seed: FARM_BASE_SEED,
        }
    }
}

/// One executed (or cache-served) farm cell, in manifest order.
#[derive(Debug, Clone)]
pub struct FarmCell {
    pub label: String,
    /// Content address of the resolved spec (the store key).
    pub spec_hash: String,
    /// `true` when the report came from the store or from an identical
    /// cell earlier in the same manifest — i.e. this cell simulated
    /// nothing.
    pub cached: bool,
    pub report: RunReport,
}

/// Load a manifest (directory of `*.json` files, or one file) into
/// labeled, seeded entries. See the module docs for the accepted shapes.
pub fn load_manifest(path: &Path, base_seed: u64) -> Result<Vec<FarmEntry>> {
    let mut entries = Vec::new();
    if path.is_dir() {
        let mut files: Vec<_> = std::fs::read_dir(path)
            .with_context(|| format!("reading manifest dir {}", path.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "json"))
            .collect();
        files.sort();
        if files.is_empty() {
            bail!("manifest dir {} contains no .json files", path.display());
        }
        for f in files {
            load_manifest_file(&f, &mut entries)?;
        }
    } else {
        load_manifest_file(path, &mut entries)?;
    }
    // Seed seedless specs deterministically so repeat invocations hash —
    // and therefore cache — identically.
    for (i, e) in entries.iter_mut().enumerate() {
        if e.spec.seed.is_none() {
            e.spec.seed = Some(crate::sim::cell_seed(base_seed, &e.label, &i.to_string()));
        }
    }
    Ok(entries)
}

fn load_manifest_file(path: &Path, out: &mut Vec<FarmEntry>) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading manifest file {}", path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
    let stem = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
    let specs: Vec<&Json> = if let Some(arr) = j.as_arr() {
        arr.iter().collect()
    } else if let Some(runs) = j.get("runs") {
        runs.as_arr()
            .ok_or_else(|| anyhow::anyhow!("{}: \"runs\" must be an array", path.display()))?
            .iter()
            .collect()
    } else {
        vec![&j]
    };
    if specs.is_empty() {
        bail!("{}: no specs", path.display());
    }
    let multi = specs.len() > 1;
    for (k, sj) in specs.into_iter().enumerate() {
        let spec = RunSpec::from_json(sj)
            .with_context(|| format!("{} (spec #{k})", path.display()))?;
        let label = match &spec.name {
            Some(n) if !n.is_empty() => n.clone(),
            _ if multi => format!("{stem}#{k}"),
            _ => stem.clone(),
        };
        out.push(FarmEntry { label, spec });
    }
    Ok(())
}

/// Execute labeled specs per `cfg`: hash, dedupe, simulate the misses on
/// the thread pool, and return cells in input order. Spec validation
/// errors fail fast (before any simulation); store read errors are misses
/// by construction, and store write failures degrade to a warning.
pub fn run_farm(entries: Vec<FarmEntry>, cfg: &FarmConfig) -> Result<Vec<FarmCell>> {
    // Hash everything up front — validates every spec before work starts.
    let mut hashes = Vec::with_capacity(entries.len());
    for e in &entries {
        let h = super::store::spec_hash(&e.spec)
            .with_context(|| format!("farm entry '{}'", e.label))?;
        hashes.push(h);
    }
    // Dedupe identical cells within this invocation: the first occurrence
    // runs; duplicates reuse its report.
    let mut first_of: BTreeMap<&str, usize> = BTreeMap::new();
    let mut unique: Vec<usize> = Vec::new();
    for (i, h) in hashes.iter().enumerate() {
        first_of.entry(h.as_str()).or_insert_with(|| {
            unique.push(i);
            unique.len() - 1
        });
    }
    let jobs: Vec<_> = unique
        .iter()
        .map(|&i| {
            let spec = entries[i].spec.clone();
            let store = cfg.store.clone();
            let cache = cfg.cache;
            move || -> Result<(RunReport, bool)> {
                let mut runner = Runner::new(spec)?;
                if let Some(s) = store {
                    runner = runner.with_store(s, cache);
                }
                runner.run_cached()
            }
        })
        .collect();
    let outs = run_parallel(cfg.threads, jobs);
    let mut ran: Vec<(RunReport, bool)> = Vec::with_capacity(outs.len());
    for (slot, out) in unique.iter().zip(outs) {
        ran.push(out.with_context(|| format!("farm entry '{}'", entries[*slot].label))?);
    }

    let mut cells = Vec::with_capacity(entries.len());
    for (i, e) in entries.into_iter().enumerate() {
        let slot = first_of[hashes[i].as_str()];
        let (report, store_hit) = &ran[slot];
        let duplicate = unique[slot] != i;
        cells.push(FarmCell {
            label: e.label,
            spec_hash: hashes[i].clone(),
            cached: *store_hit || duplicate,
            report: report.clone(),
        });
    }
    Ok(cells)
}

/// Serialize farm cells for `acpc run --manifest --json` (schema
/// `acpc-farm-v1`): one entry per cell, in manifest order, embedding the
/// full report plus hit provenance.
pub fn cells_to_json(cells: &[FarmCell]) -> Json {
    Json::from_pairs(vec![
        ("schema", Json::Str("acpc-farm-v1".into())),
        (
            "cells",
            Json::Arr(
                cells
                    .iter()
                    .map(|c| {
                        Json::from_pairs(vec![
                            ("label", Json::Str(c.label.clone())),
                            ("spec_hash", Json::Str(c.spec_hash.clone())),
                            ("cached", Json::Bool(c.cached)),
                            ("report", c.report.to_json()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PredictorKind;

    fn entry(label: &str, seed: u64) -> FarmEntry {
        FarmEntry {
            label: label.into(),
            spec: RunSpec::builder()
                .preset("smoke")
                .policy("lru")
                .predictor(PredictorKind::None)
                .accesses(5_000)
                .seed(seed)
                .build()
                .unwrap(),
        }
    }

    /// Identical cells inside one manifest run once; duplicates are marked
    /// cached even with no store attached.
    #[test]
    fn duplicate_cells_dedupe_within_one_invocation() {
        let entries = vec![entry("a", 1), entry("b", 2), entry("a-again", 1)];
        let cells = run_farm(entries, &FarmConfig { threads: 2, ..Default::default() }).unwrap();
        assert_eq!(cells.len(), 3);
        assert!(!cells[0].cached && !cells[1].cached);
        assert!(cells[2].cached, "identical later cell must reuse the first");
        assert_eq!(cells[0].spec_hash, cells[2].spec_hash);
        assert_ne!(cells[0].spec_hash, cells[1].spec_hash);
        assert_eq!(
            cells[0].report.to_json().to_pretty(),
            cells[2].report.to_json().to_pretty()
        );
    }

    #[test]
    fn manifest_loading_labels_and_seeds_deterministically() {
        let dir = std::env::temp_dir().join("acpc_farm_unit_manifest");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("b_pair.json"),
            r#"{"runs": [
                {"policy": "lru", "predictor": "none", "accesses": 5000},
                {"policy": "srrip", "predictor": "none", "accesses": 5000, "name": "named"}
            ]}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("a_single.json"),
            r#"{"policy": "lfu", "predictor": "none", "accesses": 5000, "seed": "9"}"#,
        )
        .unwrap();
        let entries = load_manifest(&dir, FARM_BASE_SEED).unwrap();
        // Directory order is name-sorted; labels fall back to file stems.
        let labels: Vec<&str> = entries.iter().map(|e| e.label.as_str()).collect();
        assert_eq!(labels, vec!["a_single", "b_pair#0", "named"]);
        // Explicit seed is kept; missing seeds are derived deterministically.
        assert_eq!(entries[0].spec.seed, Some(9));
        assert!(entries[1].spec.seed.is_some());
        let again = load_manifest(&dir, FARM_BASE_SEED).unwrap();
        assert_eq!(entries[1].spec.seed, again[1].spec.seed);
        // A different base seed re-seeds the seedless entries only.
        let other = load_manifest(&dir, 1).unwrap();
        assert_ne!(other[1].spec.seed, entries[1].spec.seed);
        assert_eq!(other[0].spec.seed, Some(9));
        std::fs::remove_dir_all(&dir).ok();
    }
}
