//! [`Runner`] — the crate's one run entrypoint — and the versioned
//! [`RunReport`] it produces.
//!
//! The runner owns every piece of resolution that used to be duplicated
//! across the CLI commands: scenario-registry lookup, predictor
//! construction with artifact fallback (one process-wide native weight
//! snapshot shared across every shard and sweep cell, plus a per-thread
//! PJRT cache for the `backend: pjrt` escape hatch), sharded-vs-single
//! dispatch, and adaptive-controller wiring.
//! `simulate`, `adapt`, each `sweep` cell, `acpc run --spec` and the
//! examples all execute through [`Runner::run`]; the legacy
//! `sim::run_workload*` functions survive only as crate-internal delegates.

use super::spec::{Resolved, ResolvedTraffic, RunSpec, SCHEMA};
use super::store::{CacheMode, ReportStore};
use crate::adapt::{AdaptiveController, ControllerSummary};
use crate::config::PredictorKind;
use crate::metrics::MetricsReport;
use crate::obs::{SourceId, TelemetryBus};
use crate::predictor::{Backend, HeuristicPredictor, ModelRuntime, PredictorBox};
use crate::runtime::{Manifest, NativeModel, NativeWeights};
use crate::sim::shard::{run_workload_sharded, PredictorReclaim};
use crate::sim::SimResult;
use crate::traffic::{OpenLoopWorkload, ReplayWorkload, TrafficSummary};
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A predictor constructor invoked once per worker thread (shard `k` gets
/// `factory(k)`). The indirection exists because *PJRT-backed* predictors
/// hold thread-affine handles and must be built inside the thread that runs
/// them; a factory handing out [`PredictorBox::Native`] clones over one
/// shared [`NativeWeights`] snapshot is equally valid (and what the runner
/// itself does for the default native backend). This is the parameter type
/// of [`Runner::with_predictor_factory`].
pub type PredictorFactory = Arc<dyn Fn(usize) -> PredictorBox + Send + Sync>;

/// How a spec-built run obtains its predictor(s), decided once per
/// [`Runner::run`] and shared by the single-threaded and sharded paths.
enum SpecPlan {
    /// Native backend, inference-only run: every shard/worker gets a
    /// [`PredictorBox::Native`] clone over this one weight snapshot — the
    /// artifact is read and repacked once per process, not once per thread.
    SharedNative(Arc<NativeWeights>),
    /// Native backend requested but the artifacts are unavailable; the
    /// (already-warned) fallback is the heuristic predictor.
    FallbackHeuristic,
    /// Build inside each worker thread: PJRT-backed runs (`backend: pjrt`
    /// or any run that trains — Adam stays in XLA) and non-learned kinds.
    PerThread,
}

/// Where the runner gets its predictor(s) from.
enum PredictorSource {
    /// Built from the spec (kind + optional artifact-model override), with
    /// heuristic fallback and per-thread TCN caching where safe.
    Spec,
    /// A caller-supplied predictor instance (single-shard runs only —
    /// PJRT handles are thread-affine). Consumed by the first `run()`.
    Owned(RefCell<Option<PredictorBox>>),
    /// A caller-supplied factory, invoked inside each worker thread.
    Factory(PredictorFactory),
}

/// Executes a resolved [`RunSpec`]. Construct with [`Runner::new`], run
/// with [`Runner::run`] — the single public run entrypoint of the crate.
///
/// ```no_run
/// use acpc::api::{Runner, RunSpec};
/// use acpc::config::PredictorKind;
///
/// let spec = RunSpec::builder()
///     .scenario("multi-tenant-mix")
///     .policy("acpc")
///     .predictor(PredictorKind::Tcn) // falls back to the heuristic sans artifacts
///     .shards(4)
///     .adaptive(true)
///     .build()?;
/// let report = Runner::new(spec)?.run()?;
/// println!("{}", report.to_json().to_pretty());
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct Runner {
    resolved: Resolved,
    source: PredictorSource,
    store: Option<(ReportStore, CacheMode)>,
    bus: Option<TelemetryBus>,
}

impl Runner {
    /// Resolve and validate a spec. Errors cover unknown
    /// policies/scenarios/profiles, bad geometry, unshardable hierarchies
    /// and predictor-less adaptive runs — nothing is deferred to mid-run.
    pub fn new(spec: RunSpec) -> Result<Runner> {
        Ok(Runner {
            resolved: spec.resolve()?,
            source: PredictorSource::Spec,
            store: None,
            bus: None,
        })
    }

    /// [`Runner::new`] from a spec file (`acpc run --spec`).
    pub fn from_spec_file(path: &std::path::Path) -> Result<Runner> {
        Self::new(RunSpec::from_file(path)?)
    }

    /// Supply a concrete predictor instance (e.g. a model with fine-tuned
    /// weights loaded from a checkpoint) instead of building one from the
    /// spec. Single-shard runs only; consumed by the first [`run`](Self::run).
    pub fn with_predictor(mut self, predictor: PredictorBox) -> Self {
        self.source = PredictorSource::Owned(RefCell::new(Some(predictor)));
        self
    }

    /// Supply a predictor factory invoked once per worker thread (sharded
    /// runs construct predictors *inside* each shard thread — PJRT handles
    /// are thread-affine).
    pub fn with_predictor_factory(mut self, factory: PredictorFactory) -> Self {
        self.source = PredictorSource::Factory(factory);
        self
    }

    /// Attach a content-addressed [`ReportStore`]: [`run`](Self::run)
    /// consults it per `mode` before simulating. Only spec-built predictor
    /// runs use the store — a run with an *injected* predictor
    /// ([`with_predictor`](Self::with_predictor) /
    /// [`with_predictor_factory`](Self::with_predictor_factory)) is not
    /// reproducible from the spec alone and always simulates.
    pub fn with_store(mut self, store: ReportStore, mode: CacheMode) -> Self {
        self.store = Some((store, mode));
        self
    }

    /// Attach a [`TelemetryBus`]: the run streams window stats, drift
    /// events, adaptation actions and periodic cache-health samples onto it
    /// (source `sim/k` per shard, `sim/0` single-threaded). Attaching a bus
    /// never perturbs the run — a subscribed run's [`RunReport`] is
    /// byte-identical to an unsubscribed one (asserted by
    /// `tests/integration_obs.rs`). Note that a report served from an
    /// attached store ([`with_store`](Self::with_store)) skips simulation
    /// and therefore emits no events.
    pub fn with_telemetry(mut self, bus: TelemetryBus) -> Self {
        self.bus = Some(bus);
        self
    }

    /// The content address of this runner's resolved spec (the report
    /// store key; see [`super::store::spec_hash`] for the derivation).
    pub fn spec_hash(&self) -> String {
        super::store::resolved_spec_hash(&self.resolved.spec)
    }

    /// The fully-resolved spec this runner executes (also embedded in the
    /// report).
    pub fn spec(&self) -> &RunSpec {
        &self.resolved.spec
    }

    /// Replay runs always simulate: the spec hash covers the capture
    /// *path*, not its bytes, so a store hit could silently serve a stale
    /// capture's results.
    fn replays(&self) -> bool {
        matches!(self.resolved.traffic, Some(ResolvedTraffic::Replay(_)))
    }

    /// May this run share the per-thread cached PJRT TCN? Only for the
    /// `backend: pjrt` escape hatch (native runs share one process-wide
    /// weight snapshot instead — see [`SpecPlan::SharedNative`]), and only
    /// when the spec asks for the default TCN artifact *and* nothing in the
    /// run can mutate its weights (no adaptive retrains, no §3.4 interval
    /// feedback).
    fn cache_eligible(&self) -> bool {
        self.resolved.backend == Backend::Pjrt
            && self.resolved.cfg.predictor == PredictorKind::Tcn
            && self.resolved.model.is_none()
            && self.resolved.controller.is_none()
            && self.resolved.cfg.feedback_interval == 0
    }

    /// Decide how spec-built predictors are obtained for this run (see
    /// [`SpecPlan`]). Trainable runs always use a [`ModelRuntime`]
    /// ([`PredictorBox::Model`]) because `train_step` needs PJRT — its
    /// *predict* path still runs the native kernel unless `backend: pjrt`.
    fn spec_plan(&self) -> SpecPlan {
        let r = &self.resolved;
        let learned =
            matches!(r.cfg.predictor, PredictorKind::Dnn | PredictorKind::Tcn);
        let trains = r.controller.is_some() || r.cfg.feedback_interval > 0;
        if !learned || trains || r.backend != Backend::Native {
            return SpecPlan::PerThread;
        }
        let name = r.model.as_deref().unwrap_or(match r.cfg.predictor {
            PredictorKind::Dnn => "dnn",
            _ => "tcn",
        });
        match shared_native_weights(name) {
            Some(w) => SpecPlan::SharedNative(w),
            None => SpecPlan::FallbackHeuristic,
        }
    }

    /// Execute the run: consult the attached report store (if any), else
    /// resolve the predictor, dispatch single-threaded or set-sharded, and
    /// assemble the [`RunReport`].
    pub fn run(&self) -> Result<RunReport> {
        Ok(self.run_cached()?.0)
    }

    /// Like [`run`](Self::run), additionally reporting provenance: `true`
    /// when the report was served from the store without simulating.
    pub fn run_cached(&self) -> Result<(RunReport, bool)> {
        if let Some((store, mode)) = &self.store {
            if mode.reads() && matches!(self.source, PredictorSource::Spec) && !self.replays() {
                let hash = self.spec_hash();
                if let Some(report) = store.get(&hash) {
                    return Ok((report, true));
                }
                let report = self.execute()?;
                if mode.writes() {
                    if let Err(e) = store.put(&hash, &report) {
                        crate::log_warn!("report store: failed to persist entry {hash}: {e}");
                    }
                }
                return Ok((report, false));
            }
        }
        Ok((self.execute()?, false))
    }

    fn execute(&self) -> Result<RunReport> {
        let r = &self.resolved;
        let cache = self.cache_eligible();
        let mut workload: Box<dyn crate::trace::Workload> = match &r.traffic {
            Some(ResolvedTraffic::Replay(path)) => Box::new(ReplayWorkload::open(path)?),
            Some(ResolvedTraffic::OpenLoop(ol)) => {
                Box::new(OpenLoopWorkload::new(r.cfg.workload(), ol.clone(), None))
            }
            None => r.cfg.workload(),
        };

        let (result, controllers) = if r.shards > 1 {
            let mk: PredictorFactory = match &self.source {
                PredictorSource::Factory(f) => Arc::clone(f),
                PredictorSource::Owned(_) => bail!(
                    "an owned predictor cannot drive a sharded run (it may hold \
                     thread-affine PJRT handles); use with_predictor_factory"
                ),
                PredictorSource::Spec => {
                    let kind = r.cfg.predictor;
                    let model = r.model.clone();
                    let backend = r.backend;
                    let plan = self.spec_plan();
                    Arc::new(move |_shard| match &plan {
                        SpecPlan::SharedNative(w) => {
                            PredictorBox::Native(NativeModel::from_weights(Arc::clone(w)))
                        }
                        SpecPlan::FallbackHeuristic => {
                            PredictorBox::Heuristic(HeuristicPredictor)
                        }
                        SpecPlan::PerThread => {
                            build_in_thread(kind, model.as_deref(), cache, backend).0
                        }
                    })
                }
            };
            // Loaded default-TCN boxes flow back into each shard thread's
            // cache after the run; the shard threads persist across cells
            // (sim::shard's pool), so a sweep pays the artifact load once
            // per thread, not once per cell.
            let reclaim: Option<PredictorReclaim> =
                if cache && matches!(self.source, PredictorSource::Spec) {
                    Some(Arc::new(|_shard, p: PredictorBox| {
                        if matches!(p, PredictorBox::Model(_)) && p.name() == "tcn" {
                            put_back_thread_tcn(p);
                        }
                    }))
                } else {
                    None
                };
            let run = run_workload_sharded(
                &r.cfg,
                workload.as_mut(),
                r.shards,
                &mk,
                reclaim.as_ref(),
                r.controller.as_ref(),
                self.bus.as_ref(),
            )?;
            (run.result, run.controllers)
        } else {
            let (mut predictor, from_cache) = match &self.source {
                PredictorSource::Spec => match self.spec_plan() {
                    SpecPlan::SharedNative(w) => {
                        (PredictorBox::Native(NativeModel::from_weights(w)), false)
                    }
                    SpecPlan::FallbackHeuristic => {
                        (PredictorBox::Heuristic(HeuristicPredictor), false)
                    }
                    SpecPlan::PerThread => {
                        build_in_thread(r.cfg.predictor, r.model.as_deref(), cache, r.backend)
                    }
                },
                PredictorSource::Owned(slot) => {
                    let p = slot.borrow_mut().take();
                    match p {
                        Some(p) => (p, false),
                        None => bail!(
                            "custom predictor already consumed by a previous run(); \
                             construct a new Runner"
                        ),
                    }
                }
                PredictorSource::Factory(f) => (f(0), false),
            };
            let mut controller =
                r.controller.clone().map(AdaptiveController::new);
            let publisher = self.bus.as_ref().map(|b| b.publisher(SourceId::sim(0)));
            let result = crate::sim::run_workload_adaptive(
                &r.cfg,
                workload.as_mut(),
                &mut predictor,
                controller.as_mut(),
                publisher,
            );
            if from_cache {
                put_back_thread_tcn(predictor);
            }
            let controllers =
                controller.map(|c| vec![c.into_summary()]).unwrap_or_default();
            (result, controllers)
        };

        let predictor_effective =
            effective_label(r.cfg.predictor, &result.predictor, r.controller.is_some());
        Ok(RunReport {
            spec: r.spec.clone(),
            predictor_effective,
            result,
            controllers,
        })
    }
}

/// Inverse of [`effective_label`]'s decoration: the bare name of the
/// predictor that ran, recovered from a serialized `predictor_effective`
/// (report-store rehydration — `SimResult::predictor` is not serialized
/// separately).
fn base_predictor_name(effective: &str) -> String {
    let s = effective
        .strip_prefix("adaptive(")
        .and_then(|x| x.strip_suffix(')'))
        .unwrap_or(effective);
    s.strip_suffix("(fallback)").unwrap_or(s).to_string()
}

/// Provenance label for what actually ran: the predictor's own name,
/// decorated with `(fallback)` when a learned predictor degraded to the
/// heuristic and wrapped in `adaptive(..)` when a controller was attached.
fn effective_label(requested: PredictorKind, ran: &str, adaptive: bool) -> String {
    let learned = matches!(requested, PredictorKind::Dnn | PredictorKind::Tcn);
    let base = if learned && ran == "heuristic" {
        "heuristic(fallback)".to_string()
    } else {
        ran.to_string()
    };
    if adaptive {
        format!("adaptive({base})")
    } else {
        base
    }
}

// ---- predictor construction -------------------------------------------

/// Build a predictor box for a kind, loading the model from the AOT
/// artifacts when needed. Hard error on load failure — callers that want
/// graceful degradation go through [`build_in_thread`].
fn build_predictor(kind: PredictorKind, model_override: Option<&str>) -> Result<PredictorBox> {
    match kind {
        PredictorKind::None => Ok(PredictorBox::None),
        PredictorKind::Heuristic => Ok(PredictorBox::Heuristic(HeuristicPredictor)),
        PredictorKind::Dnn | PredictorKind::Tcn => {
            let name = model_override.unwrap_or(match kind {
                PredictorKind::Dnn => "dnn",
                _ => "tcn",
            });
            let rt = ModelRuntime::load_from_artifacts(name)?;
            Ok(PredictorBox::Model(Box::new(rt)))
        }
    }
}

/// Build a predictor in the *calling* thread with the runner's fallback
/// policy: learned predictors degrade to the heuristic with a warning when
/// the artifacts are absent or fail to load. Learned boxes built here are
/// [`ModelRuntime`]s whose predict path honours `backend`. Returns
/// `(box, from_cache)`.
fn build_in_thread(
    kind: PredictorKind,
    model: Option<&str>,
    cache: bool,
    backend: Backend,
) -> (PredictorBox, bool) {
    match kind {
        PredictorKind::None => (PredictorBox::None, false),
        PredictorKind::Heuristic => (PredictorBox::Heuristic(HeuristicPredictor), false),
        PredictorKind::Tcn if cache && model.is_none() => match take_thread_tcn() {
            Some(mut p) => {
                if let Some(m) = p.model_mut() {
                    m.set_backend(backend);
                }
                (p, true)
            }
            // take_thread_tcn already warned, once per thread.
            None => (PredictorBox::Heuristic(HeuristicPredictor), false),
        },
        kind => match build_predictor(kind, model) {
            Ok(mut p) => {
                if let Some(m) = p.model_mut() {
                    m.set_backend(backend);
                }
                (p, false)
            }
            Err(e) => {
                crate::log_warn!(
                    "runner: predictor '{}' failed to load ({e}); falling back to the \
                     heuristic predictor",
                    kind.label()
                );
                (PredictorBox::Heuristic(HeuristicPredictor), false)
            }
        },
    }
}

/// Process-wide native weight snapshots, keyed by model name. Unlike the
/// PJRT path there is nothing thread-affine to cache per thread: one
/// artifact read + repack serves every shard, sweep cell, and serve worker
/// in the process. Failures are cached too (a broken artifact bundle is not
/// re-probed per run).
static NATIVE_WEIGHTS: OnceLock<Mutex<HashMap<String, Option<Arc<NativeWeights>>>>> =
    OnceLock::new();

/// One process-wide warning for missing/broken native weights (mirrors
/// [`TCN_FALLBACK_WARNED`] on the PJRT path).
static NATIVE_FALLBACK_WARNED: AtomicBool = AtomicBool::new(false);

fn load_native_weights(name: &str) -> Result<Arc<NativeWeights>> {
    let dir = crate::runtime::artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("artifacts not built"))?;
    let manifest = Manifest::load(&dir)?;
    let mm = manifest.model(name)?;
    let store = crate::runtime::ParamStore::load(&manifest, name)?;
    Ok(Arc::new(NativeWeights::from_params(mm, &store)?))
}

/// Fetch (loading at most once per process) the shared native weight
/// snapshot for a model. `None` means unavailable — already warned, cached
/// as a permanent failure.
fn shared_native_weights(name: &str) -> Option<Arc<NativeWeights>> {
    let map = NATIVE_WEIGHTS.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = map.lock().unwrap();
    if let Some(cached) = map.get(name) {
        return cached.clone();
    }
    let loaded = match load_native_weights(name) {
        Ok(w) => Some(w),
        Err(e) => {
            if !NATIVE_FALLBACK_WARNED.swap(true, Ordering::Relaxed) {
                crate::log_warn!(
                    "runner: native weights for '{name}' unavailable ({e}); learned \
                     runs fall back to the heuristic predictor (reported once; see \
                     predictor_effective for per-run provenance)"
                );
            }
            None
        }
    };
    map.insert(name.to_string(), loaded.clone());
    loaded
}

fn build_tcn_in_thread() -> Option<PredictorBox> {
    let rt = ModelRuntime::load_from_artifacts("tcn").ok()?;
    Some(PredictorBox::Model(Box::new(rt)))
}

thread_local! {
    /// Per-thread TCN cache: PJRT handles are thread-affine, and cache-
    /// eligible runs never mutate weights, so one artifact load + PJRT
    /// compile serves every eligible run this thread (sweep worker *or*
    /// persistent shard worker) ever executes. Tri-state: outer `None` =
    /// never probed; `Some(None)` = probe failed (permanent — a broken
    /// PJRT setup is not retried per run); `Some(Some(_))` = loaded. The
    /// box is taken for the duration of a run and put back afterwards.
    static THREAD_TCN: RefCell<Option<Option<PredictorBox>>> =
        const { RefCell::new(None) };
}

/// One process-wide warning for missing/broken TCN artifacts: a sweep can
/// probe from dozens of worker + shard-pool threads, and one line says it
/// all (the per-run provenance is in `predictor_effective`).
static TCN_FALLBACK_WARNED: AtomicBool = AtomicBool::new(false);

/// Fetch the thread's cached TCN, probing the artifacts at most once per
/// thread (success *and* failure are both cached).
fn take_thread_tcn() -> Option<PredictorBox> {
    THREAD_TCN.with(|c| {
        let mut slot = c.borrow_mut();
        if slot.is_none() {
            let loaded = build_tcn_in_thread();
            if loaded.is_none() && !TCN_FALLBACK_WARNED.swap(true, Ordering::Relaxed) {
                crate::log_warn!(
                    "runner: TCN artifacts unavailable; tcn runs fall back to the \
                     heuristic predictor (reported once; see predictor_effective for \
                     per-run provenance)"
                );
            }
            *slot = Some(loaded);
        }
        slot.as_mut().unwrap().take()
    })
}

fn put_back_thread_tcn(p: PredictorBox) {
    THREAD_TCN.with(|c| *c.borrow_mut() = Some(Some(p)));
}

// ---- report ------------------------------------------------------------

/// The versioned outcome of one [`Runner::run`] (schema `acpc-run-v1`).
/// Embeds the fully-resolved [`RunSpec`], so feeding a report's `spec`
/// object back through `acpc run --spec` (or [`RunSpec::from_json`])
/// reproduces the run bit-for-bit — wall-clock fields aside. One caveat:
/// runs that *injected* a predictor ([`Runner::with_predictor`] /
/// [`Runner::with_predictor_factory`]) are reproducible only up to those
/// weights — the spec records the requested predictor kind, not the
/// injected parameters (check `predictor_effective` against the spec).
#[derive(Debug, Clone)]
pub struct RunReport {
    /// The fully-resolved spec that produced this report.
    pub spec: RunSpec,
    /// Provenance of the predictor that actually ran (`tcn`,
    /// `heuristic(fallback)`, `adaptive(heuristic)`, `mixed(..)`, ...).
    pub predictor_effective: String,
    pub result: SimResult,
    /// Per-controller summaries of adaptive runs (one per shard; empty
    /// otherwise).
    pub controllers: Vec<ControllerSummary>,
}

impl RunReport {
    /// Merged adaptation summary of an adaptive run (`None` otherwise).
    pub fn adaptation(&self) -> Option<ControllerSummary> {
        if self.controllers.is_empty() {
            None
        } else {
            Some(ControllerSummary::merge(self.controllers.clone()))
        }
    }

    pub fn to_json(&self) -> Json {
        let r = &self.result;
        let mut j = Json::from_pairs(vec![
            ("schema", Json::Str(SCHEMA.into())),
            ("spec", self.spec.to_json()),
            ("predictor_effective", Json::Str(self.predictor_effective.clone())),
            ("metrics", r.report.to_json()),
            ("prediction_batches", Json::Num(r.prediction_batches as f64)),
            ("online_train_steps", Json::Num(r.online_train_steps as f64)),
            ("adapt_windows", Json::Num(r.adapt_windows as f64)),
            ("drift_events", Json::Num(r.drift_events as f64)),
            ("predictor_swaps", Json::Num(r.predictor_swaps as f64)),
            ("throttled_windows", Json::Num(r.throttled_windows as f64)),
            ("wall_secs", Json::Num(r.wall_secs)),
            ("accesses_per_sec", Json::Num(r.accesses_per_sec)),
        ]);
        if let Some(s) = self.adaptation() {
            j.set("adaptation", s.to_json());
        }
        if let Some(t) = &r.traffic {
            j.set("traffic", t.to_json());
        }
        j
    }

    /// Inverse of [`Self::to_json`] — how the report store rehydrates a
    /// cached run. The round-trip is byte-exact: serializing the returned
    /// report reproduces the stored text, so a cache hit is
    /// indistinguishable from the cold run that produced it (including its
    /// recorded `wall_secs` — provenance is reported separately by
    /// [`Runner::run_cached`]).
    pub fn from_json(j: &Json) -> Result<RunReport> {
        match j.req("schema")?.as_str() {
            Some(SCHEMA) => {}
            other => bail!("report schema mismatch: expected {SCHEMA:?}, got {other:?}"),
        }
        let spec = RunSpec::from_json(j.req("spec")?)?;
        let predictor_effective = j
            .req("predictor_effective")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("predictor_effective: expected string"))?
            .to_string();
        let report = MetricsReport::from_json(j.req("metrics")?)?;
        let f = |key: &str| -> Result<f64> {
            match j.req(key)? {
                Json::Null => Ok(f64::NAN),
                v => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("report.{key}: expected number")),
            }
        };
        let u = |key: &str| -> Result<u64> {
            let v = f(key)?;
            if v.is_finite() && v >= 0.0 && v.fract() == 0.0 {
                Ok(v as u64)
            } else {
                bail!("report.{key}: expected non-negative integer")
            }
        };
        let controllers = match j.get("adaptation") {
            Some(a) => vec![ControllerSummary::from_json(a)?],
            None => Vec::new(),
        };
        let traffic = match j.get("traffic") {
            Some(t) => Some(
                TrafficSummary::from_json(t)
                    .map_err(|e| anyhow::anyhow!("report.traffic: {e}"))?,
            ),
            None => None,
        };
        let result = SimResult {
            tokens: report.tokens,
            emu: report.emu,
            predictor: base_predictor_name(&predictor_effective),
            prediction_batches: u("prediction_batches")?,
            online_train_steps: u("online_train_steps")?,
            wall_secs: f("wall_secs")?,
            accesses_per_sec: f("accesses_per_sec")?,
            adapt_windows: u("adapt_windows")?,
            drift_events: u("drift_events")?,
            predictor_swaps: u("predictor_swaps")?,
            throttled_windows: u("throttled_windows")?,
            traffic,
            report,
        };
        Ok(RunReport { spec, predictor_effective, result, controllers })
    }

    /// One-line counters summary (the CLI prints this under the metrics).
    pub fn counters_line(&self) -> String {
        let r = &self.result;
        format!(
            "predictor={} tokens={} emu={:.3} pred_batches={} online_steps={} \
             wall={:.2}s ({:.2}M acc/s)",
            self.predictor_effective,
            r.tokens,
            r.emu,
            r.prediction_batches,
            r.online_train_steps,
            r.wall_secs,
            r.accesses_per_sec / 1e6
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    /// Parity: the Runner's single-shard path must be byte-identical to
    /// driving the crate-internal `run_workload` directly with the same
    /// resolved configuration — the API is a front door, not a fork.
    #[test]
    fn runner_matches_internal_run_workload() {
        let seed = 0x9A17;
        let mut cfg = ExperimentConfig::for_scenario(
            "decode-heavy",
            "acpc",
            PredictorKind::Heuristic,
            seed,
        )
        .unwrap();
        cfg.accesses = 60_000;
        let mut workload = cfg.workload();
        let mut predictor = PredictorBox::Heuristic(HeuristicPredictor);
        let old = crate::sim::run_workload(&cfg, workload.as_mut(), &mut predictor);

        let spec = RunSpec::builder()
            .scenario("decode-heavy")
            .policy("acpc")
            .predictor(PredictorKind::Heuristic)
            .accesses(60_000)
            .seed(seed)
            .build()
            .unwrap();
        let new = Runner::new(spec).unwrap().run().unwrap();
        assert_eq!(
            old.report.to_json().to_pretty(),
            new.result.report.to_json().to_pretty(),
            "runner must reproduce the direct engine path exactly"
        );
        assert_eq!(old.prediction_batches, new.result.prediction_batches);
        assert_eq!(old.tokens, new.result.tokens);
        assert_eq!(new.predictor_effective, "heuristic");
    }

    /// Parity for the sharded path against `run_workload_sharded`.
    #[test]
    fn runner_matches_internal_run_workload_sharded() {
        let seed = 0x51AB;
        let mut cfg =
            ExperimentConfig::for_scenario("decode-heavy", "lru", PredictorKind::None, seed)
                .unwrap();
        cfg.accesses = 40_000;
        let mut workload = cfg.workload();
        let mk: PredictorFactory = Arc::new(|_| PredictorBox::None);
        let old =
            run_workload_sharded(&cfg, workload.as_mut(), 4, &mk, None, None, None).unwrap();

        let spec = RunSpec::builder()
            .scenario("decode-heavy")
            .policy("lru")
            .predictor(PredictorKind::None)
            .accesses(40_000)
            .seed(seed)
            .shards(4)
            .build()
            .unwrap();
        let new = Runner::new(spec).unwrap().run().unwrap();
        assert_eq!(
            old.result.report.to_json().to_pretty(),
            new.result.report.to_json().to_pretty()
        );
        assert_eq!(new.predictor_effective, "none");
    }

    #[test]
    fn owned_predictor_is_single_use_and_single_shard() {
        let spec = RunSpec::builder()
            .preset("smoke")
            .policy("acpc")
            .accesses(20_000)
            .build()
            .unwrap();
        let runner = Runner::new(spec)
            .unwrap()
            .with_predictor(PredictorBox::Heuristic(HeuristicPredictor));
        assert!(runner.run().is_ok());
        assert!(runner.run().is_err(), "owned predictor is consumed by the first run");

        let sharded = RunSpec::builder()
            .preset("smoke")
            .policy("acpc")
            .accesses(20_000)
            .shards(2)
            .build()
            .unwrap();
        let err = Runner::new(sharded)
            .unwrap()
            .with_predictor(PredictorBox::Heuristic(HeuristicPredictor))
            .run();
        assert!(err.is_err(), "owned predictors are thread-affine");
    }

    /// Report JSON rehydration is byte-exact — the invariant the report
    /// store's cache hits rely on (here for an adaptive run, whose
    /// `adaptation` block is the hardest part to round-trip).
    #[test]
    fn report_json_roundtrip_is_byte_exact() {
        let spec = RunSpec::builder()
            .scenario("bursty-batch")
            .policy("acpc")
            .predictor(PredictorKind::Heuristic)
            .accesses(50_000)
            .seed(0xBEE5)
            .adaptive(true)
            .build()
            .unwrap();
        let report = Runner::new(spec).unwrap().run().unwrap();
        let traffic = report.result.traffic.expect("open-loop scenario reports traffic");
        assert!(traffic.offered > 0);
        let text = report.to_json().to_pretty();
        let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_pretty(), text);
        assert_eq!(back.result.predictor, "heuristic");
        assert_eq!(back.result.traffic, Some(traffic));
    }

    /// The per-thread PJRT TCN cache serves only the `backend: pjrt`
    /// escape hatch; native-backend runs route through the shared snapshot
    /// plan instead.
    #[test]
    fn pjrt_cache_is_gated_on_backend() {
        let tcn = |backend: Option<Backend>| {
            let mut b = RunSpec::builder()
                .scenario("decode-heavy")
                .policy("acpc")
                .predictor(PredictorKind::Tcn)
                .accesses(10_000);
            if let Some(be) = backend {
                b = b.backend(be);
            }
            Runner::new(b.build().unwrap()).unwrap()
        };
        assert!(!tcn(None).cache_eligible(), "default backend is native");
        assert!(!tcn(Some(Backend::Native)).cache_eligible());
        assert!(tcn(Some(Backend::Pjrt)).cache_eligible());
        // Trainable native runs still go per-thread (ModelRuntime trains on
        // PJRT), never through the shared-snapshot plan.
        let adaptive = Runner::new(
            RunSpec::builder()
                .scenario("decode-heavy")
                .policy("acpc")
                .predictor(PredictorKind::Tcn)
                .adaptive(true)
                .accesses(10_000)
                .build()
                .unwrap(),
        )
        .unwrap();
        assert!(matches!(adaptive.spec_plan(), SpecPlan::PerThread));
    }

    #[test]
    fn base_predictor_names_invert_decoration() {
        assert_eq!(base_predictor_name("none"), "none");
        assert_eq!(base_predictor_name("tcn"), "tcn");
        assert_eq!(base_predictor_name("heuristic(fallback)"), "heuristic");
        assert_eq!(base_predictor_name("adaptive(heuristic)"), "heuristic");
        assert_eq!(base_predictor_name("adaptive(heuristic(fallback))"), "heuristic");
    }

    #[test]
    fn effective_labels() {
        assert_eq!(effective_label(PredictorKind::None, "none", false), "none");
        assert_eq!(effective_label(PredictorKind::Tcn, "tcn", false), "tcn");
        assert_eq!(
            effective_label(PredictorKind::Tcn, "heuristic", false),
            "heuristic(fallback)"
        );
        assert_eq!(
            effective_label(PredictorKind::Heuristic, "heuristic", true),
            "adaptive(heuristic)"
        );
        assert_eq!(
            effective_label(PredictorKind::Tcn, "heuristic", true),
            "adaptive(heuristic(fallback))"
        );
    }
}
