//! Replay-buffer online learner (paper §3.4), lifted out of the simulator
//! so every consumer — batch sim, `acpc adapt`, the serving coordinator —
//! can fine-tune a predictor from observed reuse outcomes.
//!
//! Each observed access is enqueued with its feature row; once the labeling
//! horizon has passed, the sample's label resolves to "was the line touched
//! again within the horizon?". [`OnlineLearner::train`] then runs a few
//! compiled Adam steps over a uniform replay sample. The learner is
//! predictor-agnostic at the call site ([`OnlineLearner::train_predictor`]):
//! non-trainable predictors (heuristic, none) simply report `None`, which is
//! the controller's cue to fall back to throttling instead of retraining.

use super::last_touch::LastTouch;
use crate::predictor::{ModelRuntime, PredictorBox};
use crate::util::rng::Xoshiro256;
use std::collections::VecDeque;

/// Bound on the last-touch labeling map (entries beyond the horizon are
/// swept once the map exceeds this).
const LAST_TOUCH_CAP: usize = 1 << 17;

/// Replay-buffer online learner (§3.4).
pub struct OnlineLearner {
    /// (features, label) samples awaiting training.
    buf_x: Vec<f32>,
    buf_y: Vec<f32>,
    row: usize,
    capacity: usize,
    /// In-flight observations: line → (enqueue position, features start).
    pending: VecDeque<(u64, u64, usize)>,
    /// Lines touched recently (for labeling). Only maintained on the
    /// standalone [`observe`](Self::observe) path; controller-driven runs
    /// share one [`LastTouch`] across telemetry and learner and call
    /// [`observe_shared`](Self::observe_shared) instead, so this map stays
    /// empty and costs nothing.
    own_last: LastTouch,
    horizon: u64,
    pub steps_run: u64,
    rng: Xoshiro256,
}

impl OnlineLearner {
    pub fn new(row: usize, horizon: u64, seed: u64) -> Self {
        Self {
            buf_x: Vec::new(),
            buf_y: Vec::new(),
            row,
            capacity: 1 << 15,
            pending: VecDeque::new(),
            own_last: LastTouch::new(LAST_TOUCH_CAP, horizon),
            horizon,
            steps_run: 0,
            rng: Xoshiro256::new(seed ^ 0xFEED),
        }
    }

    /// Feature-row width this learner buffers.
    pub fn row(&self) -> usize {
        self.row
    }

    /// Labeled samples currently available for training.
    pub fn resolved(&self) -> usize {
        self.buf_y.iter().filter(|y| !y.is_nan()).count()
    }

    /// Record a touch and enqueue the access as a future training sample,
    /// maintaining the learner's own labeling map (standalone runs with no
    /// adaptive controller).
    pub fn observe(&mut self, pos: u64, line: u64, features: &[f32]) {
        self.own_last.touch(pos, line);
        self.enqueue(pos, line, features);
        let horizon = self.horizon;
        Self::resolve_matured(&mut self.pending, &mut self.buf_y, &self.own_last, pos, horizon);
    }

    /// [`observe`](Self::observe) against a shared [`LastTouch`] map the
    /// caller has *already touched* for this access (the controller touches
    /// once and fans out to telemetry + learner) — no second map insert.
    pub fn observe_shared(&mut self, pos: u64, line: u64, features: &[f32], last: &LastTouch) {
        self.enqueue(pos, line, features);
        Self::resolve_matured(&mut self.pending, &mut self.buf_y, last, pos, self.horizon);
    }

    /// Buffer one observation. A full buffer evicts its oldest half *here*
    /// — not only in [`train`](Self::train) — so drift-triggered trainers
    /// (which may not train for hundreds of thousands of accesses) always
    /// sample the current regime rather than a buffer frozen at the run's
    /// start.
    fn enqueue(&mut self, pos: u64, line: u64, features: &[f32]) {
        if self.buf_y.len() >= self.capacity {
            let keep = self.capacity / 2;
            let drop_n = self.buf_y.len() - keep;
            self.buf_x.drain(..drop_n * self.row);
            self.buf_y.drain(..drop_n);
            self.pending.clear(); // positions invalidated; restart labeling
        }
        let start = self.buf_x.len();
        self.buf_x.extend_from_slice(features);
        self.buf_y.push(f32::NAN); // resolved later
        self.pending.push_back((line, pos, start / self.row));
    }

    /// Resolve matured observations against whichever last-touch map is in
    /// use. Associated fn over disjoint field borrows so both observe paths
    /// can lend `own_last` or an external map.
    fn resolve_matured(
        pending: &mut VecDeque<(u64, u64, usize)>,
        buf_y: &mut [f32],
        last: &LastTouch,
        pos: u64,
        horizon: u64,
    ) {
        while let Some(&(l, p, idx)) = pending.front() {
            if pos.saturating_sub(p) < horizon {
                break;
            }
            let reused = last.last(l).map(|t| t > p && t - p <= horizon).unwrap_or(false);
            buf_y[idx] = reused as u8 as f32;
            pending.pop_front();
        }
    }

    /// Run up to `steps` Adam steps on resolved samples. Returns mean loss,
    /// or `None` when too few samples have matured for a full batch.
    pub fn train(&mut self, model: &mut ModelRuntime, steps: usize) -> Option<f32> {
        let b = model.mm.train.batch;
        let resolved: Vec<usize> =
            (0..self.buf_y.len()).filter(|&i| !self.buf_y[i].is_nan()).collect();
        if resolved.len() < b || steps == 0 {
            return None;
        }
        let mut total = 0.0;
        for _ in 0..steps {
            let mut x = Vec::with_capacity(b * self.row);
            let mut y = Vec::with_capacity(b);
            for _ in 0..b {
                let i = *self.rng.choose(&resolved);
                x.extend_from_slice(&self.buf_x[i * self.row..(i + 1) * self.row]);
                y.push(self.buf_y[i]);
            }
            total += model.train_step(x, y).expect("online train step");
            self.steps_run += 1;
        }
        // Buffer freshness is maintained by `observe` (oldest-half eviction
        // on overflow), so sampling here always sees the current regime.
        Some(total / steps as f32)
    }

    /// Predictor-generic entry point: fine-tunes when the box holds a
    /// trainable [`ModelRuntime`], reports `None` otherwise (heuristic /
    /// no-predictor fallback — the controller throttles instead).
    pub fn train_predictor(&mut self, predictor: &mut PredictorBox, steps: usize) -> Option<f32> {
        match predictor.model_mut() {
            Some(m) => self.train(m, steps),
            None => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::{HeuristicPredictor, FEATURE_DIM};

    #[test]
    fn labels_resolve_after_horizon() {
        let mut l = OnlineLearner::new(FEATURE_DIM, 10, 1);
        let feat = [0.5f32; FEATURE_DIM];
        // Line 7 touched at 0 and 4 (reused within horizon); line 9 once.
        l.observe(0, 7, &feat);
        l.observe(4, 9, &feat);
        assert_eq!(l.resolved(), 0, "nothing matured yet");
        // Advance past the horizon; re-touch 7 so its label is positive.
        l.observe(6, 7, &feat);
        l.observe(20, 1, &feat);
        assert!(l.resolved() >= 2, "matured: {}", l.resolved());
        // First sample of line 7 (pos 0): re-touched at 6 ≤ horizon → 1.
        assert_eq!(l.buf_y[0], 1.0);
        // Line 9 (pos 4): never re-touched → 0.
        assert_eq!(l.buf_y[1], 0.0);
    }

    /// The shared-map path must label identically to the standalone path
    /// when the shared map sees the same touch stream.
    #[test]
    fn shared_map_labels_match_standalone() {
        let feat = [0.5f32; FEATURE_DIM];
        let stream: Vec<(u64, u64)> =
            (0..200).map(|i| (i, [7u64, 9, 7, 13, 9][(i % 5) as usize])).collect();

        let mut own = OnlineLearner::new(FEATURE_DIM, 10, 1);
        for &(pos, line) in &stream {
            own.observe(pos, line, &feat);
        }

        let mut shared_map = LastTouch::new(4096, 10);
        let mut shared = OnlineLearner::new(FEATURE_DIM, 10, 1);
        for &(pos, line) in &stream {
            shared_map.touch(pos, line);
            shared.observe_shared(pos, line, &feat, &shared_map);
        }

        assert_eq!(own.resolved(), shared.resolved());
        // Bitwise compare: unresolved slots are NaN and NaN != NaN.
        let bits = |l: &OnlineLearner| l.buf_y.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&own), bits(&shared));
    }

    #[test]
    fn non_trainable_predictors_yield_none() {
        let mut l = OnlineLearner::new(FEATURE_DIM, 10, 1);
        let feat = [0.1f32; FEATURE_DIM];
        for i in 0..100 {
            l.observe(i, i % 7, &feat);
        }
        assert_eq!(l.train_predictor(&mut PredictorBox::None, 4), None);
        assert_eq!(
            l.train_predictor(&mut PredictorBox::Heuristic(HeuristicPredictor), 4),
            None
        );
        assert_eq!(l.steps_run, 0);
    }
}
