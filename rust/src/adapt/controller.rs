//! The closed-loop adaptive controller: consumes windowed telemetry, runs
//! the drift detector, and reacts — by fine-tuning a trainable predictor
//! from the replay buffer (hot-swapping its weights behind a versioned
//! handle at a batch boundary, so the access loop never stalls on a
//! mid-flight prediction), or, when no trainable model is present or
//! confidence collapses, by *throttling*: predictions are demoted to plain
//! policy-default (LRU-style) insertion until telemetry recovers
//! (LLaMCAT-style back-off).
//!
//! The controller is strictly deterministic for a fixed access stream and
//! seed: telemetry windows are counted in accesses (not wall clock), the
//! Page–Hinkley detector is stateful-but-seedless, and the only RNG (replay
//! sampling) derives from the configured seed.

use super::drift::{Drift, PageHinkley};
use super::last_touch::LastTouch;
use super::learner::OnlineLearner;
use super::telemetry::{Telemetry, WindowStats};
use crate::mem::Hierarchy;
use crate::predictor::PredictorBox;
use crate::util::json::Json;

/// Thresholds and cadences for the adaptive control loop. All units are
/// accesses/windows — never wall clock — so runs are reproducible.
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Telemetry window length in engine accesses.
    pub window_accesses: u64,
    /// Page–Hinkley magnitude tolerance (hit-rate units).
    pub ph_delta: f64,
    /// Page–Hinkley detection threshold.
    pub ph_lambda: f64,
    /// Windows before the detector / throttle logic may act.
    pub warmup_windows: u64,
    /// Windows to wait between consecutive adaptations.
    pub cooldown_windows: u64,
    /// Consecutive unhealthy windows before throttling kicks in.
    pub unhealthy_windows_to_throttle: u64,
    /// Consecutive healthy windows before a throttled controller resumes.
    pub recover_windows: u64,
    /// A window is unhealthy when its hit rate sinks below
    /// `ewma_hit * throttle_hit_ratio` …
    pub throttle_hit_ratio: f64,
    /// … or its pollution exceeds `ewma_pollution + pollution_margin`.
    pub pollution_margin: f64,
    /// Adam steps per drift-triggered fine-tune (trainable predictors).
    pub train_steps_on_drift: usize,
    /// Labeling horizon (accesses) for the replay buffer.
    pub replay_horizon: u64,
    /// Seed for replay sampling.
    pub seed: u64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        Self {
            window_accesses: 8192,
            ph_delta: 0.002,
            ph_lambda: 0.03,
            warmup_windows: 4,
            cooldown_windows: 3,
            unhealthy_windows_to_throttle: 2,
            recover_windows: 3,
            throttle_hit_ratio: 0.88,
            pollution_margin: 0.08,
            train_steps_on_drift: 8,
            replay_horizon: 4096,
            seed: 0xADA7,
        }
    }
}

impl ControllerConfig {
    /// Small windows for fast tests.
    pub fn quick() -> Self {
        Self {
            window_accesses: 2048,
            warmup_windows: 2,
            cooldown_windows: 2,
            unhealthy_windows_to_throttle: 2,
            recover_windows: 2,
            ..Self::default()
        }
    }

    /// Observation-only controller: telemetry is collected but no drift can
    /// fire and no throttle can engage, so a run with a passive controller
    /// is metric-identical to a run without one (asserted by the
    /// integration tests).
    pub fn passive() -> Self {
        Self {
            ph_lambda: f64::INFINITY,
            throttle_hit_ratio: 0.0,
            pollution_margin: f64::INFINITY,
            train_steps_on_drift: 0,
            ..Self::default()
        }
    }
}

/// What an adaptation event did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdaptationAction {
    /// Fine-tuned the trainable predictor from the replay buffer.
    Retrain { steps: u64, mean_loss: f64 },
    /// Demoted predictions to policy-default insertion.
    Throttle,
    /// Re-enabled predictions after recovery.
    Resume,
}

impl AdaptationAction {
    pub fn label(&self) -> &'static str {
        match self {
            AdaptationAction::Retrain { .. } => "retrain",
            AdaptationAction::Throttle => "throttle",
            AdaptationAction::Resume => "resume",
        }
    }
}

/// One recorded adaptation.
#[derive(Debug, Clone, Copy)]
pub struct AdaptationEvent {
    /// Telemetry window index at which the event fired.
    pub window: u64,
    /// Engine access count at the window boundary.
    pub access: u64,
    pub action: AdaptationAction,
    /// The window hit rate that triggered the event.
    pub hit_rate: f64,
    /// Predictor version *after* the event (every event bumps it).
    pub predictor_version: u64,
}

impl AdaptationEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("window", Json::Num(self.window as f64)),
            ("access", Json::Num(self.access as f64)),
            ("action", Json::Str(self.action.label().into())),
            ("hit_rate", Json::Num(self.hit_rate)),
            ("predictor_version", Json::Num(self.predictor_version as f64)),
        ];
        if let AdaptationAction::Retrain { steps, mean_loss } = self.action {
            pairs.push(("steps", Json::Num(steps as f64)));
            if mean_loss.is_finite() {
                pairs.push(("mean_loss", Json::Num(mean_loss)));
            }
        }
        Json::from_pairs(pairs)
    }

    /// Inverse of [`Self::to_json`] (report-store rehydration). A retrain
    /// event without a serialized `mean_loss` decodes it as NaN, which the
    /// serializer omits again — the round-trip is byte-exact.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let f = |key: &str| -> anyhow::Result<f64> {
            match j.req(key)? {
                Json::Null => Ok(f64::NAN),
                v => v.as_f64().ok_or_else(|| anyhow::anyhow!("event.{key}: expected number")),
            }
        };
        let u = |key: &str| -> anyhow::Result<u64> {
            let v = f(key)?;
            if v.is_finite() && v >= 0.0 && v.fract() == 0.0 {
                Ok(v as u64)
            } else {
                anyhow::bail!("event.{key}: expected non-negative integer")
            }
        };
        let label = j.req("action")?.as_str().unwrap_or_default().to_string();
        let action = match label.as_str() {
            "retrain" => AdaptationAction::Retrain {
                steps: u("steps")?,
                mean_loss: match j.get("mean_loss") {
                    Some(v) => v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("event.mean_loss: expected number"))?,
                    None => f64::NAN,
                },
            },
            "throttle" => AdaptationAction::Throttle,
            "resume" => AdaptationAction::Resume,
            other => anyhow::bail!("event.action: unknown label {other:?}"),
        };
        Ok(Self {
            window: u("window")?,
            access: u("access")?,
            action,
            hit_rate: f("hit_rate")?,
            predictor_version: u("predictor_version")?,
        })
    }
}

/// What [`AdaptiveController::maybe_window`] decided this window (callers
/// that need to react — e.g. flush stale utilities on throttle/retrain —
/// branch on this; everything else can ignore it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlDecision {
    Retrained,
    Throttled,
    Resumed,
}

/// How the controller may reach the predictor feeding its engine.
pub enum PredictorAccess<'a> {
    /// No predictor feeds this engine: nothing to throttle or retrain.
    None,
    /// The predictor is owned by the calling loop: the controller may both
    /// throttle its predictions and fine-tune it from the replay buffer.
    Local(&'a mut PredictorBox),
    /// Predictions arrive from elsewhere (the serving coordinator's
    /// predictor-service thread): throttling applies, retraining is out of
    /// reach from here.
    Remote,
}

impl PredictorAccess<'_> {
    /// Are there predictions whose application could be throttled?
    fn throttleable(&self) -> bool {
        match self {
            PredictorAccess::None => false,
            PredictorAccess::Local(p) => p.is_some(),
            PredictorAccess::Remote => true,
        }
    }
}

/// Bound on the retained per-window log (counters keep accumulating past
/// it; only the detailed log is truncated).
const WINDOW_LOG_CAP: usize = 4096;

/// The runtime adaptive-control loop. One controller per engine (per sweep
/// cell / per serving worker); see the module docs for the control law.
pub struct AdaptiveController {
    cfg: ControllerConfig,
    telemetry: Telemetry,
    detector: PageHinkley,
    /// The unified per-line last-touch map (ROADMAP item): touched once per
    /// access, consumed by both the telemetry reuse sketch and the replay
    /// learner's labeler — one map insert where there used to be two.
    last_touch: LastTouch,
    learner: Option<OnlineLearner>,
    /// Versioned-handle counter: bumps on every swap of the *effective*
    /// predictor (retrained weights, throttle engage, resume).
    version: u64,
    /// Weight hot-swaps specifically (Retrain events) — the number callers
    /// should read as "how many times were the weights replaced".
    retrains: u64,
    /// Drift detected but not yet acted on (detection landed in a cooldown
    /// window). The Page–Hinkley detector self-resets when it fires, so
    /// without this carry-over a shift during cooldown would be silently
    /// lost — the reset detector re-anchors on the post-shift regime.
    pending_drift: Option<Drift>,
    throttled: bool,
    unhealthy_streak: u64,
    healthy_streak: u64,
    cooldown_left: u64,
    ewma_hit: f64,
    ewma_pollution: f64,
    ewma_ready: bool,
    window_log: Vec<WindowStats>,
    /// Most recently harvested window, independent of the capped
    /// `window_log` (telemetry streaming reads this at every boundary).
    last_window: Option<WindowStats>,
    events: Vec<AdaptationEvent>,
    drift_windows: Vec<u64>,
    throttled_windows: u64,
}

impl AdaptiveController {
    pub fn new(cfg: ControllerConfig) -> Self {
        let detector =
            PageHinkley::new(cfg.ph_delta, cfg.ph_lambda, cfg.warmup_windows.max(3));
        // Retention must cover both consumers: the learner labels within
        // `replay_horizon`; the reuse sketch wants distances spanning a few
        // telemetry windows.
        let retention = cfg.replay_horizon.max(4 * cfg.window_accesses);
        Self {
            last_touch: LastTouch::new(1 << 17, retention),
            cfg,
            telemetry: Telemetry::new(),
            detector,
            learner: None,
            version: 0,
            retrains: 0,
            pending_drift: None,
            throttled: false,
            unhealthy_streak: 0,
            healthy_streak: 0,
            cooldown_left: 0,
            ewma_hit: 0.0,
            ewma_pollution: 0.0,
            ewma_ready: false,
            window_log: Vec::new(),
            last_window: None,
            events: Vec::new(),
            drift_windows: Vec::new(),
            throttled_windows: 0,
        }
    }

    /// Per-access hook: one touch of the unified [`LastTouch`] map feeds
    /// the telemetry reuse sketch (and, for feature-extracting runs, the
    /// learner's labeler via [`observe_features`](Self::observe_features)).
    /// Cheap; call for every access regardless of feature extraction —
    /// and call it *before* `observe_features` for the same access so the
    /// labeler sees the current touch.
    pub fn observe_access(&mut self, pos: u64, line: u64) {
        let prev = self.last_touch.touch(pos, line);
        self.telemetry.record_reuse(prev, pos);
    }

    /// Per-access hook for feature-extracting runs: feeds the replay
    /// buffer, labeling against the unified last-touch map (already
    /// touched by [`observe_access`](Self::observe_access) — no second map
    /// insert). The learner's row width is latched from the first call.
    pub fn observe_features(&mut self, pos: u64, line: u64, features: &[f32]) {
        let cfg = &self.cfg;
        let learner = self.learner.get_or_insert_with(|| {
            OnlineLearner::new(features.len(), cfg.replay_horizon, cfg.seed)
        });
        learner.observe_shared(pos, line, features, &self.last_touch);
    }

    /// Should completed predictions be applied to the hierarchy? `false`
    /// while throttled (predictions demoted to policy-default insertion).
    pub fn apply_predictions(&self) -> bool {
        !self.throttled
    }

    pub fn throttled(&self) -> bool {
        self.throttled
    }

    /// Current predictor version (bumps on every hot swap).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn windows(&self) -> u64 {
        self.telemetry.windows()
    }

    /// Distinct windows at which the drift detector fired.
    pub fn drift_count(&self) -> u64 {
        self.drift_windows.len() as u64
    }

    /// Weight hot-swaps (drift-triggered retrains). Throttle/resume bump
    /// the handle [`version`](Self::version) but do not replace weights,
    /// so they are deliberately not counted here.
    pub fn swap_count(&self) -> u64 {
        self.retrains
    }

    pub fn throttled_windows(&self) -> u64 {
        self.throttled_windows
    }

    pub fn events(&self) -> &[AdaptationEvent] {
        &self.events
    }

    pub fn window_log(&self) -> &[WindowStats] {
        &self.window_log
    }

    /// The most recently harvested window, even past the retained-log cap.
    /// `None` before the first boundary.
    pub fn last_window(&self) -> Option<WindowStats> {
        self.last_window
    }

    fn record(&mut self, w: &WindowStats, access: u64, action: AdaptationAction) {
        self.version += 1;
        self.events.push(AdaptationEvent {
            window: w.index,
            access,
            action,
            hit_rate: w.hit_rate,
            predictor_version: self.version,
        });
    }

    /// Window-boundary hook: call once per access with the engine's access
    /// count; does nothing except on multiples of `window_accesses`. On a
    /// boundary it harvests telemetry, updates the drift detector, and
    /// applies the control law against whatever predictor access the
    /// caller has.
    pub fn maybe_window(
        &mut self,
        steps: u64,
        hier: &Hierarchy,
        mut predictor: PredictorAccess<'_>,
    ) -> Option<ControlDecision> {
        if steps == 0 || steps % self.cfg.window_accesses != 0 {
            return None;
        }
        let w = self.telemetry.harvest(hier);
        self.last_window = Some(w);
        if self.window_log.len() < WINDOW_LOG_CAP {
            self.window_log.push(w);
        }
        if self.throttled {
            self.throttled_windows += 1;
        }
        let past_warmup = w.index + 1 > self.cfg.warmup_windows;
        // A window with no L2 demand carries no hit-rate evidence: its
        // `hit_rate` is 0.0 only because of the max(1) denominator, and
        // feeding that into the drift test would read as a total collapse.
        // Such windows are logged but not scored.
        let scored = w.l2_demand > 0;

        // Health bookkeeping against the EWMA baseline — only after
        // warmup. Cold-start windows (tiny demand counts, unfilled caches)
        // would otherwise seed a skewed baseline and bank an unhealthy
        // streak that lets throttling fire on pre-baseline evidence the
        // moment warmup ends.
        if past_warmup && scored {
            let unhealthy = self.ewma_ready
                && (w.hit_rate < self.ewma_hit * self.cfg.throttle_hit_ratio
                    || w.pollution > self.ewma_pollution + self.cfg.pollution_margin);
            if unhealthy {
                self.unhealthy_streak += 1;
                self.healthy_streak = 0;
            } else {
                self.unhealthy_streak = 0;
                self.healthy_streak += 1;
            }
            // The baseline is frozen while throttled: letting it absorb
            // throttled-regime windows would converge it onto the degraded
            // level, every window would then read "healthy" against its
            // own regime, and the throttle would auto-resume with no real
            // recovery (a throttle/resume oscillation). Resume therefore
            // requires telemetry back near the *pre-throttle* baseline.
            if !self.throttled {
                if self.ewma_ready {
                    self.ewma_hit = 0.8 * self.ewma_hit + 0.2 * w.hit_rate;
                    self.ewma_pollution = 0.8 * self.ewma_pollution + 0.2 * w.pollution;
                } else {
                    self.ewma_hit = w.hit_rate;
                    self.ewma_pollution = w.pollution;
                    self.ewma_ready = true;
                }
            }
        }

        // Drift detection runs on every scored window (so the drift log is
        // complete), but actions respect warmup + cooldown: a detection
        // during cooldown is carried in `pending_drift` and acted on at
        // the next actionable window instead of being lost.
        let detected = if scored { self.detector.update(w.hit_rate) } else { None };
        if detected.is_some() && past_warmup {
            self.drift_windows.push(w.index);
            self.pending_drift = detected;
        }

        let mut decision = None;
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
        } else if past_warmup {
            // Only downward shifts trigger adaptation: an upward drift is
            // logged but needs no reaction (and while throttled it is
            // usually the throttle itself working — retraining on it would
            // bypass the recovery gate and re-enable the predictions whose
            // removal caused the improvement).
            if self.pending_drift.take() == Some(Drift::Down) {
                let steps_cfg = self.cfg.train_steps_on_drift;
                let loss = match (&mut predictor, self.learner.as_mut()) {
                    (PredictorAccess::Local(p), Some(l)) => l.train_predictor(p, steps_cfg),
                    _ => None,
                };
                if let Some(mean_loss) = loss {
                    // Hot swap: the replay-tuned weights become the live
                    // predictor at the next batch boundary. A retrain also
                    // lifts any standing throttle — fresh weights deserve
                    // to be applied, and a Retrain event that left
                    // predictions discarded would misstate what ran.
                    self.throttled = false;
                    self.unhealthy_streak = 0;
                    self.retrains += 1;
                    self.record(
                        &w,
                        steps,
                        AdaptationAction::Retrain {
                            steps: steps_cfg as u64,
                            mean_loss: mean_loss as f64,
                        },
                    );
                    decision = Some(ControlDecision::Retrained);
                    self.cooldown_left = self.cfg.cooldown_windows;
                } else if !self.throttled && predictor.throttleable() {
                    // No trainable model (or replay not matured): back off.
                    self.throttled = true;
                    self.healthy_streak = 0;
                    self.record(&w, steps, AdaptationAction::Throttle);
                    decision = Some(ControlDecision::Throttled);
                    self.cooldown_left = self.cfg.cooldown_windows;
                }
            }
            // Confidence collapse independent of the mean-shift test.
            if decision.is_none()
                && !self.throttled
                && predictor.throttleable()
                && self.unhealthy_streak >= self.cfg.unhealthy_windows_to_throttle
            {
                self.throttled = true;
                self.healthy_streak = 0;
                self.record(&w, steps, AdaptationAction::Throttle);
                decision = Some(ControlDecision::Throttled);
                self.cooldown_left = self.cfg.cooldown_windows;
            }
            // Recovery: healthy long enough → resume predictions.
            if decision.is_none()
                && self.throttled
                && self.healthy_streak >= self.cfg.recover_windows
            {
                self.throttled = false;
                self.record(&w, steps, AdaptationAction::Resume);
                decision = Some(ControlDecision::Resumed);
                self.cooldown_left = self.cfg.cooldown_windows;
            }
        }
        decision
    }

    /// Replay-buffer Adam steps executed by drift-triggered retrains.
    pub fn online_train_steps(&self) -> u64 {
        self.learner.as_ref().map(|l| l.steps_run).unwrap_or(0)
    }

    /// Consume the controller into its serializable run summary.
    pub fn into_summary(self) -> ControllerSummary {
        ControllerSummary {
            windows_observed: self.telemetry.windows(),
            drift_events: self.drift_windows.len() as u64,
            swaps: self.retrains,
            throttled_windows: self.throttled_windows,
            online_train_steps: self.learner.as_ref().map(|l| l.steps_run).unwrap_or(0),
            drift_windows: self.drift_windows,
            events: self.events,
            windows: self.window_log,
        }
    }
}

/// Serializable summary of one controller run (`acpc adapt --json`).
#[derive(Debug, Clone)]
pub struct ControllerSummary {
    pub windows_observed: u64,
    pub drift_events: u64,
    pub swaps: u64,
    pub throttled_windows: u64,
    /// Replay-buffer Adam steps run by drift-triggered retrains.
    pub online_train_steps: u64,
    pub drift_windows: Vec<u64>,
    pub events: Vec<AdaptationEvent>,
    pub windows: Vec<WindowStats>,
}

impl ControllerSummary {
    /// Merge the per-shard controller summaries of a sharded adaptive run:
    /// counters sum; the drift-window list and the event/window logs are
    /// interleaved in (access, window) order. Window indices are per-shard,
    /// so a merged log can repeat an index — consumers treating it as a
    /// trace (not a key) are unaffected.
    pub fn merge(parts: Vec<ControllerSummary>) -> ControllerSummary {
        let mut out = ControllerSummary {
            windows_observed: 0,
            drift_events: 0,
            swaps: 0,
            throttled_windows: 0,
            online_train_steps: 0,
            drift_windows: Vec::new(),
            events: Vec::new(),
            windows: Vec::new(),
        };
        for p in parts {
            out.windows_observed += p.windows_observed;
            out.drift_events += p.drift_events;
            out.swaps += p.swaps;
            out.throttled_windows += p.throttled_windows;
            out.online_train_steps += p.online_train_steps;
            out.drift_windows.extend(p.drift_windows);
            out.events.extend(p.events);
            out.windows.extend(p.windows);
        }
        out.drift_windows.sort_unstable();
        out.events.sort_by_key(|e| (e.access, e.window));
        out.windows.sort_by_key(|w| w.index);
        out
    }

    /// Columnar per-window telemetry series (schema
    /// `acpc-adapt-telemetry-v1`) — the fig-style plotting input written by
    /// `acpc adapt --telemetry`. One entry per retained window, parallel
    /// arrays per metric; sharded runs interleave their per-shard windows
    /// in index order (an index can repeat once per shard).
    pub fn telemetry_json(&self) -> Json {
        fn col(windows: &[WindowStats], f: impl Fn(&WindowStats) -> f64) -> Json {
            Json::Arr(windows.iter().map(|w| Json::Num(f(w))).collect())
        }
        let w = &self.windows;
        Json::from_pairs(vec![
            ("schema", Json::Str("acpc-adapt-telemetry-v1".into())),
            ("windows_observed", Json::Num(self.windows_observed as f64)),
            ("index", col(w, |x| x.index as f64)),
            ("accesses", col(w, |x| x.accesses as f64)),
            ("l2_demand", col(w, |x| x.l2_demand as f64)),
            ("hit_rate", col(w, |x| x.hit_rate)),
            ("pollution", col(w, |x| x.pollution)),
            ("prefetch_accuracy", col(w, |x| x.prefetch_accuracy)),
            ("reuse_p50_log2", col(w, |x| x.reuse_p50_log2 as f64)),
            (
                "drift_windows",
                Json::Arr(self.drift_windows.iter().map(|&d| Json::Num(d as f64)).collect()),
            ),
            ("events", Json::Arr(self.events.iter().map(|e| e.to_json()).collect())),
        ])
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("windows_observed", Json::Num(self.windows_observed as f64)),
            ("drift_events", Json::Num(self.drift_events as f64)),
            ("swaps", Json::Num(self.swaps as f64)),
            ("throttled_windows", Json::Num(self.throttled_windows as f64)),
            ("online_train_steps", Json::Num(self.online_train_steps as f64)),
            (
                "drift_windows",
                Json::Arr(self.drift_windows.iter().map(|&w| Json::Num(w as f64)).collect()),
            ),
            ("events", Json::Arr(self.events.iter().map(|e| e.to_json()).collect())),
            ("windows", Json::Arr(self.windows.iter().map(|w| w.to_json()).collect())),
        ])
    }

    /// Inverse of [`Self::to_json`] (report-store rehydration). The
    /// rehydrated summary re-serializes byte-identically: `merge` of a
    /// single already-merged summary is the identity (stable sorts over
    /// already-sorted logs), which the store's byte-identity tests pin.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let u = |key: &str| -> anyhow::Result<u64> {
            j.req(key)?
                .as_f64()
                .filter(|v| v.is_finite() && *v >= 0.0 && v.fract() == 0.0)
                .map(|v| v as u64)
                .ok_or_else(|| anyhow::anyhow!("adaptation.{key}: expected non-negative integer"))
        };
        let arr = |key: &str| -> anyhow::Result<&[Json]> {
            j.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("adaptation.{key}: expected array"))
        };
        let drift_windows = arr("drift_windows")?
            .iter()
            .map(|v| {
                v.as_f64()
                    .filter(|x| x.is_finite() && *x >= 0.0 && x.fract() == 0.0)
                    .map(|x| x as u64)
                    .ok_or_else(|| anyhow::anyhow!("adaptation.drift_windows: expected integers"))
            })
            .collect::<anyhow::Result<Vec<u64>>>()?;
        let events = arr("events")?
            .iter()
            .map(AdaptationEvent::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let windows = arr("windows")?
            .iter()
            .map(WindowStats::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self {
            windows_observed: u("windows_observed")?,
            drift_events: u("drift_events")?,
            swaps: u("swaps")?,
            throttled_windows: u("throttled_windows")?,
            online_train_steps: u("online_train_steps")?,
            drift_windows,
            events,
            windows,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::HierarchyConfig;
    use crate::policy::AccessMeta;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    /// Drive a hierarchy + controller by hand for `n` accesses.
    fn drive(ccfg: ControllerConfig, n: u64, seed: u64) -> AdaptiveController {
        let mut h = Hierarchy::new(HierarchyConfig::scaled(), "acpc");
        let mut gen = TraceGenerator::new(GeneratorConfig::tiny(seed));
        let mut c = AdaptiveController::new(ccfg);
        let mut p = PredictorBox::Heuristic(crate::predictor::HeuristicPredictor);
        for i in 0..n {
            let a = gen.next_access();
            let meta = AccessMeta::demand(a.line(), a.pc, a.kind);
            h.access(&a, &meta);
            c.observe_access(i, a.line());
            c.maybe_window(i + 1, &h, PredictorAccess::Local(&mut p));
        }
        c
    }

    #[test]
    fn windows_tick_at_configured_cadence() {
        let mut ccfg = ControllerConfig::quick();
        ccfg.window_accesses = 1000;
        let c = drive(ccfg, 10_500, 3);
        assert_eq!(c.windows(), 10);
        assert_eq!(c.window_log().len(), 10);
    }

    #[test]
    fn passive_controller_never_acts() {
        let c = drive(ControllerConfig::passive(), 80_000, 7);
        assert!(c.events().is_empty(), "{:?}", c.events());
        assert_eq!(c.swap_count(), 0);
        assert_eq!(c.drift_count(), 0);
        assert!(!c.throttled());
        assert!(c.windows() > 0);
    }

    #[test]
    fn controller_is_deterministic() {
        let a = drive(ControllerConfig::quick(), 120_000, 11).into_summary();
        let b = drive(ControllerConfig::quick(), 120_000, 11).into_summary();
        assert_eq!(a.drift_windows, b.drift_windows);
        assert_eq!(a.swaps, b.swaps);
        assert_eq!(a.throttled_windows, b.throttled_windows);
        assert_eq!(a.events.len(), b.events.len());
        assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    }

    #[test]
    fn telemetry_series_is_columnar_and_aligned() {
        let s = drive(ControllerConfig::quick(), 40_000, 5).into_summary();
        let j = s.telemetry_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("acpc-adapt-telemetry-v1"));
        let n = s.windows.len();
        assert!(n > 0);
        for key in
            ["index", "accesses", "l2_demand", "hit_rate", "pollution", "prefetch_accuracy",
             "reuse_p50_log2"]
        {
            let arr = j.get(key).unwrap().as_arr().unwrap();
            assert_eq!(arr.len(), n, "column {key} must align with the window log");
        }
        assert!(j.get("events").unwrap().as_arr().is_some());
    }

    /// Rehydrating a serialized summary and re-merging it (as the report
    /// store does on a cache hit) reproduces the original bytes.
    #[test]
    fn summary_json_roundtrip_is_byte_exact() {
        let s = drive(ControllerConfig::quick(), 120_000, 11).into_summary();
        let merged = ControllerSummary::merge(vec![s]);
        let text = merged.to_json().to_pretty();
        let back = ControllerSummary::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_pretty(), text);
        assert_eq!(ControllerSummary::merge(vec![back]).to_json().to_pretty(), text);
    }

    #[test]
    fn summary_json_has_schema_keys() {
        let s = drive(ControllerConfig::quick(), 30_000, 5).into_summary();
        let j = s.to_json();
        for key in [
            "windows_observed",
            "drift_events",
            "swaps",
            "throttled_windows",
            "online_train_steps",
            "drift_windows",
            "events",
            "windows",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }
}
